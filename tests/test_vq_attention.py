"""VQ-Attention (the paper's technique on the token graph): invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from repro.kernels import ref
from repro.nn.vq_attention import (VQAttnConfig, init_vq_cache,
                                   vq_attention_decode, vq_attention_train)


def _exact_gqa(q, k, v):
    g = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    return ref.flash_attention(
        q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
        vv.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)


def _rand_qkv(key, b=2, s=64, hq=4, hkv=2, dh=16):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, hq, dh)),
            jax.random.normal(ks[1], (b, s, hkv, dh)),
            jax.random.normal(ks[2], (b, s, hkv, dh)))


def test_exact_when_context_fits_window():
    """S <= 2W: the codebook is never consulted -> identical to exact
    attention (the C_in term covers everything; paper's exact-recovery)."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), s=64)
    o_vq = vq_attention_train(q, k, v, VQAttnConfig(k=8, window=32))
    o_ex = _exact_gqa(q, k, v)
    assert_allclose(np.asarray(o_vq), np.asarray(o_ex), rtol=1e-4, atol=1e-4)


def test_error_decreases_with_codebook_size():
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), s=128)
    o_ex = _exact_gqa(q, k, v)
    errs = []
    for kcb in (2, 8, 64):
        o = vq_attention_train(q, k, v, VQAttnConfig(k=kcb, window=8))
        errs.append(float(jnp.abs(o - o_ex).mean()))
    assert errs[2] < errs[0]


def test_clustered_keys_near_exact():
    """When past keys genuinely cluster (the paper's regime), VQ attention
    approaches exact attention even with a small codebook."""
    key = jax.random.PRNGKey(2)
    b, s, hq, hkv, dh = 1, 256, 2, 1, 16
    centers = jax.random.normal(key, (4, dh))
    idx = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, 4)
    k = centers[idx][:, :, None, :] + 0.01 * jax.random.normal(
        jax.random.PRNGKey(4), (b, s, hkv, dh))
    v = centers[idx][:, :, None, :] + 0.01 * jax.random.normal(
        jax.random.PRNGKey(5), (b, s, hkv, dh))
    q = jax.random.normal(jax.random.PRNGKey(6), (b, s, hq, dh))
    o_ex = _exact_gqa(q, k, v)
    o_vq = vq_attention_train(q, k, v, VQAttnConfig(k=16, window=32))
    rel = float(jnp.abs(o_vq - o_ex).mean() / jnp.abs(o_ex).mean())
    assert rel < 0.12, rel


def test_train_is_differentiable_through_codebook():
    """Straight-through centroids: gradients flow to PAST tokens' k/v
    (the LM replacement for Eq. 7 -- DESIGN.md section 4)."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(7), s=64)
    cfg = VQAttnConfig(k=8, window=8)

    def loss(kv):
        kk, vv = kv
        o = vq_attention_train(q, kk, vv, cfg)
        return jnp.sum(o[:, -8:] ** 2)    # loss only on the LAST block

    gk, gv = jax.grad(loss)((k, v))
    # early tokens are reachable only through the codebook -> nonzero grads
    assert float(jnp.abs(gk[:, :16]).sum()) > 0
    assert float(jnp.abs(gv[:, :16]).sum()) > 0


def test_decode_matches_train_regime():
    q, k, v = _rand_qkv(jax.random.PRNGKey(8), s=64)
    cfg = VQAttnConfig(k=16, window=16)
    cache = init_vq_cache(2, 2, 16, cfg, jnp.float32)
    outs = []
    for t in range(64):
        o, cache = vq_attention_decode(q[:, t:t + 1], k[:, t:t + 1],
                                       v[:, t:t + 1], cache, cfg)
        outs.append(o)
    o_dec = jnp.concatenate(outs, axis=1)
    o_tr = vq_attention_train(q, k, v, cfg)
    rel = float(jnp.abs(o_dec - o_tr).mean() / jnp.abs(o_tr).mean())
    assert rel < 0.3, rel
    assert int(cache.pos) == 64
    # codebook masses account for all evicted tokens
    assert_allclose(float(cache.count.sum()) / (2 * 2), 64 - 16, atol=1e-3)


def test_decode_cache_is_constant_size():
    cfg = VQAttnConfig(k=8, window=4)
    cache = init_vq_cache(1, 1, 8, cfg, jnp.float32)
    sizes0 = jax.tree_util.tree_map(lambda a: a.shape, cache)
    key = jax.random.PRNGKey(0)
    for t in range(32):
        q = jax.random.normal(key, (1, 1, 2, 8))
        kv = jax.random.normal(key, (1, 1, 1, 8))
        _, cache = vq_attention_decode(q, kv, kv, cache, cfg)
    assert jax.tree_util.tree_map(lambda a: a.shape, cache) == sizes0
