"""Property-style invariant tests for the sampling baselines (ISSUE 6).

Shared 5-tuple contract of repro.graph.sampling: every sampler yields
``(src, dst, nodes, seed_pos, seed_weight)`` with src/dst local to the
induced subgraph, seeds contained in nodes, and a wrap-padded seed stream
(every pool id is a weight-1 seed exactly once per epoch -- the regression
the legacy ``range(0, len - b + 1, b)`` loop failed).
"""
import numpy as np
import pytest

from repro.graph.datasets import synthetic_arxiv
from repro.graph.sampling import (SAMPLER_METHODS, _labor_select,
                                  cluster_gcn_batches, hybrid_epoch_batches,
                                  labor_batches, ns_sage_batches,
                                  partition_graph, sample_epoch)


@pytest.fixture(scope="module")
def g():
    return synthetic_arxiv(n=400, seed=0)


def _epoch(g, method, seed=0, batch_size=64, **kw):
    rng = np.random.default_rng(seed)
    kw.setdefault("fanouts", [3, 3])
    if method == "cluster-gcn":
        kw["partition"] = partition_graph(g, 8, rng)
        kw.setdefault("parts_per_batch", 3)
    return sample_epoch(g, method, batch_size=batch_size, rng=rng, **kw)


@pytest.mark.parametrize("method", SAMPLER_METHODS)
def test_seeds_contained_and_edges_internal(g, method):
    for src, dst, nodes, seed_pos, seed_w in _epoch(g, method):
        n_sub = len(nodes)
        # seed positions index into the subgraph and resolve to real nodes
        assert len(seed_pos) == len(seed_w)
        assert np.all(seed_pos >= 0) and np.all(seed_pos < n_sub)
        # all edges are internal to the subgraph...
        assert np.all(src >= 0) and np.all(src < n_sub)
        assert np.all(dst >= 0) and np.all(dst < n_sub)
        # ...and are REAL edges of g (no fabricated connectivity)
        for s, d in zip(nodes[src[:50]], nodes[dst[:50]]):
            assert s in g.in_csr.neighbors(d)
        # node list is sorted and unique (the searchsorted seed_pos
        # contract of the neighborhood samplers)
        assert np.all(np.diff(nodes) > 0)


@pytest.mark.parametrize("method", SAMPLER_METHODS)
def test_identical_rng_identical_batches(g, method):
    a = _epoch(g, method, seed=7)
    b = _epoch(g, method, seed=7)
    assert len(a) == len(b)
    for ba, bb in zip(a, b):
        for xa, xb in zip(ba, bb):
            np.testing.assert_array_equal(xa, xb)


@pytest.mark.parametrize("method", ["ns-sage", "labor"])
def test_every_pool_id_seeds_exactly_once(g, method):
    """The tail-batch regression: wrap padding must keep every pool id a
    weight-1 seed exactly once per epoch, with ceil(pool/b) batches."""
    pool = g.train_idx
    b = 64
    assert len(pool) % b != 0, "pick sizes that exercise the tail batch"
    batches = _epoch(g, method, batch_size=b)
    assert len(batches) == -(-len(pool) // b)
    counts = np.zeros(g.n)
    for _, _, nodes, seed_pos, seed_w in batches:
        assert len(seed_pos) == b          # static batch width
        np.add.at(counts, nodes[seed_pos], seed_w)
    assert np.all(counts[pool] == 1.0)
    assert counts.sum() == len(pool)       # pad seeds carry weight 0


def test_cluster_tail_keeps_remainder_partitions(g):
    """3 parts/batch over 8 partitions -> batches of 3+3+2 partitions; the
    legacy loop dropped the final 2 and with them their nodes."""
    batches = _epoch(g, "cluster-gcn")
    assert len(batches) == 3
    covered = np.concatenate([nodes for _, _, nodes, _, _ in batches])
    assert len(np.unique(covered)) == g.n   # every node trains once
    assert len(covered) == g.n              # partitions are disjoint


def test_partition_cover_and_disjoint(g):
    part = partition_graph(g, 8, np.random.default_rng(0))
    assert part.shape == (g.n,)
    assert part.min() >= 0 and part.max() < 8


@pytest.mark.parametrize("labor", [False, True])
def test_fanout_caps(g, labor):
    """Per-seed sampled in-degree <= fanout at every layer -- for LABOR
    this is the deterministic contract of the shared-variate thinning."""
    from repro.graph.sampling import _expand_batch
    rng = np.random.default_rng(3)
    seeds = rng.choice(g.n, 32, replace=False)
    fanouts = [3, 2]
    _, layers = _expand_batch(g, seeds, fanouts, rng, labor=labor)
    assert len(layers) == len(fanouts)
    for picks, r in zip(layers, fanouts):
        for ns in picks:
            assert len(ns) <= r
            assert len(np.unique(ns)) == len(ns)


def test_labor_shared_variates_correlate_picks(g):
    """Seeds with a common neighbor pool pick the SAME neighbors under one
    shared variate draw: the union over seeds stays near the per-seed
    fanout instead of growing additively (LABOR's variance reduction), and
    the picks are exactly the fanout smallest r-values."""
    rng = np.random.default_rng(5)
    rvals = rng.random(g.n)
    deg = g.in_csr.degrees()
    seeds = np.where(deg >= 4)[0][:16]
    picks = _labor_select(g.in_csr, seeds, 2, rvals)
    for i, ns in zip(seeds, picks):
        full = g.in_csr.neighbors(i)
        expect = full[np.argsort(rvals[full], kind="stable")[:2]]
        np.testing.assert_array_equal(np.sort(ns), np.sort(expect))
    # cross-seed correlation: two seeds sharing their full neighbor set
    # must pick identically -- build the check from any shared neighbors
    chosen = {int(i): set(int(t) for t in ns)
              for i, ns in zip(seeds, picks)}
    for i in seeds:
        for j in seeds:
            si = set(g.in_csr.neighbors(i).tolist())
            if si and si == set(g.in_csr.neighbors(j).tolist()):
                assert chosen[int(i)] == chosen[int(j)]


def test_labor_union_no_larger_than_ns(g):
    """At equal fanout the LABOR union should (weakly) undercut NS-SAGE on
    average -- the defusing-the-explosion claim, as a coarse statistical
    check over several epochs."""
    tot_ns = tot_lb = 0
    for seed in range(4):
        for _, _, nodes, _, _ in _epoch(g, "ns-sage", seed=seed):
            tot_ns += len(nodes)
        for _, _, nodes, _, _ in _epoch(g, "labor", seed=seed):
            tot_lb += len(nodes)
    assert tot_lb <= tot_ns * 1.02


def test_sample_epoch_unknown_method_raises(g):
    with pytest.raises(ValueError, match="unknown sampler"):
        _epoch(g, "metropolis")
    with pytest.raises(ValueError, match="partition"):
        sample_epoch(g, "cluster-gcn", batch_size=8,
                     rng=np.random.default_rng(0))


def test_direct_iterators_match_sample_epoch(g):
    """The thin wrappers and the sample_epoch front consume rng
    identically (the parity precondition)."""
    for method, fn in (("ns-sage", ns_sage_batches),
                       ("labor", labor_batches)):
        direct = list(fn(g, 64, [3, 3], np.random.default_rng(2),
                         g.train_idx))
        front = _epoch(g, method, seed=2)
        for ba, bb in zip(direct, front):
            for xa, xb in zip(ba, bb):
                np.testing.assert_array_equal(xa, xb)
    part = partition_graph(g, 8, np.random.default_rng(2))
    direct = list(cluster_gcn_batches(g, part, 3,
                                      np.random.default_rng(2)))
    # sample_epoch draws the permutation from the same stream state
    rng = np.random.default_rng(2)
    part2 = partition_graph(g, 8, rng)
    front = sample_epoch(g, "cluster-gcn", batch_size=64, rng=rng,
                         partition=part2, parts_per_batch=3)
    np.testing.assert_array_equal(part, part2)


# ---------------------------------------------------------------------------
# hybrid batches
# ---------------------------------------------------------------------------

def test_hybrid_rows_distinct_mask_on_seeds_only(g):
    rng = np.random.default_rng(0)
    b = 64
    ids, mask = hybrid_epoch_batches(g, b, [3, 3], rng, n_ctx=32)
    assert ids.shape == mask.shape
    assert ids.shape[1] == b + 32
    for s in range(ids.shape[0]):
        # distinct ids per row (refresh_assignment scatter contract)
        assert len(np.unique(ids[s])) == ids.shape[1]
        # loss only on seed slots
        assert np.all(mask[s, b:] == 0.0)
    # every node seeds exactly one batch (weight-1 seed slots cover g.n)
    seeds = ids[:, :b][mask[:, :b] > 0]
    assert len(np.unique(seeds)) == g.n


def test_hybrid_nctx_zero_degenerates_to_plain_slices(g):
    from repro.graph.batching import epoch_slices
    ids, mask = hybrid_epoch_batches(g, 64, [3, 3],
                                     np.random.default_rng(9), n_ctx=0)
    rng = np.random.default_rng(9)
    ids2, mask2 = epoch_slices(rng.permutation(np.arange(g.n)), 64)
    np.testing.assert_array_equal(ids, ids2)
    np.testing.assert_array_equal(mask, mask2)


def test_hybrid_ctx_clamped_to_graph(g):
    ids, mask = hybrid_epoch_batches(g, 64, [3], np.random.default_rng(1),
                                     n_ctx=10 * g.n)
    assert ids.shape[1] == g.n              # b + n_ctx clamped to n
    for s in range(ids.shape[0]):
        assert len(np.unique(ids[s])) == g.n
