"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step + one decode step on CPU; asserts shapes + finiteness.
(The FULL configs are exercised only via the dry-run, per the assignment.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, get_smoke
from repro.models.lm import (init_lm, init_serve_cache, prefill, serve_step,
                             train_loss)

ALL = list(ARCHS)


def _aux(cfg, b, key):
    if cfg.family == "audio":
        return jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        return jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ALL)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    b, s = 2, 32
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    aux = _aux(cfg, b, key)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, tokens, cfg, aux))(params)
    assert jnp.isfinite(loss), arch
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ALL)
def test_decode_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    b = 2
    cache = init_serve_cache(cfg, b, 64)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab)
    logits, cache = serve_step(params, tok, cache, cfg)
    assert logits.shape == (b, cfg.vocab), arch
    assert bool(jnp.isfinite(logits).all()), arch
    # second step must advance cleanly on the updated cache
    logits2, cache = serve_step(params, tok, cache, cfg)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-moe-30b-a3b"])
def test_vq_attention_variant_smoke(arch):
    """The paper's technique as a config flag on the LM archs."""
    cfg = get_smoke(arch).with_vq(k=8, window=8)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    tokens = jax.random.randint(key, (2, 33), 0, cfg.vocab)
    loss = train_loss(params, tokens, cfg)
    assert jnp.isfinite(loss)
    cache = init_serve_cache(cfg, 2, 64)
    logits, _ = serve_step(params, tokens[:, :1], cache, cfg)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_smoke():
    cfg = get_smoke("granite-3-8b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    out = prefill(params, tokens, cfg)
    assert out.shape == (2, cfg.vocab)


def test_exact_configs_match_assignment():
    """The full configs carry the exact assigned numbers."""
    a = ARCHS
    g = a["granite-3-8b"]
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (40, 4096, 32, 8, 12800, 49155)
    l = a["llama3-405b"]
    assert (l.n_layers, l.d_model, l.n_heads, l.n_kv_heads, l.d_ff,
            l.vocab) == (126, 16384, 128, 8, 53248, 128256)
    q = a["qwen3-32b"]
    assert (q.n_layers, q.d_model, q.n_heads, q.d_ff,
            q.vocab, q.qk_norm) == (64, 5120, 64, 25600, 151936, True)
    m = a["qwen3-moe-30b-a3b"]
    assert (m.n_experts, m.top_k, m.d_ff, m.d_model) == (128, 8, 768, 2048)
    p = a["phi3.5-moe-42b-a6.6b"]
    assert (p.n_experts, p.top_k, p.d_ff) == (16, 2, 6400)
    z = a["zamba2-2.7b"]
    assert (z.ssm_state, z.n_layers, z.d_model) == (64, 54, 2560)
    w = a["whisper-tiny"]
    assert (w.n_layers, w.d_model, w.n_heads, w.d_ff) == (4, 384, 6, 1536)
    v = a["llama-3.2-vision-11b"]
    assert (v.n_layers, v.d_model, v.d_ff, v.vocab) == (40, 4096, 14336,
                                                        128256)
    x = a["xlstm-350m"]
    assert (x.n_layers, x.d_model, x.n_heads) == (24, 1024, 4)
    ll = a["llama3.2-3b"]
    assert (ll.n_layers, ll.d_model, ll.n_heads, ll.d_ff) == (28, 3072, 24,
                                                              8192)
