"""Train-form vs decode-form equivalence for the recurrent substrates.

The parallel (training) formulations -- associative-scan SSD for Mamba2,
decay-masked quadratic for mLSTM, time-scan for sLSTM -- must produce the
same outputs as running the O(1)-per-step decode recurrences token by
token.  This is the correctness contract that makes the decode_32k /
long_500k serve cells meaningful.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.nn.ssm import (apply_mamba2_step, apply_mamba2_train, init_mamba2,
                          init_mamba2_state)
from repro.nn.xlstm import (apply_mlstm_step, apply_mlstm_train,
                            apply_slstm_step, apply_slstm_train, init_mlstm,
                            init_mlstm_state, init_slstm, init_slstm_state)


def test_mamba2_train_equals_stepwise():
    d, n, b, s = 32, 16, 2, 12
    p = init_mamba2(jax.random.PRNGKey(0), d, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    y_par = apply_mamba2_train(p, x, d, n)
    st = init_mamba2_state(b, d, n)
    outs = []
    for t in range(s):
        o, st = apply_mamba2_step(p, x[:, t:t + 1], st, d, n)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=2e-4,
                    atol=2e-4)


def test_mlstm_train_equals_stepwise():
    d, h, b, s = 32, 4, 2, 16
    p = init_mlstm(jax.random.PRNGKey(0), d, h)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    y_par = apply_mlstm_train(p, x, h)
    st = init_mlstm_state(b, d, h)
    outs = []
    for t in range(s):
        o, st = apply_mlstm_step(p, x[:, t:t + 1], st, h)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=2e-3,
                    atol=2e-3)


def test_mlstm_chunked_equals_unchunked():
    """The 32k memory fix (query-chunked decay form) is exact."""
    d, h, b = 32, 4, 1
    p = init_mlstm(jax.random.PRNGKey(0), d, h)
    # s > chunk and divisible -> chunked path; compare vs tiny-s direct path
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 2048, d))
    y_chunked = apply_mlstm_train(p, x, h)          # chunk=1024 -> scan path
    # stepwise oracle on a prefix
    st = init_mlstm_state(b, d, h)
    outs = []
    for t in range(64):
        o, st = apply_mlstm_step(p, x[:, t:t + 1], st, h)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    assert_allclose(np.asarray(y_chunked[:, :64]), np.asarray(y_seq),
                    rtol=2e-3, atol=2e-3)


def test_slstm_train_equals_stepwise():
    d, b, s = 24, 2, 10
    p = init_slstm(jax.random.PRNGKey(0), d)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    y_par = apply_slstm_train(p, x)
    st = init_slstm_state(b, d)
    outs = []
    for t in range(s):
        o, st = apply_slstm_step(p, x[:, t:t + 1], st)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=1e-4,
                    atol=1e-5)


@pytest.mark.parametrize("arch", ["xlstm-350m", "zamba2-2.7b"])
def test_full_model_prefix_decode_consistency(arch):
    """serve_step token-by-token must track forward_train teacher-forced
    logits for the recurrent families (exact state carry)."""
    from repro.configs.registry import get_smoke
    from repro.models.lm import (forward_train, init_lm, init_serve_cache,
                                 serve_step)
    cfg = get_smoke(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    hidden, _ = forward_train(params, tokens, cfg)
    logits_train = hidden @ params["head"]
    cache = init_serve_cache(cfg, b, 32)
    logits_steps = []
    for t in range(s):
        lg, cache = serve_step(params, tokens[:, t:t + 1], cache, cfg)
        logits_steps.append(lg)
    for t in range(s):
        assert_allclose(np.asarray(logits_steps[t]),
                        np.asarray(logits_train[:, t]), rtol=3e-3, atol=3e-3)
