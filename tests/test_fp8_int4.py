"""fp8 codeword + nibble-packed int4 assignment operand tiers (DESIGN.md
section 15): the float8_e4m3fn codeword quantizer and its round-trip error
bound, nibble pack/unpack/gather/scatter and the ``PackedAssignment``
pytree, uint4 emission from the VQ-update kernel (+ the per-dtype k-limit
guards), fp8/packed kernel parity against the dequantized oracles, the
5-tier precision ladder in kernels/ops.py, dtype-keyed autotuner entries
(no int8-vs-fp8 or uint8-vs-uint4 collisions), the shared ``dtype_nbits``
byte accounting, pack-aware state constructors, the fp8 bitcast payload of
``gather_from_shards``, and end-to-end init/train/infer smoke under the
fp8 and int8+a4 tiers.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.core.codebook import CodebookConfig
from repro.core.conv import (assignment_packed, init_layer_vq_state,
                             refresh_assignment)
from repro.distributed.quantization import (PackedAssignment, dtype_nbits,
                                            gather_nibbles, pack_nibbles,
                                            quantize_codewords,
                                            scatter_nibbles, tree_bytes,
                                            unpack_nibbles)
from repro.kernels import autotune, ops, ref
from repro.kernels.context_ell import context_ell_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.vq_update import vq_assign_update_pallas

FP8 = jnp.float8_e4m3fn


def _case(b, deg, n, nb, k, f_blk, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ids = jax.random.randint(k1, (b, deg), 0, n).astype(jnp.int32)
    val = jax.random.normal(k2, (b, deg), jnp.float32)
    assign = jax.random.randint(k3, (nb, n), 0, k).astype(jnp.uint8)
    cw = jax.random.normal(k4, (nb, k, f_blk), jnp.float32)
    return ids, val, assign, cw


# ---------------------------------------------------------------------------
# fp8 codeword quantizer
# ---------------------------------------------------------------------------

def test_fp8_quantize_roundtrip_error_bound():
    cw = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 8)) * 3.0
    qt = quantize_codewords(cw, dtype=FP8)
    assert qt.q.dtype == FP8
    assert qt.scale.shape == (4, 1, 8)
    deq = qt.q.astype(jnp.float32) * qt.scale
    # e4m3 keeps >= 3 mantissa bits over the normal range (relative error
    # <= 2^-4) and the subnormal lattice pitch is scale * 2^-9; together:
    bound = np.abs(np.asarray(cw)) / 16.0 \
        + np.asarray(qt.scale) * 2.0 ** -10 * 1.01
    err = np.abs(np.asarray(deq) - np.asarray(cw))
    assert (err <= bound).all()


def test_fp8_quantize_prev_pins_dtype():
    cw = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 4))
    prev = quantize_codewords(cw, dtype=FP8)
    # data-driven requantize (the jitted EMA-update path): dtype comes from
    # the previous snapshot, not from the dtype arg
    nxt = quantize_codewords(cw * 1.01, prev=prev)
    assert nxt.q.dtype == FP8
    nxt8 = quantize_codewords(cw * 1.01, prev=quantize_codewords(cw))
    assert nxt8.q.dtype == jnp.int8


def test_quantize_codewords_rejects_unknown_dtype():
    cw = jnp.zeros((1, 4, 4))
    with pytest.raises((ValueError, KeyError)):
        quantize_codewords(cw, dtype=jnp.float16)


# ---------------------------------------------------------------------------
# nibble packing: pack/unpack identity, gather, scatter
# ---------------------------------------------------------------------------

def test_pack_unpack_identity_all_ids_and_odd_tail():
    # every id 0..15, even and odd lengths (the odd tail pads a 0 nibble)
    for n in (16, 17, 1, 2, 31):
        ids = jnp.arange(n, dtype=jnp.uint8) % 16
        packed = pack_nibbles(ids[None])
        assert packed.dtype == jnp.uint8
        assert packed.shape == (1, (n + 1) // 2)
        out = unpack_nibbles(packed, n)
        assert np.array_equal(np.asarray(out[0]), np.asarray(ids))


def test_gather_scatter_nibbles_match_dense():
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.integers(0, 16, (3, 33)), dtype=jnp.uint8)
    packed = pack_nibbles(dense)
    ids = jnp.asarray([0, 32, 7, 8, 31])          # distinct, mixed parity
    got = gather_nibbles(packed, ids)
    assert np.array_equal(np.asarray(got), np.asarray(dense[:, ids]))
    vals = jnp.asarray(rng.integers(0, 16, (3, 5)), dtype=jnp.uint8)
    upd = scatter_nibbles(packed, ids, vals)
    want = dense.at[:, ids].set(vals)
    assert np.array_equal(np.asarray(unpack_nibbles(upd, 33)),
                          np.asarray(want))


def test_packed_assignment_pytree_roundtrip():
    dense = jnp.asarray([[1, 15, 0, 7, 9]], dtype=jnp.uint8)
    pa = PackedAssignment.pack(dense)
    assert pa.shape == (1, 5)
    assert np.array_equal(np.asarray(pa.unpack()), np.asarray(dense))
    # registered pytree: survives jit boundaries with static n
    out = jax.jit(lambda p: p.unpack())(pa)
    assert np.array_equal(np.asarray(out), np.asarray(dense))
    # exact sub-byte accounting: ceil(5/2) bytes per branch
    assert tree_bytes((pa,)) == 3


def test_dtype_nbits_sub_byte_and_hlo_names():
    assert dtype_nbits(jnp.uint4) == 4
    assert dtype_nbits(jnp.int4) == 4
    assert dtype_nbits(jnp.uint8) == 8
    assert dtype_nbits(FP8) == 8
    assert dtype_nbits(jnp.float32) == 32
    assert dtype_nbits("f8e4m3fn") == 8     # HLO short names (dryrun)
    assert dtype_nbits("u4") == 4
    assert dtype_nbits("pred") == 8


# ---------------------------------------------------------------------------
# uint4 emission from the VQ-update kernel + the per-dtype k-limit guards
# ---------------------------------------------------------------------------

def test_vq_update_emit_uint4_matches_int32():
    x = jax.random.normal(jax.random.PRNGKey(2), (100, 8))
    cw = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
    i32, q32, c32, s32 = vq_assign_update_pallas(x, cw, interpret=True)
    i4, q4, c4, s4 = vq_assign_update_pallas(x, cw, interpret=True,
                                             emit_dtype=jnp.uint4)
    assert i4.dtype == jnp.uint4
    assert np.array_equal(np.asarray(i32), np.asarray(i4).astype(np.int32))
    assert_allclose(np.asarray(q32), np.asarray(q4))
    assert np.array_equal(np.asarray(c32), np.asarray(c4))


def test_vq_update_emit_uint4_needs_k16():
    x = jnp.zeros((8, 4))
    cw = jnp.zeros((32, 4))
    with pytest.raises(ValueError, match="uint4.*k <= 16"):
        vq_assign_update_pallas(x, cw, interpret=True, emit_dtype=jnp.uint4)


def test_vq_update_emit_uint8_needs_k256():
    x = jnp.zeros((8, 4))
    cw = jnp.zeros((300, 4))
    with pytest.raises(ValueError, match="uint8.*k <= 256"):
        vq_assign_update_pallas(x, cw, interpret=True, emit_dtype=jnp.uint8)


def test_vq_update_emit_rejects_unsupported_dtype_naming_it():
    x = jnp.zeros((8, 4))
    cw = jnp.zeros((8, 4))
    with pytest.raises(ValueError, match="int16"):
        vq_assign_update_pallas(x, cw, interpret=True, emit_dtype=jnp.int16)
    # int32 is the documented always-valid fallback
    i, _, _, _ = vq_assign_update_pallas(x, cw, interpret=True,
                                         emit_dtype=jnp.int32)
    assert i.dtype == jnp.int32


# ---------------------------------------------------------------------------
# kernel parity: fp8 codewords, packed assignment tables (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_wt", [False, True])
def test_context_ell_fp8_packed_parity(with_wt):
    ids, val, assign, cw = _case(128, 8, 999, 4, 16, 8)   # odd n: padded tail
    qt = quantize_codewords(cw, dtype=FP8)
    deq = qt.q.astype(jnp.float32) * qt.scale
    pa = PackedAssignment.pack(assign)
    w_t = jax.random.normal(jax.random.PRNGKey(9), (4 * 8, 24)) \
        if with_wt else None
    got = context_ell_pallas(ids, val, pa, qt.q, cw_scale=qt.scale,
                             w_t=w_t, interpret=True)
    want = ref.context_ell(ids, val, assign, deq, w_t)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_context_ell_packed_int8_parity():
    ids, val, assign, cw = _case(64, 4, 200, 2, 16, 8, seed=1)
    qt = quantize_codewords(cw)
    deq = qt.q.astype(jnp.float32) * qt.scale
    pa = PackedAssignment.pack(assign)
    got = context_ell_pallas(ids, val, pa, qt.q, cw_scale=qt.scale,
                             interpret=True)
    want = ref.context_ell(ids, val, assign, deq)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ref_context_ell_unpacks_packed():
    ids, val, assign, cw = _case(32, 4, 100, 2, 16, 8, seed=2)
    pa = PackedAssignment.pack(assign)
    a = ref.context_ell(ids, val, pa, cw)
    b = ref.context_ell(ids, val, assign, cw)
    assert_allclose(np.asarray(a), np.asarray(b))


def test_spmm_ell_fp8_parity():
    from repro.distributed.quantization import quantize_tensor
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    ids = jax.random.randint(k1, (64, 8), 0, 100).astype(jnp.int32)
    val = jax.random.normal(k2, (64, 8))
    x = jax.random.normal(k3, (100, 16))
    qt = quantize_tensor(x, dtype=FP8)
    assert qt.q.dtype == FP8
    deq = qt.q.astype(jnp.float32) * qt.scale
    got = spmm_ell_pallas(ids, val, qt.q, x_scale=qt.scale, interpret=True)
    want = ref.spmm_ell(ids, val, deq)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# precision ladder + dispatch
# ---------------------------------------------------------------------------

def test_precision_ladder_helpers():
    assert ops.PRECISIONS == ("fp32", "int8", "fp8", "int8+a4", "fp8+a4")
    assert ops.precision_codeword_dtype("fp32") is None
    assert ops.precision_codeword_dtype("int8") == jnp.dtype(jnp.int8)
    assert ops.precision_codeword_dtype("fp8") == jnp.dtype(FP8)
    assert ops.precision_codeword_dtype("fp8+a4") == jnp.dtype(FP8)
    assert not ops.precision_packs_assignment("fp8")
    assert ops.precision_packs_assignment("int8+a4")
    assert ops.precision_packs_assignment("fp8+a4")


def test_configure_rejects_unknown_precision_listing_tiers():
    with pytest.raises(ValueError) as ei:
        ops.configure_kernel_precision("int4")
    msg = str(ei.value)
    for tier in ops.PRECISIONS:
        assert tier in msg
    assert ops.kernel_precision() in ops.PRECISIONS   # state unchanged


def test_kernel_precision_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_PRECISION", "fp8+a4")
    assert ops.kernel_precision() == "fp8+a4"
    monkeypatch.setenv("REPRO_KERNEL_PRECISION", "nope")
    with pytest.raises(ValueError, match="fp8\\+a4"):
        ops.kernel_precision()


def test_context_dispatch_packed_halves_table_budget():
    # fractional itemsize: the packed table crosses to 'loop' at 2x the
    # node count of the uint8 table under the same budget
    ops.configure_context_dispatch(reset=True, vmem_budget_mb=0.5)
    try:
        n8 = 0.5 * 2 ** 20 / 4          # uint8 threshold at nb=4
        assert ops.context_ell_variant(int(n8), 4, 1,
                                       dtype=jnp.uint8) == "fused"
        assert ops.context_ell_variant(int(n8) + 1, 4, 1,
                                       dtype=jnp.uint8) == "loop"
        assert ops.context_ell_variant(int(2 * n8), 4, 0.5,
                                       dtype=jnp.uint4) == "fused"
        assert ops.context_ell_variant(int(2 * n8) + 1, 4, 0.5,
                                       dtype=jnp.uint4) == "loop"
    finally:
        ops.configure_context_dispatch(reset=True)


def test_autotune_keys_no_tier_collisions(tmp_path, monkeypatch):
    # int8 vs fp8 spmm sources and uint8 vs uint4 context tables share an
    # itemsize (or half of one) but are distinct operand regimes: their
    # cache entries must never collide (REPRO_AUTOTUNE=1 + fp8+a4 vs int8)
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear()
    try:
        keys = {autotune.cache_key("spmm", (1000, 16, 1), jnp.int8),
                autotune.cache_key("spmm", (1000, 16, 1), FP8),
                autotune.cache_key("context", (1000, 4), jnp.uint8),
                autotune.cache_key("context", (1000, 4), jnp.uint4)}
        assert len(keys) == 4
        cfg8 = autotune.tuned_context(1000, 2, 1, dtype=jnp.uint8)
        cfg4 = autotune.tuned_context(1000, 2, 0.5, dtype=jnp.uint4)
        assert cfg8 is not None and cfg4 is not None
        k8 = autotune.cache_key("context", (1000, 2), jnp.uint8)
        k4 = autotune.cache_key("context", (1000, 2), jnp.uint4)
        assert autotune.lookup(k8) == cfg8
        assert autotune.lookup(k4) == cfg4
    finally:
        autotune.clear()


# ---------------------------------------------------------------------------
# pack-aware state constructors
# ---------------------------------------------------------------------------

def test_init_layer_vq_state_fp8_a4(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_PRECISION", "fp8+a4")
    cfg = CodebookConfig(k=16, f_prod=8)
    assert assignment_packed(cfg)
    st = init_layer_vq_state(jax.random.PRNGKey(0), 101, 16, 16, cfg)
    assert isinstance(st.assignment, PackedAssignment)
    assert st.assignment.shape[1] == 101
    assert st.qcw is not None and st.qcw.feat.q.dtype == FP8
    # k > 16 falls back to the uint8 table under the same tier
    cfg_big = CodebookConfig(k=32, f_prod=8)
    assert not assignment_packed(cfg_big)
    st_big = init_layer_vq_state(jax.random.PRNGKey(0), 50, 16, 16, cfg_big)
    assert st_big.assignment.dtype == jnp.uint8


def test_refresh_assignment_packed_matches_dense(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_PRECISION", "int8+a4")
    cfg = CodebookConfig(k=16, f_prod=8)
    st = init_layer_vq_state(jax.random.PRNGKey(0), 64, 16, 16, cfg)
    nb = st.assignment.shape[0]
    batch_ids = jnp.asarray([3, 7, 0, 20, 63, 11])       # distinct ids
    new = jnp.tile(jnp.asarray([[1, 2, 3, 4, 5, 15]], dtype=jnp.uint8),
                   (nb, 1))
    st2 = refresh_assignment(st, batch_ids, new)
    dense = st.assignment.unpack().at[:, batch_ids].set(new)
    assert np.array_equal(np.asarray(st2.assignment.unpack()),
                          np.asarray(dense))


def test_quantize_vq_states_tiers_and_guards():
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import (GNNConfig, init_vq_states,
                                  quantize_vq_states)
    g = synthetic_arxiv(n=100, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=1,
                    codebook=CodebookConfig(k=16, f_prod=4))
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    vq_f8a4 = quantize_vq_states(vq, cfg, precision="fp8+a4")
    assert isinstance(vq_f8a4[0].assignment, PackedAssignment)
    assert vq_f8a4[0].qcw.feat.q.dtype == FP8
    # tier switch rebuilds the snapshot in the new dtype and unpacks
    vq_i8 = quantize_vq_states(vq_f8a4, cfg, precision="int8")
    assert vq_i8[0].assignment.dtype == jnp.uint8
    assert vq_i8[0].qcw.feat.q.dtype == jnp.int8
    # +a4 guard names the usable fallback tier
    cfg_big = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                        n_out=g.num_classes, n_layers=1,
                        codebook=CodebookConfig(k=32, f_prod=4))
    vq_big = init_vq_states(jax.random.PRNGKey(1), cfg_big, g.n)
    with pytest.raises(ValueError, match="k <= 16"):
        quantize_vq_states(vq_big, cfg_big, precision="fp8+a4")


# ---------------------------------------------------------------------------
# fp8 shard gather payload
# ---------------------------------------------------------------------------

def test_gather_from_shards_fp8_bit_exact():
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.collectives import gather_from_shards

    ndev = jax.local_device_count()
    mesh = jax.make_mesh((ndev,), ("shard",))
    n_local, f = 8, 5
    table = jax.random.normal(
        jax.random.PRNGKey(0), (ndev * n_local, f)).astype(FP8)
    ids = jax.random.randint(jax.random.PRNGKey(1), (ndev, 6), 0,
                             ndev * n_local)
    run = shard_map(
        lambda tab, i: gather_from_shards(tab, i.reshape(-1), "shard"),
        mesh=mesh, in_specs=(P("shard"), P("shard")), out_specs=P("shard"))
    out = run(table, ids)
    assert out.dtype == FP8
    want = np.asarray(table)[np.asarray(ids).reshape(-1)]
    assert np.array_equal(np.asarray(out).view(np.uint8),
                          want.view(np.uint8))


# ---------------------------------------------------------------------------
# end-to-end smoke under the new tiers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier", ["fp8", "int8+a4"])
def test_tier_inference_agreement(tier, monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_PRECISION", raising=False)
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import (GNNConfig, init_gnn, init_vq_states,
                                  quantize_vq_states)
    from repro.train.gnn_trainer import vq_inference
    g = synthetic_arxiv(n=300, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    y32 = vq_inference(params, vq, g, cfg, batch_size=100)
    yq = vq_inference(params, quantize_vq_states(vq, cfg, precision=tier),
                      g, cfg, batch_size=100)
    agree = float((np.argmax(np.asarray(y32), -1) ==
                   np.argmax(np.asarray(yq), -1)).mean())
    assert agree >= 0.95


@pytest.mark.parametrize("tier", ["fp8", "fp8+a4"])
def test_tier_training_smoke(tier):
    import os
    if os.environ.get("REPRO_FORCE_PALLAS", "0") == "1":
        pytest.skip("training grads cannot trace through the intra-term "
                    "SpMM pallas_call (test_int8.py convention)")
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import GNNConfig
    from repro.train.gnn_trainer import train_vq
    g = synthetic_arxiv(n=300, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    ops.configure_kernel_precision(tier)
    try:
        r = train_vq(g, cfg, epochs=2, batch_size=100, eval_every=100)
    finally:
        ops.configure_kernel_precision(reset=True)
    st = r["vq_states"][0]
    if tier.endswith("+a4"):
        assert isinstance(st.assignment, PackedAssignment)
    assert st.qcw is not None and st.qcw.feat.q.dtype == FP8
    assert np.isfinite(r["final"]["val"])
