"""Fused VQ-context kernel family (kernels/context_ell.py) + the lazy
Eq. 7 backward (core/message_passing.py): kernel-vs-oracle parity over the
edge shapes, the ops.py fused/loop dispatch heuristic + configure/reset
hooks, the one-kernel-dispatch contract of context_messages_reconstruct,
the lazy-residual contract of inject_context_grad, and gradient parity of
approx_message_passing's cotangent against dense autodiff through the full
convolution matrix on a tiny graph.

Gradient tests skip under REPRO_FORCE_PALLAS=1: reverse-mode AD cannot
trace through the intra-term SpMM pallas_call (no transpose rule).  The
streaming Eq. 7 backward itself never differentiates through a kernel --
the custom-VJP backward *invokes* the context kernel forward -- and is
covered under FORCE_PALLAS by the w_t-epilogue parity sweep here plus the
dispatch tests.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.core.message_passing import (ConvOperands, approx_message_passing,
                                        context_messages_reconstruct,
                                        inject_context_grad_materialized,
                                        intra_messages, reconstruct)
from repro.kernels import ops, ref
from repro.kernels.context_ell import context_ell_pallas

_FORCED_PALLAS = os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"
needs_autodiff = pytest.mark.skipif(
    _FORCED_PALLAS, reason="no reverse-mode AD through the intra-term "
    "pallas_call; Eq. 7's own kernel is parity-covered under FORCE_PALLAS")


def _case(b, deg, n, nb, k, f_blk, seed=None, cw_dtype=jnp.float32):
    key = jax.random.PRNGKey(seed if seed is not None
                             else b * 131 + deg * 7 + nb)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ids = jax.random.randint(k1, (b, deg), 0, n).astype(jnp.int32)
    val = jax.random.normal(k2, (b, deg), jnp.float32)
    assign = jax.random.randint(k3, (nb, n), 0, k).astype(jnp.int32)
    cw = jax.random.normal(k4, (nb, k, f_blk), cw_dtype)
    return ids, val, assign, cw


def _legacy_loop(out_ids, out_vals, assignment, codewords):
    """The pre-fusion context path: per-branch gather + SpMM + concat."""
    branch_ids = assignment[:, out_ids]                    # [nb, b, D]
    per_branch = [ref.spmm_ell(branch_ids[i], out_vals, codewords[i])
                  for i in range(codewords.shape[0])]
    return jnp.concatenate(per_branch, axis=-1)


# ---------------------------------------------------------------------------
# kernel parity: fused kernel vs oracle vs legacy per-branch loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,deg,n,nb,k,f_blk", [
    (1, 1, 1, 1, 1, 1),        # degenerate minimum
    (8, 4, 16, 2, 4, 8),       # everything below one tile
    (33, 7, 50, 4, 16, 8),     # b a non-multiple of bb, nb=4
    (128, 32, 300, 2, 64, 16), # multi-tile
    (5, 0, 10, 4, 8, 8),       # D=0 column padding (no out-of-batch slots)
    (257, 5, 999, 1, 256, 8),  # single branch, paper-scale k
])
@pytest.mark.parametrize("cw_dtype", [jnp.float32, jnp.bfloat16])
def test_context_ell_sweep(b, deg, n, nb, k, f_blk, cw_dtype):
    ids, val, assign, cw = _case(b, deg, n, nb, k, f_blk, cw_dtype=cw_dtype)
    got = context_ell_pallas(ids, val, assign, cw, interpret=True)
    want = ref.context_ell(ids, val, assign, cw)
    tol = dict(rtol=2e-2, atol=1e-2) if cw_dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)
    assert got.shape == (b, nb * f_blk)
    assert_allclose(np.asarray(got), np.asarray(want), **tol)
    if deg > 0 and cw_dtype == jnp.float32:
        legacy = _legacy_loop(ids, val, assign, cw)
        assert_allclose(np.asarray(want), np.asarray(legacy),
                        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,deg,n,nb,k,f_blk,f_out", [
    (33, 7, 50, 4, 16, 8, 12),
    (64, 5, 200, 2, 32, 8, 8),
    (6, 0, 10, 2, 8, 4, 5),    # D=0 with epilogue
])
def test_context_ell_wt_epilogue(b, deg, n, nb, k, f_blk, f_out):
    """The fused ``@ W^T`` epilogue (the streaming Eq. 7 backward form)."""
    ids, val, assign, cw = _case(b, deg, n, nb, k, f_blk)
    w_t = jax.random.normal(jax.random.PRNGKey(f_out), (nb * f_blk, f_out))
    got = context_ell_pallas(ids, val, assign, cw, w_t=w_t, interpret=True)
    want = ref.context_ell(ids, val, assign, cw, w_t)
    assert got.shape == (b, f_out)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_context_ell_all_out_of_batch_rows():
    """Rows whose every slot is a real out-of-batch edge (no zero padding)."""
    ids, val, assign, cw = _case(40, 6, 100, 4, 16, 8)
    val = jnp.abs(val) + 0.5                     # all slots carry real edges
    got = context_ell_pallas(ids, val, assign, cw, interpret=True)
    want = ref.context_ell(ids, val, assign, cw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_context_ell_padding_zero_vals():
    """Padding slots carry val == 0; their ids may point anywhere valid."""
    ids, val, assign, cw = _case(24, 5, 60, 2, 8, 8)
    val = val.at[3].set(0.0).at[17].set(0.0)
    got = context_ell_pallas(ids, val, assign, cw, interpret=True)
    want = ref.context_ell(ids, val, assign, cw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(got)[3] == 0) and np.all(np.asarray(got)[17] == 0)


@pytest.mark.parametrize("bb", [8, 32, 100])   # incl. non-pow2, b % bb != 0
def test_context_ell_tile_sizes(bb):
    ids, val, assign, cw = _case(53, 6, 210, 4, 16, 8)
    got = context_ell_pallas(ids, val, assign, cw, bb=bb, interpret=True)
    want = ref.context_ell(ids, val, assign, cw)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ops.py dispatch: heuristic, env/configure overrides, reset
# ---------------------------------------------------------------------------

def test_context_variant_heuristic(monkeypatch):
    monkeypatch.delenv("REPRO_CONTEXT_VARIANT", raising=False)
    monkeypatch.setenv("REPRO_CONTEXT_VMEM_BUDGET_MB", "4")
    assert ops.context_ell_variant(100_000, 4) == "fused"   # 1.6 MiB table
    assert ops.context_ell_variant(2_000_000, 4) == "loop"  # 32 MiB table
    monkeypatch.setenv("REPRO_CONTEXT_VARIANT", "loop")
    assert ops.context_ell_variant(8, 1) == "loop"
    monkeypatch.setenv("REPRO_CONTEXT_VARIANT", "fused")
    assert ops.context_ell_variant(2_000_000, 4) == "fused"
    monkeypatch.setenv("REPRO_CONTEXT_VARIANT", "nope")
    with pytest.raises(ValueError):
        ops.context_ell_variant(8, 1)


def test_context_configure_and_reset(monkeypatch):
    monkeypatch.delenv("REPRO_CONTEXT_VARIANT", raising=False)
    monkeypatch.delenv("REPRO_CONTEXT_VMEM_BUDGET_MB", raising=False)
    try:
        ops.configure_context_dispatch(variant="loop")
        assert ops.context_ell_variant(8, 1) == "loop"
        ops.configure_context_dispatch(variant="auto", vmem_budget_mb=0.001)
        assert ops.context_ell_variant(10_000, 4) == "loop"
        with pytest.raises(ValueError):
            ops.configure_context_dispatch(variant="nope")
        # reset clears every programmatic override -> back to defaults
        ops.configure_context_dispatch(reset=True)
        assert not ops._context_overrides
        assert ops.context_ell_variant(10_000, 4) == "fused"
        # reset composes with setting new values in the same call
        ops.configure_context_dispatch(variant="loop", reset=True)
        assert ops._context_overrides == {"variant": "loop"}
    finally:
        ops._context_overrides.clear()


def test_ops_dispatch_fused_and_loop(monkeypatch):
    """Forced-pallas: both dispatch variants match the oracle."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    ids, val, assign, cw = _case(30, 6, 80, 4, 16, 8)
    w_t = jax.random.normal(jax.random.PRNGKey(5), (4 * 8, 10))
    want = ref.context_ell(ids, val, assign, cw)
    want_w = ref.context_ell(ids, val, assign, cw, w_t)
    try:
        for variant in ("fused", "loop"):
            ops.configure_context_dispatch(variant=variant, reset=True)
            got = ops.context_ell(ids, val, assign, cw)
            got_w = ops.context_ell(ids, val, assign, cw, w_t)
            assert_allclose(np.asarray(got), np.asarray(want),
                            rtol=1e-5, atol=1e-5)
            assert_allclose(np.asarray(got_w), np.asarray(want_w),
                            rtol=1e-4, atol=1e-4)
    finally:
        ops._context_overrides.clear()


# ---------------------------------------------------------------------------
# the tentpole contracts: one kernel dispatch; lazy Eq. 7 residuals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb", [1, 2, 4])
def test_context_messages_single_dispatch(monkeypatch, nb):
    """context_messages_reconstruct issues exactly ONE kernel dispatch
    regardless of n_branches (the pre-fusion path issued nb of them)."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.delenv("REPRO_CONTEXT_VARIANT", raising=False)
    ids, val, assign, cw = _case(16, 5, 40, nb, 8, 8)
    jaxpr = jax.make_jaxpr(
        lambda v, i, c, a: context_messages_reconstruct(v, i, c, a))(
            val, ids, cw, assign)
    assert str(jaxpr).count("pallas_call") == 1


def _tiny_operands(seed=0, b=6, deg=4, dr=3, n=15, nb=2, k=8,
                   f_in=8, f_grad=6):
    """Random tiny-graph ConvOperands + VQ state (dr != deg on purpose so
    residual-shape assertions cannot alias the intra-term gather)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 12)
    in_pos = jax.random.randint(ks[0], (b, deg), -1, b).astype(jnp.int32)
    in_vals = jnp.where(in_pos >= 0, jax.random.normal(ks[1], (b, deg)), 0.0)
    out_ids = jax.random.randint(ks[2], (b, deg), 0, n).astype(jnp.int32)
    out_vals = jnp.where(in_pos < 0, jax.random.normal(ks[3], (b, deg)), 0.0)
    rev_ids = jax.random.randint(ks[4], (b, dr), 0, n).astype(jnp.int32)
    rev_vals = jax.random.normal(ks[5], (b, dr))
    fcw = jax.random.normal(ks[6], (nb, k, f_in // nb))
    gcw = jax.random.normal(ks[7], (nb, k, f_grad // nb))
    assign = jax.random.randint(ks[8], (nb, n), 0, k).astype(jnp.int32)
    x_b = jax.random.normal(ks[9], (b, f_in))
    w = jax.random.normal(ks[10], (f_in, f_grad))
    cot = jax.random.normal(ks[11], (b, f_in))
    ops_ = ConvOperands(in_pos, in_vals, out_ids, out_vals,
                        rev_ids, rev_vals)
    return ops_, x_b, fcw, gcw, assign, w, cot


@needs_autodiff
def test_inject_residuals_lazy():
    """inject_context_grad stores NO [b, Dr, f_grad] reconstruction: the
    vjp residuals are the O(b*Dr) edge operands + the O(k*f) codebook."""
    b, dr, f_grad = 6, 3, 6
    ops_, x_b, fcw, gcw, assign, w, _ = _tiny_operands(
        b=b, dr=dr, f_grad=f_grad)
    _, vjp_fn = jax.vjp(
        lambda x: approx_message_passing(ops_, x, fcw, gcw, assign, w), x_b)
    leaves = jax.tree_util.tree_leaves(vjp_fn)
    shapes = [tuple(l.shape) for l in leaves]
    assert (b, dr, f_grad) not in shapes          # the materialized tensor
    assert not any(l.ndim == 3 and l.shape[:2] == (b, dr) for l in leaves)
    # positive check: the codebook table IS the residual
    assert gcw.shape in shapes


@needs_autodiff
@pytest.mark.parametrize("with_w", [False, True])
def test_eq7_gradient_parity_dense(with_w):
    """approx_message_passing's cotangent (streaming fused backward) ==
    dense autodiff through the full convolution matrix + the dense Eq. 7
    phantom term, on a tiny graph."""
    b, deg, dr, n, nb, k, f_in = 6, 4, 3, 15, 2, 8, 8
    f_grad = f_in if not with_w else 6
    ops_, x_b, fcw, gcw, assign, w, cot = _tiny_operands(
        b=b, deg=deg, dr=dr, n=n, nb=nb, k=k, f_in=f_in, f_grad=f_grad)
    w = w if with_w else None

    got = jax.grad(lambda x: jnp.sum(
        approx_message_passing(ops_, x, fcw, gcw, assign, w) * cot))(x_b)

    # dense C_in [b, b] and its exact autodiff cotangent C_in^T cot
    c_in = np.zeros((b, b), np.float32)
    in_pos, in_vals = np.asarray(ops_.in_pos), np.asarray(ops_.in_vals)
    for i in range(b):
        for d in range(deg):
            if in_pos[i, d] >= 0:
                c_in[i, in_pos[i, d]] += in_vals[i, d]
    dense_intra = jax.grad(lambda x: jnp.sum(
        (jnp.asarray(c_in) @ x) * cot))(x_b)

    # dense Eq. 7 phantom:  Crev @ Ghat_full (@ W^T), Ghat_full = R G~
    ghat_full = np.asarray(reconstruct(gcw, assign, jnp.arange(n)))  # [n, fg]
    c_rev = np.zeros((b, n), np.float32)
    rev_ids, rev_vals = np.asarray(ops_.rev_ids), np.asarray(ops_.rev_vals)
    for i in range(b):
        for d in range(dr):
            c_rev[i, rev_ids[i, d]] += rev_vals[i, d]
    phantom = c_rev @ ghat_full
    if w is not None:
        phantom = phantom @ np.asarray(w).T

    assert_allclose(np.asarray(got), np.asarray(dense_intra) + phantom,
                    rtol=1e-4, atol=1e-4)


@needs_autodiff
@pytest.mark.parametrize("with_w", [False, True])
def test_eq7_streaming_matches_materialized(with_w):
    """The lazy streaming backward == the pre-PR materialized injection."""
    f_grad = 8 if not with_w else 6
    ops_, x_b, fcw, gcw, assign, w, cot = _tiny_operands(f_grad=f_grad)
    w = w if with_w else None

    def legacy(x):
        grad_hat = jax.lax.stop_gradient(
            reconstruct(gcw, assign, ops_.rev_ids))
        xi = inject_context_grad_materialized(x, ops_.rev_vals, grad_hat, w)
        m = intra_messages(ops_.in_pos, ops_.in_vals, xi, ops_.stripe_index)
        return m + context_messages_reconstruct(
            ops_.out_vals, ops_.out_ids, fcw, assign)

    g_new = jax.grad(lambda x: jnp.sum(
        approx_message_passing(ops_, x, fcw, gcw, assign, w) * cot))(x_b)
    g_old = jax.grad(lambda x: jnp.sum(legacy(x) * cot))(x_b)
    assert_allclose(np.asarray(g_new), np.asarray(g_old),
                    rtol=1e-5, atol=1e-5)


@needs_autodiff
def test_eq7_inject_off_is_plain_autodiff():
    """inject=False: the cotangent is exactly the dense C_in^T term."""
    ops_, x_b, fcw, gcw, assign, w, cot = _tiny_operands()
    got = jax.grad(lambda x: jnp.sum(approx_message_passing(
        ops_, x, fcw, gcw, assign, None, inject=False) * cot))(x_b)
    want = jax.grad(lambda x: jnp.sum(
        intra_messages(ops_.in_pos, ops_.in_vals, x) * cot))(x_b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
