import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _fresh_env_knobs():
    """Drop the hostenv knob snapshot between tests.

    ``repro.hostenv`` freezes REPRO_* env knobs at their last host-side
    value while a jax trace is active (the env-read-once contract); a
    monkeypatched knob from one test must not leak into the next test's
    traces, so every test starts from a clean snapshot (the first read
    then sees the live -- possibly monkeypatched -- environment).
    """
    from repro import hostenv
    hostenv.reset_env_snapshot()
    yield
