"""Block-size/dispatch autotuner (kernels/autotune.py): cache keying and
persistence, the opt-in gate (disabled -> None everywhere), tuner
round-trips producing valid configs that hit the cache on re-query, and
the ops.py dispatch precedence -- forced variant > explicitly configured
VMEM budget > autotuner measurement > size heuristic.
"""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.kernels import autotune, ops, ref


@pytest.fixture
def tuner_cache(tmp_path, monkeypatch):
    """Route the cache to a temp file, enable tuning, reset state."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear()
    yield path
    autotune.clear()


# ---------------------------------------------------------------------------
# cache machinery
# ---------------------------------------------------------------------------

def test_shape_bucket():
    assert autotune.shape_bucket(0) == 0
    assert autotune.shape_bucket(1) == 1
    assert autotune.shape_bucket(100) == 128
    assert autotune.shape_bucket(128) == 128
    assert autotune.shape_bucket(129) == 256


def test_cache_key_buckets_and_backend():
    k = autotune.cache_key("spmm", (100, 16, 4), jnp.float32)
    assert k == f"spmm|128x16x4|float32|{jax.default_backend()}"
    # nearby shapes share a key; different dtypes do not
    assert autotune.cache_key("spmm", (65, 16, 4), jnp.float32) == k
    assert autotune.cache_key("spmm", (100, 16, 4), jnp.int8) != k


def test_record_lookup_roundtrip(tuner_cache):
    autotune.record("k1", {"variant": "fused", "bb": 64})
    assert autotune.lookup("k1") == {"variant": "fused", "bb": 64}
    assert autotune.lookup("nope") is None
    # persisted: a fresh in-memory cache reloads from the file
    autotune.clear(memory_only=True)
    assert autotune.lookup("k1") == {"variant": "fused", "bb": 64}
    on_disk = json.loads(tuner_cache.read_text())
    assert on_disk["k1"]["bb"] == 64


def test_corrupt_cache_file_is_ignored(tuner_cache):
    tuner_cache.write_text("{not json")
    autotune.clear(memory_only=True)
    assert autotune.lookup("anything") is None
    autotune.record("k", {"bb": 128})     # recovers by rewriting
    autotune.clear(memory_only=True)
    assert autotune.lookup("k") == {"bb": 128}


# ---------------------------------------------------------------------------
# opt-in gate
# ---------------------------------------------------------------------------

def test_disabled_returns_none(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert not autotune.enabled()
    assert autotune.tuned_spmm(1000, 16) is None
    assert autotune.tuned_context(1000, 4) is None
    assert autotune.tuned_vq_update(256, 64, 8) is None


# ---------------------------------------------------------------------------
# tuner round-trips (measure once, then cache hits)
# ---------------------------------------------------------------------------

def test_tuned_spmm_measures_and_caches(tuner_cache):
    cfg = autotune.tuned_spmm(500, 16)
    assert cfg["variant"] in ("resident", "hbm")
    assert cfg["bb"] in (64, 128, 256)
    # second query must be a pure cache hit: break measurement to prove it
    def boom(*a, **k):
        raise AssertionError("re-measured a cached key")
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(autotune, "_time", boom)
        assert autotune.tuned_spmm(500, 16) == cfg
        # same bucket (next pow2 of 500 == of 512) -> still a hit
        assert autotune.tuned_spmm(512, 16) == cfg


def test_tuned_context_and_vq_update(tuner_cache):
    ctx = autotune.tuned_context(2000, 4)
    assert ctx["variant"] in ("fused", "loop")
    vq = autotune.tuned_vq_update(256, 64, 8)
    assert vq["bb"] in (128, 256) and vq["kb"] in (256, 512)
    # uint8 and int32 assignment tables tune independently
    ctx8 = autotune.tuned_context(2000, 4, itemsize=1)
    assert ctx8["variant"] in ("fused", "loop")
    keys = set(json.loads(tuner_cache.read_text()))
    assert len([k for k in keys if k.startswith("context|")]) == 2


# ---------------------------------------------------------------------------
# dispatch precedence in ops.py
# ---------------------------------------------------------------------------

def test_dispatch_prefers_tuned_variant(tuner_cache, monkeypatch):
    # seed the cache with a deliberately contrarian winner: the heuristic
    # at the default budget would say "resident" for this tiny shape
    key = autotune.cache_key("spmm", (512, 16, 4), jnp.float32)
    autotune.record(key, {"variant": "hbm", "bb": 128})
    ops.configure_spmm_dispatch(reset=True)
    assert ops.spmm_ell_variant(512, 16) == "hbm"
    # ... but a forced variant out-ranks the tuner
    ops.configure_spmm_dispatch(variant="resident")
    try:
        assert ops.spmm_ell_variant(512, 16) == "resident"
    finally:
        ops.configure_spmm_dispatch(reset=True)
    # ... and an explicitly configured budget also silences the tuner
    ops.configure_spmm_dispatch(vmem_budget_mb=64.0)
    try:
        assert ops.spmm_ell_variant(512, 16) == "resident"
    finally:
        ops.configure_spmm_dispatch(reset=True)


def test_context_dispatch_budget_silences_tuner(tuner_cache):
    key = autotune.cache_key("context", (4096, 4), jnp.int32)
    autotune.record(key, {"variant": "loop", "bb": 64})
    ops.configure_context_dispatch(reset=True)
    try:
        assert ops.context_ell_variant(4096, 4) == "loop"
        ops.configure_context_dispatch(vmem_budget_mb=64.0)
        assert ops.context_ell_variant(4096, 4) == "fused"
    finally:
        ops.configure_context_dispatch(reset=True)


def test_env_budget_silences_tuner(tuner_cache, monkeypatch):
    key = autotune.cache_key("spmm", (512, 16, 4), jnp.float32)
    autotune.record(key, {"variant": "hbm", "bb": 128})
    monkeypatch.setenv("REPRO_SPMM_VMEM_BUDGET_MB", "64")
    ops.configure_spmm_dispatch(reset=True)
    assert ops.spmm_ell_variant(512, 16) == "resident"


def test_tuned_spmm_includes_stripe(tuner_cache):
    """The spmm tuner races HBM stripe sizes under the same cache entry
    and always records one (the resident variant carries the default)."""
    cfg = autotune.tuned_spmm(500, 16)
    assert cfg["stripe"] in (256, 512, 1024)
    autotune.clear(memory_only=True)
    assert autotune.tuned_spmm(500, 16)["stripe"] == cfg["stripe"]


def test_tuned_stripe_flows_into_hbm_call(tuner_cache, monkeypatch):
    """A tuned stripe reaches the HBM kernel through ops.spmm_ell, and a
    pre-stripe cache entry (no 'stripe' key) still dispatches fine."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    keyr = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(keyr, 3)
    ids = jax.random.randint(k1, (40, 4), 0, 200).astype(jnp.int32)
    val = jax.random.normal(k2, (40, 4), jnp.float32)
    x = jax.random.normal(k3, (200, 8), jnp.float32)
    want = np.asarray(ref.spmm_ell(ids, val, x))
    key = autotune.cache_key("spmm", (200, 8, 4), jnp.float32)
    ops.configure_spmm_dispatch(reset=True)
    autotune.record(key, {"variant": "hbm", "bb": 64, "stripe": 256})
    assert_allclose(np.asarray(ops.spmm_ell(ids, val, x)), want,
                    rtol=1e-5, atol=1e-5)
    autotune.record(key, {"variant": "hbm", "bb": 64})  # legacy entry
    autotune.clear(memory_only=True)                    # reload from file
    assert_allclose(np.asarray(ops.spmm_ell(ids, val, x)), want,
                    rtol=1e-5, atol=1e-5)


def test_tuned_bb_flows_into_kernel_call(tuner_cache, monkeypatch):
    """ops.spmm_ell consumes the tuned block size end-to-end (forced
    Pallas interpret path) and stays parity-correct."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    keyr = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(keyr, 3)
    ids = jax.random.randint(k1, (40, 4), 0, 200).astype(jnp.int32)
    val = jax.random.normal(k2, (40, 4), jnp.float32)
    x = jax.random.normal(k3, (200, 8), jnp.float32)
    key = autotune.cache_key("spmm", (200, 8, 4), jnp.float32)
    autotune.record(key, {"variant": "resident", "bb": 64})
    ops.configure_spmm_dispatch(reset=True)
    got = ops.spmm_ell(ids, val, x)
    assert_allclose(np.asarray(got), np.asarray(ref.spmm_ell(ids, val, x)),
                    rtol=1e-5, atol=1e-5)
