"""Substrate tests: graph structures/datasets/samplers, token pipeline,
optimizer, checkpoint/restart, bounds (hypothesis property tests)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

try:  # property tests are optional: skip (not error) without hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import bounds
from repro.data.tokens import TokenStreamConfig, batch_shard
from repro.graph.batching import inductive_view, make_pack
from repro.graph.datasets import DATASETS, synthetic_arxiv, synthetic_ppi
from repro.graph.sampling import (cluster_gcn_batches, graphsaint_rw_batches,
                                  ns_sage_batches, partition_graph)
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adam, rmsprop


# ---------------------------------------------------------------------------
# graph substrate
# ---------------------------------------------------------------------------

def test_datasets_build():
    for name, fn in DATASETS.items():
        g = fn()
        assert g.n > 0 and g.m > 0
        assert g.features.shape == (g.n, g.f)
        assert g.max_degree() <= 48
        # CSR invariants
        assert g.in_csr.indptr[-1] == g.m
        assert (g.in_csr.indices < g.n).all()


def test_pack_positions_consistent():
    g = synthetic_arxiv(n=300, seed=0)
    bidx = np.arange(64)
    pack = make_pack(g, bidx)
    nbr = np.asarray(pack.nbr_ids)
    pos = np.asarray(pack.nbr_pos)
    mask = np.asarray(pack.nbr_mask)
    # wherever pos >= 0, the neighbor id must equal batch_ids[pos]
    for r in range(64):
        for d in range(nbr.shape[1]):
            if mask[r, d] > 0 and pos[r, d] >= 0:
                assert bidx[pos[r, d]] == nbr[r, d]


def test_inductive_view_hides_test_nodes():
    g = synthetic_ppi(n=400)
    gv = inductive_view(g)
    vis = np.zeros(g.n, bool)
    vis[g.train_idx] = True
    for i in np.where(~vis)[0]:
        assert len(gv.in_csr.neighbors(i)) == 0


def test_samplers_produce_valid_subgraphs():
    g = synthetic_arxiv(n=400, seed=0)
    rng = np.random.default_rng(0)
    for src, dst, nodes, seed_pos, seed_w in ns_sage_batches(
            g, 32, [5, 5], rng, g.train_idx):
        assert (src < len(nodes)).all() and (dst < len(nodes)).all()
        assert len(seed_pos) == 32 and len(seed_w) == 32
        assert (seed_pos < len(nodes)).all()
        break
    part = partition_graph(g, 8, rng)
    assert part.min() >= 0 and part.max() < 8
    for src, dst, nodes, seed_pos, seed_w in cluster_gcn_batches(
            g, part, 2, rng):
        assert len(nodes) > 0
        break
    for src, dst, nodes, seed_pos, seed_w in graphsaint_rw_batches(
            g, 64, 3, rng, g.train_idx):
        assert len(nodes) >= 64
        break


# ---------------------------------------------------------------------------
# token pipeline: determinism + shard invariance (elastic contract)
# ---------------------------------------------------------------------------

def test_token_stream_shard_invariance():
    cfg = TokenStreamConfig(vocab=97, seq_len=33, global_batch=8, seed=3)
    full = batch_shard(cfg, step=7, shard=0, n_shards=1)
    halves = np.concatenate([batch_shard(cfg, 7, s, 2) for s in (0, 1)])
    assert (full == halves).all()
    quarters = np.concatenate([batch_shard(cfg, 7, s, 4) for s in range(4)])
    assert (full == quarters).all()


def test_token_stream_deterministic_and_structured():
    cfg = TokenStreamConfig(vocab=97, seq_len=128, global_batch=4, seed=0)
    a = batch_shard(cfg, 0, 0, 1)
    b = batch_shard(cfg, 0, 0, 1)
    assert (a == b).all()
    assert (a >= 0).all() and (a < 97).all()
    # structured: not all tokens unique-uniform (Markov chain repeats)
    assert len(np.unique(a[0])) < 97


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adam_matches_manual_step():
    opt = adam(lr=0.1, b1=0.9, b2=0.999)
    p = {"w": jnp.ones((3,))}
    st_ = opt.init(p)
    g = {"w": jnp.full((3,), 0.5)}
    p2, st2 = opt.update(g, st_, p)
    # bias-corrected first step: delta = lr * g / (|g| + eps)
    assert_allclose(np.asarray(p2["w"]), np.ones(3) - 0.1, rtol=1e-4)
    assert int(st2.step) == 1


def test_rmsprop_decreases_quadratic():
    opt = rmsprop(lr=0.05)
    p = {"w": jnp.array([3.0, -2.0])}
    st_ = opt.init(p)
    for _ in range(100):
        g = {"w": 2 * p["w"]}
        p, st_ = opt.update(g, st_, p)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_optimizer_preserves_namedtuple_structure():
    """Regression: NamedTuple params are tuples; the update must not
    collapse them (bug found in the dry run)."""
    from repro.nn.attention import init_attn, AttnParams
    p = {"attn": init_attn(jax.random.PRNGKey(0), 8, 2, 1, 4)}
    opt = adam(1e-3)
    st_ = opt.init(p)
    g = jax.tree_util.tree_map(jnp.ones_like, p)
    p2, _ = opt.update(g, st_, p)
    assert isinstance(p2["attn"], AttnParams)


# ---------------------------------------------------------------------------
# checkpoint / restart
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"w": jnp.arange(6.0).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 10, state, {"cursor": 123})
    restored, manifest = ckpt.restore(str(tmp_path), state)
    assert manifest["step"] == 10 and manifest["cursor"] == 123
    assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_keeps_latest_and_gc(tmp_path):
    state = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]


def test_checkpoint_restart_resumes_training(tmp_path):
    """Kill-and-restart drill: a second `train` call picks up from the
    checkpoint and ends at the same step count."""
    from repro.configs.registry import get_smoke
    from repro.train.loop import train
    cfg = get_smoke("granite-3-8b")
    r1 = train(cfg, steps=6, batch=2, seq_len=32, ckpt_dir=str(tmp_path),
               ckpt_every=3, log_every=2)
    assert ckpt.latest_step(str(tmp_path)) == 6
    # "crashed" run resumes: only steps 7..8 execute
    r2 = train(cfg, steps=8, batch=2, seq_len=32, ckpt_dir=str(tmp_path),
               ckpt_every=3, log_every=1)
    steps = [h["step"] for h in r2["history"]]
    assert min(steps) >= 7 and max(steps) == 8


def test_failure_injection_drill(tmp_path):
    from repro.configs.registry import get_smoke
    from repro.train.loop import train
    cfg = get_smoke("granite-3-8b")
    r = train(cfg, steps=6, batch=2, seq_len=32, ckpt_dir=str(tmp_path),
              ckpt_every=2, log_every=1, inject_failure_at=5)
    assert max(h["step"] for h in r["history"]) == 6   # recovered + finished


# ---------------------------------------------------------------------------
# Theorem 2 / Corollary 3 bounds (hypothesis property test)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(8, 40), f=st.sampled_from([4, 8, 16]),
           k=st.integers(2, 8), seed=st.integers(0, 1000))
    def test_theorem2_bound_holds(n, f, k, seed):
        """|| C R R' X W - C X W ||_F <= eps ||C|| ||X|| ||W||  for a fixed
        convolution (Lip(h)=0, identity activation): the Thm 2 inequality."""
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        c = jax.random.normal(ks[0], (n, n)) / np.sqrt(n)
        x = jax.random.normal(ks[1], (n, f))
        w = jax.random.normal(ks[2], (f, f)) / np.sqrt(f)
        assign = jax.random.randint(ks[3], (n,), 0, k)
        onehot = jax.nn.one_hot(assign, k)
        cw = (onehot.T @ x) / jnp.maximum(onehot.sum(0)[:, None], 1e-9)
        x_hat = cw[assign]

        eps = bounds.vq_relative_error(x, x_hat)
        lhs = bounds.fro(c @ x_hat @ w - c @ x @ w)
        rhs = bounds.feature_error_bound(
            eps, bounds.fro(c), bounds.fro(x), bounds.fro(w))
        assert float(lhs) <= float(rhs) * (1 + 1e-5)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_theorem2_bound_holds():
        pass
