"""Distribution-layer tests: sharding rules, compressed collectives, and a
small-mesh dry-run executed in a subprocess (8 virtual devices -- the same
code path as the 512-device production dry-run)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_smoke
from repro.distributed.collectives import (compressed_grad_allreduce,
                                           dequantize_int8, quantize_int8)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, (64, 32)),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 1.01     # within one quantization step


def test_compressed_allreduce_error_feedback():
    """Error feedback: the residual carries exactly what quantization lost,
    so the two-step sum converges to the true sum."""
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (128,)),
                    jnp.float32)

    def one_dev(xx, res):
        # psum over a single-device axis == identity; tests the plumbing
        return compressed_grad_allreduce({"g": xx}, "i", res)

    out, res = jax.vmap(lambda xx: one_dev(xx, None), axis_name="i")(
        x[None])
    recon1 = out["g"][0]
    # second step with the residual: cumulative sum error shrinks
    out2, _ = jax.vmap(lambda xx, rr: compressed_grad_allreduce(
        {"g": xx}, "i", {"g": rr}), axis_name="i")(x[None], res["g"][None])
    total_err = jnp.abs((recon1 + out2["g"][0]) - 2 * x).max()
    naive_err = 2 * jnp.abs(recon1 - x).max()
    assert float(total_err) <= float(naive_err) + 1e-6


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-moe-30b-a3b",
                                  "llama3.2-3b", "whisper-tiny"])
def test_strategy_selection(arch):
    from repro.distributed.sharding import strategy_for
    # strategy choice is a pure function of the full config + mesh shape;
    # evaluate against a mock 16-way-model mesh via the production rules
    import repro.distributed.sharding as shd
    cfg = ARCHS[arch]

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    s = strategy_for(cfg, FakeMesh())
    expected = {"granite-3-8b": "tp_fsdp",
                "qwen3-moe-30b-a3b": "moe_ep_dp",
                "llama3.2-3b": "fsdp",
                "whisper-tiny": "replicate"}[arch]
    assert s == expected


def test_param_shardings_never_invalid():
    """Every leaf's spec must divide its dims on the production mesh --
    checked for all 10 archs without any device allocation."""
    import repro.distributed.sharding as shd
    from repro.models import lm

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    sizes = {"pod": 2, "data": 16, "model": 16}
    for arch, cfg in ARCHS.items():
        params = jax.eval_shape(
            lambda c=cfg: lm.init_lm(jax.random.PRNGKey(0), c))
        strategy = shd.strategy_for(cfg, FakeMesh())
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        for path, leaf in flat:
            pathstr = "".join(str(p) for p in path)
            spec = shd._spec_for_leaf(pathstr, tuple(leaf.shape), strategy,
                                      FakeMesh(), cfg)
            for dim, entry in zip(leaf.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = int(np.prod([sizes[a] for a in axes]))
                assert dim % n == 0, (arch, pathstr, leaf.shape, spec)


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """End-to-end mini dry-run on 16 virtual devices (mesh 4x4) -- the same
    lower+compile path as the 512-chip run, in a fresh process so the
    XLA_FLAGS device-count override is safe."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from repro.configs.registry import get_smoke
from repro.distributed import sharding as shd
from repro.models import lm
from repro.train.loop import TrainState, make_train_step
from repro.train.optimizer import adam

mesh = jax.make_mesh((4, 4), ("data", "model"))
cfg = get_smoke("granite-3-8b")
opt = adam(1e-3)
params = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
opt_state = jax.eval_shape(opt.init, params)
state = TrainState(params, opt_state, jax.ShapeDtypeStruct((), jnp.int32))
strategy = "tp_fsdp"
state_sh = TrainState(
    params=shd.param_shardings(params, cfg, mesh, strategy),
    opt=type(opt_state)(step=shd.replicated(mesh),
                        mu=shd.param_shardings(opt_state.mu, cfg, mesh,
                                               strategy),
                        nu=shd.param_shardings(opt_state.nu, cfg, mesh,
                                               strategy)),
    step=shd.replicated(mesh))
step = make_train_step(cfg, opt, accum=2)
tok = jax.ShapeDtypeStruct((8, 33), jnp.int32)
with mesh:
    fn = jax.jit(step, in_shardings=(state_sh, None),
                 out_shardings=(state_sh, shd.replicated(mesh)))
    compiled = fn.lower(state, tok).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # jax < 0.4.x returned one dict per device
        ca = ca[0]
    print("COMPILED_OK", ca["flops"] > 0)
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert "COMPILED_OK True" in out.stdout, out.stderr[-2000:]
