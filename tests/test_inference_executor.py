"""Inference-executor tests (DESIGN.md section 11): jitted ``lax.scan``
layer sweeps vs the eager per-batch fallback, the wrap-padded tail
regression (``g.n % batch_size != 0``), inductive feature-half refresh
inside jit, the compile-count / jaxpr contracts, the one-compile serve
step, and the accounting / metric bugfix satellites of ISSUE 5."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import codebook as cbm
from repro.core.codebook import CodebookConfig
from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                  full_operands, inference_slices)
from repro.graph.datasets import synthetic_arxiv
from repro.analysis.trace_count import INFER_TRACE_COUNT
from repro.models.gnn import (GNNConfig,
                              _layer_out_dims, _vq_infer_layer_body,
                              hits_at_k, init_gnn, init_vq_states,
                              vq_infer_epoch, vq_serve_batch)
from repro.train.gnn_trainer import vq_inference


@pytest.fixture(scope="module")
def g():
    return synthetic_arxiv(n=300, seed=0)


@pytest.fixture(scope="module")
def setup(g):
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=32,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=32, f_prod=4))
    ops = full_operands(g)
    return dict(cfg=cfg, ops=ops, x=jnp.asarray(g.features),
                params=init_gnn(jax.random.PRNGKey(0), cfg),
                vq=init_vq_states(jax.random.PRNGKey(1), cfg, g.n),
                plan=build_epoch_plan(g, full_ops=ops))


def _both_paths(g, setup, batch, monkeypatch, **kw):
    monkeypatch.setenv("REPRO_INFER_EXECUTOR", "0")
    eager = vq_inference(setup["params"], setup["vq"], g, setup["cfg"],
                         batch, **kw)
    monkeypatch.setenv("REPRO_INFER_EXECUTOR", "1")
    exe = vq_inference(setup["params"], setup["vq"], g, setup["cfg"],
                       batch, **kw)
    return exe, eager


# ---------------------------------------------------------------------------
# executor vs eager fallback (the ragged-tail regression, satellite 1)
# ---------------------------------------------------------------------------

def test_executor_matches_eager_nondivisible(g, setup, monkeypatch):
    """g.n % batch_size != 0: the wrap-padded executor must agree with the
    eager per-batch loop on every (real) node."""
    assert g.n % 128 != 0
    exe, eager = _both_paths(g, setup, 128, monkeypatch)
    assert exe.shape == (g.n, setup["cfg"].n_out)
    assert_allclose(exe, eager, rtol=2e-5, atol=1e-6)


def test_executor_matches_eager_divisible(g, setup, monkeypatch):
    assert g.n % 100 == 0
    exe, eager = _both_paths(g, setup, 100, monkeypatch)
    assert_allclose(exe, eager, rtol=2e-5, atol=1e-6)


def test_tail_padding_never_leaks_into_real_outputs(g, setup, monkeypatch):
    """Nodes duplicated by the wrap-padding (real slot early in the epoch,
    padded slot in the tail batch) must keep their REAL-slot output: the
    padded slot's write is diverted to the sacrificial row.  The eager
    fallback only ever writes real slots, so exact agreement on the
    duplicated nodes pins the masked-scatter contract."""
    batch = 128
    ids, smask = inference_slices(g.n, batch)
    dup = ids[-1][smask[-1] == 0]
    assert len(dup) > 0                      # the shape really has a tail
    exe, eager = _both_paths(g, setup, batch, monkeypatch)
    assert_allclose(exe[dup], eager[dup], rtol=2e-5, atol=1e-6)


def test_inference_slices_is_identity_epoch_slices():
    ids, smask = inference_slices(10, 4)
    ref_ids, ref_smask = epoch_slices(np.arange(10), 4)
    assert np.array_equal(ids, ref_ids)
    assert np.array_equal(smask, ref_smask)


# ---------------------------------------------------------------------------
# inductive feature-half refresh inside the jitted sweep
# ---------------------------------------------------------------------------

def test_inductive_refresh_inside_jit(g, setup, monkeypatch):
    exe, eager = _both_paths(g, setup, 128, monkeypatch, inductive=True)
    assert_allclose(exe, eager, rtol=2e-5, atol=1e-6)


def test_inductive_executor_states_match_host_assignment(g, setup):
    """The layer-0 state returned by the executor carries exactly the
    feature-half assignment of the input features (computed on host as the
    oracle), proving the refresh really runs inside the layer sweep."""
    s = setup
    ids, smask = inference_slices(g.n, 128)
    _, states = vq_infer_epoch(
        s["params"], s["vq"], s["plan"], jnp.asarray(ids.astype(np.int32)),
        jnp.asarray(smask), s["x"], s["ops"].degrees, s["cfg"],
        inductive=True)
    fi, _ = _layer_out_dims(s["cfg"])[0]
    want = cbm.assign_features_only(
        s["vq"][0].codebook, s["x"], fi, s["cfg"].layer_codebook_cfg())
    assert np.array_equal(np.asarray(states[0].assignment),
                          np.asarray(want))
    # and the histogram invariant of refresh_assignment holds
    assert_allclose(np.asarray(states[0].counts).sum(-1),
                    np.asarray(s["vq"][0].counts).sum(-1), rtol=1e-6)


# ---------------------------------------------------------------------------
# compile-count / jaxpr contracts
# ---------------------------------------------------------------------------

def test_compile_count_independent_of_batch_count(g):
    """One inference pass costs exactly n_layers layer traces, whatever S
    is and whether the batch size divides g.n; a repeat call re-traces
    nothing.  (Fresh cfg -> cold jit cache for this test.)"""
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    params = init_gnn(jax.random.PRNGKey(2), cfg)
    vq = init_vq_states(jax.random.PRNGKey(3), cfg, g.n)

    before = INFER_TRACE_COUNT.snapshot()
    vq_inference(params, vq, g, cfg, 128)      # S = 3 (padded tail)
    assert INFER_TRACE_COUNT.delta(before)["layer"] == cfg.n_layers

    before = INFER_TRACE_COUNT.snapshot()
    vq_inference(params, vq, g, cfg, 128)      # warm: zero new traces
    assert INFER_TRACE_COUNT.delta(before)["layer"] == 0

    before = INFER_TRACE_COUNT.snapshot()
    vq_inference(params, vq, g, cfg, 97)       # S = 4, still ragged n
    assert INFER_TRACE_COUNT.delta(before)["layer"] == cfg.n_layers


def test_layer_body_jaxpr_one_scan_size_independent_of_S(g, setup):
    """The layer sweep lowers to ONE lax.scan whose jaxpr size does not
    grow with the number of batches S (the eager path grew linearly)."""
    s = setup
    body = functools.partial(_vq_infer_layer_body, cfg=s["cfg"], layer=0)

    def jaxpr_for(S, b):
        perm = jnp.zeros((S, b), jnp.int32)
        sm = jnp.ones((S, b), jnp.float32)
        return jax.make_jaxpr(body)(
            s["params"][0], s["vq"][0], s["plan"], perm, sm, s["x"],
            s["ops"].degrees)

    j2, j5 = jaxpr_for(2, 64), jaxpr_for(5, 64)
    for j in (j2, j5):
        assert sum(1 for e in j.jaxpr.eqns
                   if e.primitive.name == "scan") == 1
    assert len(j2.jaxpr.eqns) == len(j5.jaxpr.eqns)


# ---------------------------------------------------------------------------
# serving step
# ---------------------------------------------------------------------------

def test_serve_batch_matches_executor_on_identical_partition(g, setup):
    """With no padding and identical batch partitions across layers, the
    layer-locked executor and the per-request all-layer serve step are the
    same computation: layer l+1's gathered activations ARE the batch's own
    layer-l outputs."""
    s = setup
    ids, smask = inference_slices(g.n, 100)    # divisible: no padding
    assert (smask > 0).all()
    out_exec, _ = vq_infer_epoch(
        s["params"], s["vq"], s["plan"], jnp.asarray(ids.astype(np.int32)),
        jnp.asarray(smask), s["x"], s["ops"].degrees, s["cfg"])
    served = np.concatenate(
        [np.asarray(vq_serve_batch(
            s["params"], s["vq"], s["plan"],
            jnp.asarray(ids[i].astype(np.int32)), s["x"],
            s["ops"].degrees, s["cfg"])) for i in range(ids.shape[0])])
    assert_allclose(np.asarray(out_exec), served, rtol=2e-5, atol=1e-6)


def test_serve_batch_duplicate_ids_rows_agree(g, setup):
    """Request padding repeats ids: every duplicate row must compute the
    same output (the node->slot scatter keeps one authoritative slot)."""
    s = setup
    bids = np.arange(64) % 40                  # ids 0..23 appear twice
    out = np.asarray(vq_serve_batch(
        s["params"], s["vq"], s["plan"], jnp.asarray(bids.astype(np.int32)),
        s["x"], s["ops"].degrees, s["cfg"]))
    assert_allclose(out[:24], out[40:], rtol=1e-6, atol=1e-7)


def test_gnn_server_serve_and_drain(g, setup):
    from repro.launch.serve_gnn import GNNServer, drain_requests
    s = setup
    server = GNNServer(g, s["cfg"], s["params"], s["vq"], batch=64)
    server.warmup()
    req = np.arange(100) % g.n                 # spans two steps (padding)
    out = server.serve(req)
    assert out.shape == (100, s["cfg"].n_out)
    assert server.serve(np.zeros(0, np.int64)).shape == (0, s["cfg"].n_out)
    # chunking + padding must not change per-node outputs
    assert_allclose(out[:64], server.serve(req[:64]), rtol=1e-6, atol=1e-7)
    rng = np.random.default_rng(0)
    requests = [rng.integers(0, g.n, sz) for sz in (3, 64, 7, 130)]
    rep = drain_requests(server, requests)
    assert rep["nodes"] == sum(len(r) for r in requests)
    assert rep["requests"] == len(requests)
    assert rep["steps"] >= 4 and rep["nodes_per_s"] > 0
    assert rep["request_p99_ms"] >= rep["request_p50_ms"]


def test_gnn_server_rejects_indivisible_mesh(g, setup):
    from repro.launch.serve_gnn import GNNServer

    class _StubMesh:
        shape = {"data": 2}
    with pytest.raises(ValueError, match="divisible"):
        GNNServer(g, setup["cfg"], setup["params"], setup["vq"],
                  batch=33, mesh=_StubMesh())


# ---------------------------------------------------------------------------
# bugfix satellites: hits_at_k, pad bucket, memory accounting
# ---------------------------------------------------------------------------

def test_hits_at_k_empty_pos_is_zero_not_nan():
    out = hits_at_k(np.zeros(0), np.asarray([0.5, 1.5]))
    assert out == 0.0 and not np.isnan(out)


def test_hits_at_k_empty_neg_is_one():
    assert hits_at_k(np.asarray([1.0, 2.0]), np.zeros(0)) == 1.0


def test_pad_bucket_values_and_cap_boundary():
    from repro.train.gnn_trainer import PAD_BUCKET_CAP, _pad_bucket
    assert _pad_bucket(1) == 256
    assert _pad_bucket(256) == 256
    assert _pad_bucket(257) == 512
    assert _pad_bucket(4096, cap=4096) == 4096
    with pytest.raises(ValueError, match="pad-bucket cap"):
        _pad_bucket(4097, cap=4096)
    # non-power-of-two cap: the bucket clamp shrinks padding only, the
    # bucket always covers every real node
    assert _pad_bucket(4500, cap=5000) == 5000
    assert _pad_bucket(PAD_BUCKET_CAP) == PAD_BUCKET_CAP
    with pytest.raises(ValueError, match="pad-bucket cap"):
        _pad_bucket(PAD_BUCKET_CAP + 1)


@pytest.mark.parametrize("f,f_grad,f_prod", [
    (10, 10, 4),    # f not divisible by f_prod
    (16, 4, 4),     # grad-width-capped layout (1 branch, not 4)
    (12, 12, 4),    # divisible layout: old and new accounting agree
])
def test_vq_batch_bytes_codebook_term_matches_allocation(f, f_grad, f_prod):
    """The Table 3 codebook term must equal what init_codebook actually
    allocates per layer (the old `max(1, f // f_prod)` count disagrees on
    non-divisible and grad-capped layouts)."""
    from repro.train.gnn_trainer import vq_batch_bytes
    b, deg, L, k = 64, 8, 2, 32
    total = vq_batch_bytes(b, deg, f, L, k, f_prod=f_prod, f_grad=f_grad)
    other = b * deg * 4 * 6 + L * b * f * 4 + b * deg * f * 4
    cb = cbm.init_codebook(jax.random.PRNGKey(0), f, f_grad,
                           CodebookConfig(k=k, f_prod=f_prod))
    assert total - other == L * cb.codewords_w.size * 4


def test_trainer_accounting_matches_hidden_layer_allocation(g):
    """The train_vq call site must feed the BACKBONE's f_grad into the
    accounting: for GAT the gradient codewords live at f_out + heads, so
    the hidden-layer codebook term must equal what init_vq_states actually
    allocates for a hidden layer (defaulting f_grad to cfg.hidden silently
    re-created the naive count)."""
    from repro.nn.gnn_layers import BACKBONES
    cfg = GNNConfig(backbone="gat", f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=3, heads=4,
                    codebook=CodebookConfig(k=16, f_prod=4))
    vq = init_vq_states(jax.random.PRNGKey(0), cfg, 10)
    fi0, fo0 = _layer_out_dims(cfg)[0]
    f_grad = BACKBONES["gat"].f_grad(fi0, fo0, heads=cfg.heads)
    nb, fb, gb = cbm.branch_layout(cfg.hidden, f_grad, 4)
    mid = vq[1].codebook.codewords_w       # hidden layer: fi = fo = hidden
    assert mid.shape == (nb, cfg.codebook.k, fb + gb)
    # the naive f // f_prod count would have claimed 16 branches
    assert nb != cfg.hidden // 4


def test_vq_batch_bytes_regression_vs_naive_branch_count():
    """Pin the bug: for a grad-capped layout the naive f // f_prod count
    (4 branches) over-counted what branch_layout allocates (1 branch)."""
    nb, fb, gb = cbm.branch_layout(16, 4, 4)
    assert (nb, fb, gb) == (1, 16, 4)
    assert nb != max(1, 16 // 4)
