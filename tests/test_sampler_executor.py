"""Sampler-epoch-executor and hybrid parity tests (DESIGN.md section 12).

Mirrors test_epoch_executor.py's scan-vs-loop pattern: both execution
paths consume the SAME pre-sampled epoch (one ``sample_epoch`` call per
epoch from one rng stream), padding rows are loss- and message-neutral,
so the device-resident ``lax.scan`` executor and the per-batch host loop
must produce matching loss traces and parameters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.codebook import CodebookConfig
from repro.graph.batching import (full_operands, make_pack,
                                  pack_sampler_epoch, pad_bucket,
                                  subgraph_operands)
from repro.graph.datasets import synthetic_arxiv
from repro.graph.sampling import SAMPLER_METHODS, sample_epoch
from repro.models.gnn import (GNNConfig, full_train_step, init_gnn,
                              init_vq_states, sampler_train_epoch,
                              vq_forward, full_forward)
from repro.train.gnn_trainer import (train_hybrid, train_sampler,
                                     train_scenario, train_vq)
from repro.train.optimizer import adam


def _copy(tree):
    """sampler_train_epoch donates its carry; give each path its own."""
    return jax.tree_util.tree_map(lambda a: a.copy(), tree)


def _leaves_allclose(a, b, rtol=2e-4, atol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert_allclose(np.asarray(la), np.asarray(lb), rtol=rtol,
                        atol=atol)


@pytest.fixture(scope="module")
def g():
    return synthetic_arxiv(n=300, seed=0)


@pytest.fixture(scope="module")
def cfg(g):
    return GNNConfig(backbone="gcn", f_in=g.f, hidden=32,
                     n_out=g.num_classes, n_layers=2,
                     codebook=CodebookConfig(k=32, f_prod=4))


@pytest.mark.parametrize("method", SAMPLER_METHODS)
def test_executor_matches_host_loop(g, cfg, method, monkeypatch):
    """Same rng -> identical loss trace and final params on both paths."""
    kw = dict(epochs=2, batch_size=64, eval_every=2, seed=5)
    if method == "cluster-gcn":
        kw["n_parts"] = 8
    monkeypatch.setenv("REPRO_SAMPLER_EXECUTOR", "1")
    r_exec = train_sampler(g, cfg, method, **kw)
    monkeypatch.setenv("REPRO_SAMPLER_EXECUTOR", "0")
    r_loop = train_sampler(g, cfg, method, **kw)
    for le, ll in zip(r_exec["losses"], r_loop["losses"]):
        assert le.shape == ll.shape       # identical batch streams
        assert_allclose(le, ll, rtol=2e-4, atol=1e-6)
    _leaves_allclose(r_exec["params"], r_loop["params"])
    assert r_exec["final"]["val"] == pytest.approx(
        r_loop["final"]["val"], abs=1e-6)


def test_scan_matches_per_batch_steps_directly(g, cfg):
    """Lower-level than train_sampler: one pre-sampled epoch, the packed
    scan vs a hand-rolled full_train_step loop over the same batches."""
    rng = np.random.default_rng(0)
    batches = sample_epoch(g, "labor", batch_size=64, rng=rng,
                           fanouts=[3, 3])
    deg_cap = g.max_degree()
    x = jnp.asarray(g.features)
    labels = g.labels
    opt = adam(1e-3)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    ost = opt.init(params)

    splan = pack_sampler_epoch(batches, deg_cap)
    p_scan, o_scan, losses = sampler_train_epoch(
        _copy(params), _copy(ost), splan, x, jnp.asarray(labels), cfg, opt)

    p_loop, o_loop = _copy(params), _copy(ost)
    loop_losses = []
    for src, dst, nodes, seed_pos, seed_w in batches:
        n_real = len(nodes)
        n_pad = pad_bucket(n_real)
        sub_ops = subgraph_operands(src, dst, n_pad, deg_cap)
        xs = jnp.zeros((n_pad, g.f), jnp.float32).at[:n_real].set(x[nodes])
        lpad = np.zeros((n_pad,) + labels.shape[1:], labels.dtype)
        lpad[:n_real] = labels[nodes]
        mask = np.zeros(n_pad, np.float32)
        mask[seed_pos] = seed_w
        p_loop, o_loop, loss = full_train_step(
            p_loop, o_loop, xs, sub_ops, jnp.asarray(lpad),
            jnp.asarray(mask), cfg, opt)
        loop_losses.append(float(loss))
    assert_allclose(np.asarray(losses), np.asarray(loop_losses, np.float32),
                    rtol=2e-4, atol=1e-6)
    _leaves_allclose(p_scan, p_loop)


def test_executor_requires_node_task():
    from repro.graph.datasets import synthetic_collab
    gl = synthetic_collab(n=300)
    cfg_link = GNNConfig(backbone="gcn", f_in=gl.f, hidden=32, n_out=32,
                         n_layers=2, task="link",
                         codebook=CodebookConfig(k=32, f_prod=4))
    # link task silently takes the host path (pair mining is host-side)
    r = train_sampler(gl, cfg_link, "ns-sage", epochs=1, batch_size=64,
                      eval_every=1)
    assert "val" in r["final"]


def test_unknown_sampler_raises(g, cfg):
    with pytest.raises(ValueError, match="unknown sampler"):
        train_sampler(g, cfg, "metropolis", epochs=1, batch_size=64)


# ---------------------------------------------------------------------------
# hybrid parity
# ---------------------------------------------------------------------------

def test_hybrid_all_in_batch_equals_exact_forward(g, cfg):
    """With EVERY node in the batch there are no out-of-batch messages:
    the hybrid's vq_apply forward must equal exact message passing (the
    all-in-batch limit of the Message Invariance argument)."""
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    pack = make_pack(g, np.arange(g.n))
    out_vq, _ = vq_forward(params, x, None, pack, vq, ops.degrees, cfg,
                           inject=False)
    out_full = full_forward(params, x, ops, cfg)
    assert_allclose(np.asarray(out_vq), np.asarray(out_full), rtol=1e-4,
                    atol=1e-5)


def test_hybrid_nctx_zero_is_plain_vq(g, cfg):
    """n_ctx=0 degenerates to plain VQ training bit-for-bit: identical
    batches, identical rng consumption, identical params."""
    rv = train_vq(g, cfg, epochs=2, batch_size=64, eval_every=2, seed=3)
    rh = train_hybrid(g, cfg, epochs=2, batch_size=64, eval_every=2,
                      seed=3, n_ctx=0)
    _leaves_allclose(rv["params"], rh["params"], rtol=1e-6, atol=0)
    assert rv["final"]["val"] == rh["final"]["val"]


def test_hybrid_scan_matches_host_loop(g, cfg, monkeypatch):
    """The hybrid rides train_vq's batch_fn hook; executor on/off must
    agree (the batch_fn-aware host fallback)."""
    kw = dict(epochs=2, batch_size=64, eval_every=2, seed=1, n_ctx=32)
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "1")
    r_exec = train_hybrid(g, cfg, **kw)
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "0")
    r_loop = train_hybrid(g, cfg, **kw)
    _leaves_allclose(r_exec["params"], r_loop["params"])
    assert r_exec["final"]["val"] == pytest.approx(
        r_loop["final"]["val"], abs=1e-5)


def test_hybrid_widens_batches_improves_over_few_epochs(g, cfg):
    """Sanity: the hybrid trains (loss decreases over an epoch) and its
    batch stream really is wider than batch_size."""
    from repro.graph.sampling import hybrid_epoch_batches
    ids, _ = hybrid_epoch_batches(g, 64, [3, 3],
                                  np.random.default_rng(0), n_ctx=32)
    assert ids.shape[1] == 96


def test_scenario_dispatch_env_default(g, cfg, monkeypatch):
    monkeypatch.setenv("REPRO_SCALE_METHOD", "labor")
    r = train_scenario(g, cfg, epochs=1, batch_size=64, eval_every=1)
    assert "losses" in r                  # sampler result shape
    monkeypatch.setenv("REPRO_SCALE_METHOD", "warp")
    with pytest.raises(ValueError, match="unknown scale method"):
        train_scenario(g, cfg, epochs=1, batch_size=64)


def test_vq_batch_fn_guards(g, cfg):
    cfg_link = cfg._replace(task="link")
    with pytest.raises(ValueError, match="node-task"):
        train_vq(g, cfg_link, epochs=1, batch_size=64,
                 batch_fn=lambda rng: None)
