"""Row-sharded graph state tests (DESIGN.md section 14).

Three rings, cheapest first:

  * id-map unit tests for the ``distributed/sharding.py`` helpers;
  * collective-level oracles for ``gather_from_shards`` /
    ``shard_scatter_rows`` under ``jax.vmap(axis_name=...)`` -- 2 and 4
    virtual lanes without needing real devices, covering shard-boundary
    ids, non-divisible ``n % ndev`` padding, integer payloads, and the
    int8 compressed-payload tolerance;
  * executor parity: the sharded epoch executor vs the replicated DP
    path at the same mesh size (and vs ``vq_train_epoch`` at ndev=1),
    plus BIT-exact sharded inference (inductive refresh included) and
    serving vs the replicated single-device executors -- natively when
    enough devices exist (the CI sharded-executor job forces 4 virtual
    CPU devices) and via an XLA_FLAGS subprocess everywhere else.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.codebook import CodebookConfig
from repro.distributed.collectives import (gather_from_shards,
                                           shard_scatter_rows)
from repro.distributed.sharding import (global_to_local, graph_dp_mesh,
                                        local_to_global, node_to_shard,
                                        shard_padded_rows, shard_rows_spec)
from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                  full_operands, inference_slices)
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import (GNNConfig, init_gnn, init_vq_states,
                              vq_infer_epoch, vq_serve_batch,
                              vq_train_epoch)
from repro.train.optimizer import rmsprop

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _copy(tree):
    return jax.tree_util.tree_map(lambda a: a.copy(), tree)


def _shards(table_pad, ndev):
    """[n_pad, ...] -> [ndev, n_local, ...] contiguous row blocks (the
    vmap stand-in for each lane's shard_map operand)."""
    return table_pad.reshape((ndev, -1) + table_pad.shape[1:])


# ---------------------------------------------------------------------------
# id maps
# ---------------------------------------------------------------------------

def test_shard_padded_rows_contract():
    # +1 sacrificial row, then round to a multiple of ndev
    assert shard_padded_rows(300, 1) == 301
    assert shard_padded_rows(300, 2) == 302
    assert shard_padded_rows(301, 2) == 302
    assert shard_padded_rows(301, 4) == 304
    assert shard_padded_rows(7, 4) == 8
    for n in (1, 7, 300, 301):
        for nd in (1, 2, 3, 4):
            npad = shard_padded_rows(n, nd)
            assert npad % nd == 0 and npad >= n + 1
    with pytest.raises(ValueError, match="positive"):
        shard_padded_rows(10, 0)


def test_id_maps_roundtrip():
    n, ndev = 301, 4
    n_pad = shard_padded_rows(n, ndev)
    n_loc = n_pad // ndev
    gids = np.arange(n_pad)
    shards = node_to_shard(gids, n_loc)
    assert shards.min() == 0 and shards.max() == ndev - 1
    # contiguous-block ownership: equal blocks, ascending
    assert (np.diff(shards) >= 0).all()
    assert (np.bincount(shards) == n_loc).all()
    loc = global_to_local(gids, shards, n_loc)
    assert loc.min() == 0 and loc.max() == n_loc - 1
    np.testing.assert_array_equal(local_to_global(loc, shards, n_loc), gids)
    # wrap-pad rows (>= n, incl. the sacrificial row n) all live on the
    # LAST shard for this (n, ndev): pinned to one owner, never split
    assert (node_to_shard(np.arange(n, n_pad), n_loc) == ndev - 1).all()


def test_shard_rows_spec_shapes():
    assert shard_rows_spec() == jax.sharding.PartitionSpec("data")
    assert shard_rows_spec(2) == jax.sharding.PartitionSpec("data", None)


# ---------------------------------------------------------------------------
# cross-shard gather / scatter under the vmap oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [2, 4])
def test_gather_from_shards_matches_local_gather(ndev):
    rng = np.random.default_rng(0)
    n = 13                                     # n % ndev != 0: pad rows
    n_pad = shard_padded_rows(n, ndev)
    table = jnp.asarray(rng.standard_normal((n_pad, 5)), jnp.float32)
    b = 6
    ids = jnp.asarray(rng.integers(0, n, (ndev, b)), jnp.int32)
    out = jax.vmap(lambda t, i: gather_from_shards(t, i, "d"),
                   axis_name="d")(_shards(table, ndev), ids)
    for s in range(ndev):
        assert_allclose(np.asarray(out[s]),
                        np.asarray(table)[np.asarray(ids[s])],
                        rtol=0, atol=0)


@pytest.mark.parametrize("ndev", [2, 4])
def test_gather_from_shards_boundary_and_pad_rows(ndev):
    rng = np.random.default_rng(1)
    n = 21
    n_pad = shard_padded_rows(n, ndev)
    n_loc = n_pad // ndev
    table = jnp.asarray(rng.standard_normal((n_pad, 3)), jnp.float32)
    # every shard edge (last row of shard s, first of s+1), the global
    # sacrificial row n, and the last pad row
    edge = []
    for s in range(ndev):
        edge += [s * n_loc, (s + 1) * n_loc - 1]
    edge += [n, n_pad - 1]
    ids = jnp.asarray(np.tile(edge, (ndev, 1)), jnp.int32)
    out = jax.vmap(lambda t, i: gather_from_shards(t, i, "d"),
                   axis_name="d")(_shards(table, ndev), ids)
    for s in range(ndev):
        assert_allclose(np.asarray(out[s]), np.asarray(table)[edge],
                        rtol=0, atol=0)


def test_gather_from_shards_integer_payload_exact():
    rng = np.random.default_rng(2)
    ndev, n = 2, 10
    n_pad = shard_padded_rows(n, ndev)
    table = jnp.asarray(rng.integers(-5000, 5000, (n_pad, 4)), jnp.int32)
    ids = jnp.asarray(rng.integers(0, n, (ndev, 7)), jnp.int32)
    out = jax.vmap(lambda t, i: gather_from_shards(t, i, "d"),
                   axis_name="d")(_shards(table, ndev), ids)
    assert out.dtype == jnp.int32
    for s in range(ndev):
        np.testing.assert_array_equal(np.asarray(out[s]),
                                      np.asarray(table)[np.asarray(ids[s])])


@pytest.mark.parametrize("ndev", [2, 4])
def test_gather_from_shards_compressed_roundtrip(ndev):
    # the int8 compressed payload quantizes every shard against one
    # pmax-shared scale; with exactly one owner per row the roundtrip is
    # exact up to a single quantization half-step
    rng = np.random.default_rng(3)
    n = 17
    n_pad = shard_padded_rows(n, ndev)
    table = jnp.asarray(rng.standard_normal((n_pad, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, (ndev, 9)), jnp.int32)
    out = jax.vmap(
        lambda t, i: gather_from_shards(t, i, "d", compress=True),
        axis_name="d")(_shards(table, ndev), ids)
    step = float(jnp.max(jnp.abs(table))) / 127.0
    for s in range(ndev):
        assert_allclose(np.asarray(out[s]),
                        np.asarray(table)[np.asarray(ids[s])],
                        atol=0.51 * step, rtol=0)


@pytest.mark.parametrize("ndev", [2, 4])
def test_shard_scatter_rows_matches_global_set(ndev):
    rng = np.random.default_rng(4)
    n = 19
    n_pad = shard_padded_rows(n, ndev)
    table = jnp.asarray(rng.standard_normal((n_pad, 4)), jnp.float32)
    b = 5
    # globally-distinct real targets + every lane parking one write on
    # the sacrificial row n (the wrap-pad diversion)
    real = rng.permutation(n)[: ndev * (b - 1)].reshape(ndev, b - 1)
    ids = np.concatenate([real, np.full((ndev, 1), n)], axis=1)
    rows = rng.standard_normal((ndev, b, 4)).astype(np.float32)
    out = jax.vmap(lambda t, i, r: shard_scatter_rows(t, i, r, "d"),
                   axis_name="d")(
        _shards(table, ndev), jnp.asarray(ids, jnp.int32),
        jnp.asarray(rows))
    merged = np.asarray(out).reshape(n_pad, 4)
    expect = np.asarray(table).copy()
    for s in range(ndev):
        for j in range(b - 1):
            expect[ids[s, j]] = rows[s, j]
    # every row except the sacrificial one must match exactly
    keep = np.arange(n_pad) != n
    np.testing.assert_array_equal(merged[keep], expect[keep])


# ---------------------------------------------------------------------------
# executor parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def g():
    # n chosen so n % 2 != 0 and n % 4 != 0: every mesh pads rows, and
    # S = ceil(301/64) = 5 batches also pads the inference scan axis
    return synthetic_arxiv(n=301, seed=0)


@pytest.fixture(scope="module")
def setup(g):
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=32,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=32, f_prod=4))
    ops = full_operands(g)
    tm = np.zeros(g.n, np.float32)
    tm[g.train_idx] = 1.0
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    opt = rmsprop(3e-3)
    rng = np.random.default_rng(0)
    ids, sm = epoch_slices(rng.permutation(np.arange(g.n)), 64)
    return dict(cfg=cfg, ops=ops, x=jnp.asarray(g.features),
                labels=jnp.asarray(g.labels), tm=jnp.asarray(tm),
                params=params, vq=vq, opt=opt, ost=opt.init(params),
                plan=build_epoch_plan(g),
                ids=jnp.asarray(ids.astype(np.int32)), sm=jnp.asarray(sm))


def _sharded_state(mesh, s):
    from repro.distributed.data_parallel import ShardedGraphState
    return ShardedGraphState(mesh, s["plan"], s["x"], s["ops"].degrees,
                             labels=s["labels"], train_mask=s["tm"])


def test_sharded_epoch_matches_single_device_executor(g, setup):
    # ndev=1 instantiation: the cross-shard gathers degenerate to local
    # gathers and the run must match the plain executor
    from repro.distributed.data_parallel import vq_train_epoch_sharded
    s = setup
    mesh = graph_dp_mesh(1)
    st = _sharded_state(mesh, s)
    p1, v1, o1, l1, e1 = vq_train_epoch(
        _copy(s["params"]), _copy(s["vq"]), _copy(s["ost"]), s["plan"],
        s["ids"], s["sm"], s["x"], s["labels"], s["tm"],
        s["ops"].degrees, s["cfg"], s["opt"])
    p2, v2, o2, l2, e2 = vq_train_epoch_sharded(
        st, _copy(s["params"]), _copy(s["vq"]), _copy(s["ost"]),
        s["ids"], s["sm"], s["cfg"], s["opt"])
    assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree_util.tree_leaves(v1),
                    jax.tree_util.tree_leaves(v2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_sharded_inference_and_serve_exact_at_one_device(g, setup):
    from repro.distributed.data_parallel import (vq_infer_epoch_sharded,
                                                 vq_serve_batch_sharded)
    s = setup
    mesh = graph_dp_mesh(1)
    st = _sharded_state(mesh, s)
    iids, ism = inference_slices(g.n, 64)
    iids_d = jnp.asarray(iids.astype(np.int32))
    ism_d = jnp.asarray(ism)
    ref, states_ref = vq_infer_epoch(
        s["params"], s["vq"], s["plan"], iids_d, ism_d, s["x"],
        s["ops"].degrees, s["cfg"], inductive=True)
    out, states = vq_infer_epoch_sharded(
        st, s["params"], s["vq"], iids_d, ism_d, s["cfg"], inductive=True)
    np.testing.assert_array_equal(np.asarray(ref), st.unshard(out))
    for a, b in zip(jax.tree_util.tree_leaves(states_ref),
                    jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    bids = jnp.asarray((np.arange(48) * 7) % g.n, jnp.int32)
    y_ref = vq_serve_batch(s["params"], s["vq"], s["plan"], bids, s["x"],
                           s["ops"].degrees, s["cfg"])
    y = vq_serve_batch_sharded(st, s["params"], s["vq"], bids, s["cfg"])
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y))


def _multi_device_parity(g, s, ndev):
    """Shared body of the native 2-/4-device parity tests: sharded epoch
    vs replicated DP at the same mesh, bit-exact sharded inference (with
    inductive refresh) and serve vs the replicated ndev=1 executors, and
    the per-device capacity drop."""
    from repro.distributed.data_parallel import (
        vq_infer_epoch_sharded, vq_serve_batch_sharded,
        vq_train_epoch_dp, vq_train_epoch_sharded)
    mesh = graph_dp_mesh(ndev)
    st = _sharded_state(mesh, s)

    # --- capacity: per-device graph-state bytes drop ~1/ndev ---
    repl = sum(int(t.nbytes) for t in (
        s["plan"].nbr_ids, s["plan"].nbr_mask, s["plan"].rev_ids,
        s["plan"].rev_mask, s["x"], s["labels"], s["tm"],
        s["ops"].degrees))
    assert st.per_device_bytes() <= 0.6 * repl

    # --- epoch: sharded == replicated DP at the same mesh size ---
    p1, v1, o1, l1, e1 = vq_train_epoch_dp(
        mesh, _copy(s["params"]), _copy(s["vq"]), _copy(s["ost"]),
        s["plan"], s["ids"], s["sm"], s["x"], s["labels"], s["tm"],
        s["ops"].degrees, s["cfg"], s["opt"])
    p2, v2, o2, l2, e2 = vq_train_epoch_sharded(
        st, _copy(s["params"]), _copy(s["vq"]), _copy(s["ost"]),
        s["ids"], s["sm"], s["cfg"], s["opt"])
    assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-6, atol=2e-7)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-7)
    # codebook counts/sums/revival + assignment tables stay synchronized
    for a, b in zip(jax.tree_util.tree_leaves(v1),
                    jax.tree_util.tree_leaves(v2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-6, atol=1e-6)

    # --- inference: BIT-exact vs replicated ndev=1 (scan-axis split) ---
    iids, ism = inference_slices(g.n, 64)
    iids_d = jnp.asarray(iids.astype(np.int32))
    ism_d = jnp.asarray(ism)
    ref, states_ref = vq_infer_epoch(
        s["params"], s["vq"], s["plan"], iids_d, ism_d, s["x"],
        s["ops"].degrees, s["cfg"], inductive=True)
    out, states = vq_infer_epoch_sharded(
        st, s["params"], s["vq"], iids_d, ism_d, s["cfg"], inductive=True)
    np.testing.assert_array_equal(np.asarray(ref), st.unshard(out))
    for a, b in zip(jax.tree_util.tree_leaves(states_ref),
                    jax.tree_util.tree_leaves(states)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- serve: bit-exact, duplicate ids included ---
    bids = np.concatenate([(np.arange(40) * 7) % g.n, np.zeros(8, int)])
    bids = jnp.asarray(bids, jnp.int32)
    y_ref = vq_serve_batch(s["params"], s["vq"], s["plan"], bids, s["x"],
                           s["ops"].degrees, s["cfg"])
    y = vq_serve_batch_sharded(st, s["params"], s["vq"], bids, s["cfg"])
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y))


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (CI runs via XLA_FLAGS)")
def test_sharded_two_device_parity_native(g, setup):
    _multi_device_parity(g, setup, 2)


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >= 4 devices (CI sharded-executor job)")
def test_sharded_four_device_parity_native(g, setup):
    _multi_device_parity(g, setup, 4)


@pytest.mark.skipif(len(jax.devices()) >= 2,
                    reason="covered natively above")
def test_sharded_two_device_parity_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.join(os.path.dirname(__file__),
                      "test_sharded_state.py"),
         "-k", "test_sharded_two_device_parity_native"],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1 passed" in out.stdout


def test_sharded_epoch_compress_payload_trains(g, setup):
    # the int8 feature-gather payload is lossy but must keep the epoch
    # finite and close to the exact path (single mesh device: the
    # quantize/dequant roundtrip is the only difference)
    from repro.distributed.data_parallel import vq_train_epoch_sharded
    s = setup
    st = _sharded_state(graph_dp_mesh(1), s)
    p, v, o, losses, errs = vq_train_epoch_sharded(
        st, _copy(s["params"]), _copy(s["vq"]), _copy(s["ost"]),
        s["ids"], s["sm"], s["cfg"], s["opt"], compress=True)
    assert np.isfinite(np.asarray(losses)).all()
    assert np.isfinite(np.asarray(errs)).all()


# ---------------------------------------------------------------------------
# actionable misconfiguration errors (issue satellite)
# ---------------------------------------------------------------------------

def test_graph_dp_mesh_error_names_sharded_requirements():
    want = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="sharded graph"):
        graph_dp_mesh(want)
    with pytest.raises(ValueError, match="shard_padded_rows"):
        graph_dp_mesh(want)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        graph_dp_mesh(want)


def test_train_vq_divisibility_error_names_sharded_requirements(g):
    from repro.train.gnn_trainer import train_vq

    class _StubMesh:
        shape = {"data": 2}

    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    with pytest.raises(ValueError, match="clamped to the 301-node pool"):
        train_vq(g, cfg, epochs=1, batch_size=333, mesh=_StubMesh())
    with pytest.raises(ValueError, match="shard_graph"):
        train_vq(g, cfg, epochs=1, batch_size=333, mesh=_StubMesh(),
                 shard_graph=True)
    with pytest.raises(ValueError, match="pass mesh="):
        train_vq(g, cfg, epochs=1, batch_size=64, shard_graph=True)


def test_sharded_state_requires_labels_to_train(g, setup):
    from repro.distributed.data_parallel import (ShardedGraphState,
                                                 vq_train_epoch_sharded)
    s = setup
    st = ShardedGraphState(graph_dp_mesh(1), s["plan"], s["x"],
                           s["ops"].degrees)
    with pytest.raises(ValueError, match="labels"):
        vq_train_epoch_sharded(st, _copy(s["params"]), _copy(s["vq"]),
                               _copy(s["ost"]), s["ids"], s["sm"],
                               s["cfg"], s["opt"])


def test_gnn_server_sharded_matches_unsharded(g, setup):
    from repro.launch.serve_gnn import GNNServer
    s = setup
    ref = GNNServer(g, s["cfg"], s["params"], s["vq"], batch=64)
    srv = GNNServer(g, s["cfg"], s["params"], s["vq"], batch=64,
                    mesh=graph_dp_mesh(1), shard_graph=True)
    ref.refresh(), srv.refresh()
    req = (np.arange(100) * 3) % g.n
    np.testing.assert_array_equal(ref.serve(req), srv.serve(req))
    # sharding never grows the per-device footprint (at ndev=1 the only
    # delta is the padded sacrificial row)
    assert srv.graph_state_bytes_per_device() <= \
        1.1 * ref.graph_state_bytes_per_device()
    with pytest.raises(ValueError, match="pass mesh="):
        GNNServer(g, s["cfg"], s["params"], s["vq"], batch=64,
                  shard_graph=True)
