"""Int8 codeword/assignment operand path (DESIGN.md section 13): the
per-branch/per-channel codeword quantizer and its drift-aware rescale, the
int8-epilogue kernel variants (fused context +/- w_t, SpMM x_scale) against
the dequantized-fp32 oracle, uint8 assignment emission from the VQ-update
kernel, the ops.py dispatch consuming QTensor/uint8 operands data-driven
(no env reads inside jit), the precision-aware state constructors in
core/conv.py + models/gnn.py, and fp32-vs-int8 end-to-end agreement for
inference and a short training run.
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.core.codebook import CodebookConfig
from repro.core.conv import (assignment_dtype, init_layer_vq_state,
                             layer_codewords, quantize_layer_state)
from repro.core.message_passing import inject_context_grad
from repro.distributed.quantization import (CODEWORD_SCALE_DRIFT, QTensor,
                                            quantize_codewords,
                                            quantize_tensor)
from repro.kernels import ops, ref
from repro.kernels.context_ell import context_ell_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.vq_update import vq_assign_update_pallas


def _case(b, deg, n, nb, k, f_blk, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ids = jax.random.randint(k1, (b, deg), 0, n).astype(jnp.int32)
    val = jax.random.normal(k2, (b, deg), jnp.float32)
    assign = jax.random.randint(k3, (nb, n), 0, k).astype(jnp.uint8)
    cw = jax.random.normal(k4, (nb, k, f_blk), jnp.float32)
    return ids, val, assign, cw


# ---------------------------------------------------------------------------
# quantizer: shapes, round-trip error, drift-aware rescale
# ---------------------------------------------------------------------------

def test_quantize_codewords_shapes_and_roundtrip():
    cw = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 8))
    qt = quantize_codewords(cw)
    assert qt.q.shape == (4, 64, 8) and qt.q.dtype == jnp.int8
    assert qt.scale.shape == (4, 1, 8) and qt.scale.dtype == jnp.float32
    deq = qt.q.astype(jnp.float32) * qt.scale
    # symmetric int8 per (branch, channel): error bounded by half a step
    amax = jnp.max(jnp.abs(cw), axis=-2, keepdims=True)
    assert float(jnp.max(jnp.abs(deq - cw) / (amax / 127.0))) <= 0.51


def test_quantize_codewords_drift_band():
    cw = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 4))
    prev = quantize_codewords(cw)
    # within the band (amax shrank by < drift): scale is reused exactly
    kept = quantize_codewords(cw * 0.95, prev=prev)
    assert_allclose(np.asarray(kept.scale), np.asarray(prev.scale))
    # shrunk below amax/drift or grown above amax: rescaled
    for factor in (1.0 / (CODEWORD_SCALE_DRIFT * 1.2), 1.5):
        moved = quantize_codewords(cw * factor, prev=prev)
        assert not np.allclose(np.asarray(moved.scale),
                               np.asarray(prev.scale))


# ---------------------------------------------------------------------------
# kernel parity: int8 operands vs the dequantized-fp32 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,deg,n,nb,k,f_blk", [
    (8, 4, 16, 2, 4, 8),
    (33, 7, 50, 4, 16, 8),
    (257, 5, 999, 1, 256, 8),      # k=256 at the uint8 boundary
])
@pytest.mark.parametrize("with_wt", [False, True])
def test_context_ell_int8_parity(b, deg, n, nb, k, f_blk, with_wt):
    ids, val, assign, cw = _case(b, deg, n, nb, k, f_blk)
    qt = quantize_codewords(cw)
    deq = qt.q.astype(jnp.float32) * qt.scale
    w_t = jax.random.normal(jax.random.PRNGKey(9),
                            (nb * f_blk, 5)) if with_wt else None
    got = context_ell_pallas(ids, val, assign, qt.q, cw_scale=qt.scale,
                             w_t=w_t, interpret=True)
    want = ref.context_ell(ids, val, assign.astype(jnp.int32), deq, w_t=w_t)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # the CPU reference with int8 operands agrees too
    ref_q = ref.context_ell(ids, val, assign, qt.q, w_t=w_t,
                            cw_scale=qt.scale)
    assert_allclose(np.asarray(ref_q), np.asarray(want), rtol=1e-5,
                    atol=1e-5)


def test_spmm_ell_int8_parity():
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    ids = jax.random.randint(k1, (64, 8), 0, 100).astype(jnp.int32)
    val = jax.random.normal(k2, (64, 8), jnp.float32)
    x = jax.random.normal(k3, (100, 16), jnp.float32)
    qt = quantize_tensor(x)
    deq = qt.q.astype(jnp.float32) * qt.scale
    got = spmm_ell_pallas(ids, val, qt.q, x_scale=qt.scale, interpret=True)
    want = ref.spmm_ell(ids, val, deq)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    ref_q = ref.spmm_ell(ids, val, qt.q, qt.scale)
    assert_allclose(np.asarray(ref_q), np.asarray(want), rtol=1e-5,
                    atol=1e-5)


# ---------------------------------------------------------------------------
# uint8 assignment emission from the VQ-update kernel
# ---------------------------------------------------------------------------

def test_vq_update_emit_uint8_matches_int32():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (100, 8), jnp.float32)
    cw = jax.random.normal(jax.random.PRNGKey(5), (64, 8), jnp.float32)
    i32, qe32, c32, s32 = vq_assign_update_pallas(x, cw, interpret=True)
    i8, qe8, c8, s8 = vq_assign_update_pallas(x, cw, interpret=True,
                                              emit_dtype=jnp.uint8)
    assert i8.dtype == jnp.uint8
    assert np.array_equal(np.asarray(i32), np.asarray(i8).astype(np.int32))
    assert_allclose(np.asarray(qe32), np.asarray(qe8))
    assert np.array_equal(np.asarray(c32), np.asarray(c8))


def test_vq_update_emit_uint8_needs_small_k():
    x = jnp.zeros((8, 4))
    cw = jnp.zeros((300, 4))
    with pytest.raises(ValueError, match="emit_dtype"):
        vq_assign_update_pallas(x, cw, interpret=True,
                                emit_dtype=jnp.uint8)


# ---------------------------------------------------------------------------
# ops dispatch: QTensor/uint8 operands are consumed data-driven
# ---------------------------------------------------------------------------

def test_ops_context_ell_qtensor_cpu_path():
    ids, val, assign, cw = _case(16, 4, 40, 2, 16, 8)
    qt = quantize_codewords(cw)
    deq = qt.q.astype(jnp.float32) * qt.scale
    got = ops.context_ell(ids, val, assign, qt)
    want = ref.context_ell(ids, val, assign.astype(jnp.int32), deq)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ops_spmm_ell_qtensor_cpu_path():
    key = jax.random.PRNGKey(6)
    k1, k2, k3 = jax.random.split(key, 3)
    ids = jax.random.randint(k1, (32, 4), 0, 50).astype(jnp.int32)
    val = jax.random.normal(k2, (32, 4), jnp.float32)
    x = jax.random.normal(k3, (50, 8), jnp.float32)
    qt = quantize_tensor(x)
    got = ops.spmm_ell(ids, val, qt)
    want = ref.spmm_ell(ids, val, qt.q.astype(jnp.float32) * qt.scale)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_uint8_table_shifts_dispatch_crossover():
    """The 4x VMEM-envelope win: at a budget where the int32 table forces
    the loop variant, the uint8 table (itemsize=1) stays fused."""
    ops.configure_context_dispatch(reset=True, vmem_budget_mb=1.0)
    try:
        n, nb = 100_000, 4           # int32 table: 1.6 MB > 1 MB budget
        assert ops.context_ell_variant(n, nb, itemsize=4) == "loop"
        assert ops.context_ell_variant(n, nb, itemsize=1) == "fused"
    finally:
        ops.configure_context_dispatch(reset=True)


def test_kernel_precision_config(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_PRECISION", raising=False)
    assert ops.kernel_precision() == "fp32"
    monkeypatch.setenv("REPRO_KERNEL_PRECISION", "int8")
    assert ops.kernel_precision() == "int8"
    ops.configure_kernel_precision("fp32")      # override out-ranks env
    try:
        assert ops.kernel_precision() == "fp32"
    finally:
        ops.configure_kernel_precision(reset=True)
    assert ops.kernel_precision() == "int8"
    with pytest.raises(ValueError):
        ops.configure_kernel_precision("int4")


# ---------------------------------------------------------------------------
# state constructors: precision-aware assignment dtype + qcw snapshots
# ---------------------------------------------------------------------------

def test_init_layer_vq_state_precision(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_PRECISION", raising=False)
    cfg = CodebookConfig(k=64, f_prod=4)
    st32 = init_layer_vq_state(jax.random.PRNGKey(0), 50, 16, 16, cfg)
    assert st32.assignment.dtype == jnp.int32 and st32.qcw is None
    ops.configure_kernel_precision("int8")
    try:
        assert assignment_dtype(cfg) == jnp.uint8
        st8 = init_layer_vq_state(jax.random.PRNGKey(0), 50, 16, 16, cfg)
    finally:
        ops.configure_kernel_precision(reset=True)
    assert st8.assignment.dtype == jnp.uint8
    assert st8.qcw is not None
    fcw, gcw = layer_codewords(st8, 16, cfg)
    assert isinstance(fcw, QTensor) and isinstance(gcw, QTensor)
    # dense=True always yields dense f32 tables (GAT/transformer path)
    dfcw, _ = layer_codewords(st8, 16, cfg, dense=True)
    assert not isinstance(dfcw, QTensor) and dfcw.dtype == jnp.float32


def test_quantize_layer_state_drift_reuse():
    cfg = CodebookConfig(k=32, f_prod=4)
    st = init_layer_vq_state(jax.random.PRNGKey(1), 30, 8, 8, cfg)
    q1 = quantize_layer_state(st, 8, cfg)
    assert q1.qcw is not None
    # requantizing an unchanged codebook keeps the grid byte-identical
    q2 = quantize_layer_state(q1, 8, cfg)
    assert np.array_equal(np.asarray(q1.qcw.feat.q),
                          np.asarray(q2.qcw.feat.q))
    assert_allclose(np.asarray(q1.qcw.feat.scale),
                    np.asarray(q2.qcw.feat.scale))


# ---------------------------------------------------------------------------
# Eq. 7 backward with a QTensor gradient-codeword operand
# ---------------------------------------------------------------------------

def test_inject_context_grad_qtensor():
    b, deg, n, nb, f_blk, f_out = 8, 3, 20, 2, 4, 6
    ids, val, assign, gcw = _case(b, deg, n, nb, 16, f_blk, seed=7)
    qt = quantize_codewords(gcw)
    deq = qt.q.astype(jnp.float32) * qt.scale
    x = jax.random.normal(jax.random.PRNGKey(8), (b, f_out))
    w = jax.random.normal(jax.random.PRNGKey(9), (f_out, nb * f_blk))

    def loss(x_b, gq):
        return jnp.sum(inject_context_grad(x_b, val, ids, gq, assign, w))

    # grad only wrt x_b: the int8 snapshot is a frozen operand, but the
    # custom-VJP backward still builds its cotangent (the QTensor-safe
    # tree_map zeros in _inject_bwd) -- a non-tree-safe rule would throw
    gx_q = jax.grad(loss)(x, qt)
    gx_d = jax.grad(loss)(x, deq)
    assert_allclose(np.asarray(gx_q), np.asarray(gx_d), rtol=1e-5,
                    atol=1e-5)
    # the phantom term is real (not the identity grad of ones)
    assert not np.allclose(np.asarray(gx_q), 1.0)


# ---------------------------------------------------------------------------
# end-to-end: fp32-trained model served int8, and int8 training smoke
# ---------------------------------------------------------------------------

def test_quantized_inference_agreement(monkeypatch):
    # pin fp32 state construction so the comparison is really int8-vs-fp32
    # even when the whole sweep runs under REPRO_KERNEL_PRECISION=int8
    monkeypatch.delenv("REPRO_KERNEL_PRECISION", raising=False)
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import (GNNConfig, init_gnn, init_vq_states,
                                  quantize_vq_states)
    from repro.train.gnn_trainer import vq_inference

    g = synthetic_arxiv(n=300, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=32, f_prod=4))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    vq8 = quantize_vq_states(vq, cfg)
    for st in vq8:
        assert st.assignment.dtype == jnp.uint8 and st.qcw is not None
    y32 = vq_inference(params, vq, g, cfg, batch_size=100)
    y8 = vq_inference(params, vq8, g, cfg, batch_size=100)
    agree = float((np.argmax(np.asarray(y32), -1) ==
                   np.argmax(np.asarray(y8), -1)).mean())
    assert agree >= 0.98


def test_quantize_vq_states_needs_small_k():
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import (GNNConfig, init_vq_states,
                                  quantize_vq_states)
    g = synthetic_arxiv(n=100, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=1,
                    codebook=CodebookConfig(k=300, f_prod=4))
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    with pytest.raises(ValueError, match="256"):
        quantize_vq_states(vq, cfg)


@pytest.mark.skipif(
    os.environ.get("REPRO_FORCE_PALLAS", "0") == "1",
    reason="training grads cannot trace through the intra-term SpMM "
    "pallas_call (test_context_ell.py convention); the int8 forward "
    "operands are parity-covered under FORCE_PALLAS above")
def test_int8_training_smoke():
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import GNNConfig
    from repro.train.gnn_trainer import train_vq

    g = synthetic_arxiv(n=300, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=32, f_prod=4))
    ops.configure_kernel_precision("int8")
    try:
        r = train_vq(g, cfg, epochs=2, batch_size=100, eval_every=100)
    finally:
        ops.configure_kernel_precision(reset=True)
    for st in r["vq_states"]:
        assert st.assignment.dtype == jnp.uint8
        assert st.qcw is not None and st.qcw.feat.q.dtype == jnp.int8
    assert np.isfinite(r["final"]["val"])
