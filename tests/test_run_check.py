"""CI gating of benchmarks/run.py: the --check parity gate must exit
non-zero on out-of-tolerance rows, and the --baseline bench-trend gate on
>20% regressions of gated metrics -- both fail-closed (a gate that exits 0
on a red row is worse than no gate).  Uses synthetic suite stubs injected
into sys.modules, never the real (slow) benches.
"""
import json
import os
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import run as bench_run  # noqa: E402


def _row(name, metrics, tolerance=None):
    ok = tolerance is None or all(
        metrics.get(m, 0.0) <= t for m, t in tolerance.items())
    return {"name": name, "us_per_call": 1.0, "metrics": metrics,
            "tolerance": tolerance, "pass": ok}


@pytest.fixture
def stub_suite(monkeypatch):
    """Install benchmarks.bench_stub with caller-provided rows."""
    def install(rows):
        mod = types.ModuleType("benchmarks.bench_stub")
        mod.run_structured = lambda: rows
        monkeypatch.setitem(sys.modules, "benchmarks.bench_stub", mod)
        return mod
    return install


# ---------------------------------------------------------------------------
# --check parity gate
# ---------------------------------------------------------------------------

def test_check_fails_on_out_of_tolerance_parity(stub_suite, tmp_path):
    # synthetic parity delta above tolerance: maxerr 0.5 vs gate 1e-3
    stub_suite([_row("stub/parity", {"maxerr": 0.5},
                     tolerance={"maxerr": 1e-3})])
    out = tmp_path / "out.json"
    with pytest.raises(SystemExit) as e:
        bench_run.run_suite_structured("stub", str(out), check=True)
    assert e.value.code == 1
    data = json.loads(out.read_text())
    assert data["failures"] == ["stub/parity"]


def test_check_fails_on_sub_gate_speedup_ratio(stub_suite, tmp_path):
    # a gated speedup ratio that misses the bar (int8_over_fp32 must be
    # <= 1/1.3; 0.9 means the int8 path is barely faster than fp32)
    stub_suite([_row("stub/int8_vs_fp32", {"int8_over_fp32": 0.9},
                     tolerance={"int8_over_fp32": 1.0 / 1.3})])
    with pytest.raises(SystemExit) as e:
        bench_run.run_suite_structured("stub", None, check=True)
    assert e.value.code == 1


def test_check_passes_within_tolerance(stub_suite, tmp_path, capsys):
    stub_suite([_row("stub/ok", {"maxerr": 1e-6},
                     tolerance={"maxerr": 1e-3}),
                _row("stub/ungated", {"speedup": 3.0})])
    out = tmp_path / "out.json"
    bench_run.run_suite_structured("stub", str(out), check=True)  # no raise
    assert json.loads(out.read_text())["failures"] == []
    assert "ok" in capsys.readouterr().out


def test_without_check_failures_report_but_exit_zero(stub_suite):
    stub_suite([_row("stub/parity", {"maxerr": 0.5},
                     tolerance={"maxerr": 1e-3})])
    bench_run.run_suite_structured("stub", None, check=False)  # no raise


# ---------------------------------------------------------------------------
# baseline_failures comparator
# ---------------------------------------------------------------------------

def _baseline(rows):
    return {"suite": "stub", "rows": rows}


def test_baseline_flags_large_regression():
    base = _baseline([_row("a", {"ratio": 0.4}, {"ratio": 1.0})])
    cur = [_row("a", {"ratio": 0.6}, {"ratio": 1.0})]   # +50% and +0.2
    fails = bench_run.baseline_failures(cur, base)
    assert len(fails) == 1 and fails[0].startswith("a:ratio")


def test_baseline_tolerates_small_and_relative_noise():
    base = _baseline([
        _row("rel", {"ratio": 0.4}, {"ratio": 1.0}),
        _row("abs", {"ratio": 0.5}, {"ratio": 1.0}),
        _row("tiny", {"maxerr": 1e-6}, {"maxerr": 1e-3}),
    ])
    cur = [
        # +15% relative: inside rel=1.2
        _row("rel", {"ratio": 0.46}, {"ratio": 1.0}),
        # above rel but only +0.015 absolute: inside slack=0.02
        _row("abs", {"ratio": 0.515}, {"ratio": 1.0}),
        # near-zero baseline (< floor): any multiple is still noise
        _row("tiny", {"maxerr": 1e-4}, {"maxerr": 1e-3}),
    ]
    assert bench_run.baseline_failures(cur, _baseline([])) == []
    assert bench_run.baseline_failures(cur, base) == []


def test_baseline_headroom_guard():
    # a 2.4x jump that still sits below half the hard gate is scheduler
    # noise, not a trend: the absolute tolerance has ample margin left
    base = _baseline([_row("a", {"ratio": 0.05}, {"ratio": 0.77})])
    cur = [_row("a", {"ratio": 0.12}, {"ratio": 0.77})]
    assert bench_run.baseline_failures(cur, base) == []
    # past half the gate, the same relative jump does fail
    base = _baseline([_row("a", {"ratio": 0.2}, {"ratio": 0.77})])
    cur = [_row("a", {"ratio": 0.48}, {"ratio": 0.77})]
    assert len(bench_run.baseline_failures(cur, base)) == 1


def test_baseline_new_rows_and_ungated_metrics_never_fail():
    base = _baseline([_row("old", {"ratio": 0.1}, {"ratio": 1.0})])
    cur = [
        _row("new", {"ratio": 9.9}, {"ratio": 10.0}),    # not in baseline
        _row("old", {"wall_us": 99.0, "ratio": 0.1},     # wall ungated
             {"ratio": 1.0}),
    ]
    assert bench_run.baseline_failures(cur, base) == []


def test_baseline_gate_fails_even_without_check(stub_suite, tmp_path):
    stub_suite([_row("a", {"ratio": 0.9}, {"ratio": 1.0})])
    bp = tmp_path / "base.json"
    bp.write_text(json.dumps(_baseline(
        [_row("a", {"ratio": 0.3}, {"ratio": 1.0})])))
    out = tmp_path / "out.json"
    with pytest.raises(SystemExit) as e:
        bench_run.run_suite_structured("stub", str(out), check=False,
                                       baseline_path=str(bp))
    assert e.value.code == 1
    assert json.loads(out.read_text())["trend_failures"]


# ---------------------------------------------------------------------------
# CLI argument handling (fail-closed paths)
# ---------------------------------------------------------------------------

def test_main_missing_baseline_is_hard_error(monkeypatch, tmp_path):
    monkeypatch.setattr(sys, "argv", [
        "run", "kernels", "--baseline", str(tmp_path / "gone.json")])
    with pytest.raises(SystemExit, match="no such file"):
        bench_run.main()


def test_main_rejects_gate_flags_without_valid_suite(monkeypatch):
    monkeypatch.setattr(sys, "argv", ["run", "nosuch", "--check"])
    with pytest.raises(SystemExit, match="require exactly one suite"):
        bench_run.main()
    monkeypatch.setattr(sys, "argv", ["run", "--json"])
    with pytest.raises(SystemExit, match="path operand"):
        bench_run.main()
