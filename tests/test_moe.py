"""MoE dispatch correctness: the capacity-gather formulation must equal the
dense (every-expert-on-every-token) reference when capacity is ample, and
degrade only by dropping overflow tokens when it is not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.nn.ffn import MoEParams, apply_moe, init_moe


def _dense_reference(p: MoEParams, x, top_k):
    """Compute every expert for every token, combine by router top-k."""
    logits = x.astype(jnp.float32) @ p.router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum('td,edf->tef', x.astype(jnp.float32),
                   p.w1.astype(jnp.float32))
    g = jnp.einsum('td,edf->tef', x.astype(jnp.float32),
                   p.w3.astype(jnp.float32))
    ye = jnp.einsum('tef,efd->ted', jax.nn.silu(h) * g,
                    p.w2.astype(jnp.float32))        # [T, E, d]
    w = jnp.zeros(probs.shape).at[
        jnp.arange(x.shape[0])[:, None], top_e].add(top_p)
    return jnp.einsum('te,ted->td', w, ye)


@pytest.mark.parametrize("e,k", [(8, 2), (16, 2), (8, 4)])
def test_moe_matches_dense_reference_with_ample_capacity(e, k):
    t, d, ff = 64, 16, 24
    p = init_moe(jax.random.PRNGKey(0), d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    # capacity_factor large enough that nothing drops
    y, aux = apply_moe(p, x, k, capacity_factor=float(e))
    ref = _dense_reference(p, x, k)
    assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_only_overflow():
    t, d, ff, e, k = 32, 8, 16, 4, 1
    p = init_moe(jax.random.PRNGKey(0), d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    y_ample, _ = apply_moe(p, x, k, capacity_factor=float(e))
    y_tight, _ = apply_moe(p, x, k, capacity_factor=0.5)
    # tight capacity zeroes some rows but never invents new ones
    changed = np.abs(np.asarray(y_ample - y_tight)).sum(-1) > 1e-6
    zeroed = np.abs(np.asarray(y_tight)).sum(-1) < 1e-6
    assert changed.sum() > 0
    assert (zeroed | ~changed).all()


def test_moe_gradients_flow_to_router_and_experts():
    t, d, ff, e, k = 32, 8, 16, 4, 2
    p = init_moe(jax.random.PRNGKey(0), d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))

    def loss(p):
        y, aux = apply_moe(p, x, k)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g.router).sum()) > 0
    assert float(jnp.abs(g.w1).sum()) > 0
    assert float(jnp.abs(g.w2).sum()) > 0


def test_moe_load_balance_aux_range():
    """Aux loss is ~1 for balanced routing, > 1 for collapsed routing."""
    t, d, ff, e, k = 256, 8, 16, 8, 2
    p = init_moe(jax.random.PRNGKey(0), d, e, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    _, aux = apply_moe(p, x, k)
    assert 0.8 < float(aux) < 2.0
    # collapse the router (all tokens -> expert 0) -> aux grows toward E/k
    p2 = p._replace(router=jnp.zeros_like(p.router).at[:, 0].set(10.0))
    _, aux2 = apply_moe(p2, jnp.abs(x), k)
    assert float(aux2) > 1.5 * float(aux)
