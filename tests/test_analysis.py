"""repro.analysis checker tests: each rule must (a) stay silent on the
clean tree and (b) fire on a seeded regression -- a forced
dequant-before-kernel upcast, a dropped donation, a per-branch dispatch
explosion, an over-budget BlockSpec, a callback in a scan body, an env
read moved into a jit-reachable function, and so on.  The seeded
fixtures are the checker's own acceptance tests: a rule that cannot
catch its target regression is dead weight in CI."""
import ast
import os
import textwrap

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import Finding, ast_checks, jaxpr_checks, \
    load_baseline, pallas_vmem, registry, suppress
from repro.kernels import ops

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SDS = jax.ShapeDtypeStruct


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Finding plumbing
# ---------------------------------------------------------------------------

def test_finding_formats_and_baseline(tmp_path):
    f = Finding("REPRO001", "src/repro/x.py", 7, "msg")
    assert f.format("text") == "src/repro/x.py:7: REPRO001 msg"
    assert f.format("github") == \
        "::error file=src/repro/x.py,line=7,title=REPRO001::msg"
    # line 0 findings still render a valid annotation line
    assert "line=1" in Finding("REPRO101", "<entry:e>", 0, "m").format(
        "github")
    base = tmp_path / "baseline.txt"
    base.write_text(f"# comment\n{f.key()}\n")
    keys = load_baseline(str(base))
    assert suppress([f], keys) == []
    other = Finding("REPRO002", "src/repro/x.py", 7, "msg")
    assert suppress([f, other], keys) == [other]


# ---------------------------------------------------------------------------
# AST rules on synthetic sources
# ---------------------------------------------------------------------------

def _sub_findings(src, rel):
    tree = ast.parse(textwrap.dedent(src))
    out = []
    out += ast_checks._banned_call_findings(rel, tree)
    out += ast_checks._kernel_loop_findings(rel, tree)
    out += ast_checks._pytree_findings(rel, tree)
    out += ast_checks._import_side_effect_findings(rel, tree)
    return out


def _env_findings(src, rel="src/repro/fake.py"):
    return ast_checks._env_findings([(rel, ast.parse(
        textwrap.dedent(src)))])


def test_repro001_env_read_in_jit_body():
    fs = _env_findings("""
        import os, jax
        @jax.jit
        def hot(x):
            return x * float(os.environ.get("SCALE", "1"))
    """)
    assert [f.rule for f in fs] == ["REPRO001"]


def test_repro001_transitive_reachability():
    # the env read sits in a helper the jit body merely references
    fs = _env_findings("""
        import os, jax
        def helper():
            return os.getenv("KNOB")
        @jax.jit
        def hot(x):
            return x if helper() else x
    """)
    assert [f.rule for f in fs] == ["REPRO001"]


def test_repro001_host_side_read_ok():
    # same read, but nothing jit-traced references the function
    fs = _env_findings("""
        import os
        def host_config():
            return os.environ.get("KNOB")
    """)
    assert fs == []


def test_repro001_scan_body_is_a_root():
    fs = _env_findings("""
        import os, jax
        def body(c, x):
            return c + float(os.environ.get("S", "0")), None
        def epoch(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert [f.rule for f in fs] == ["REPRO001"]


def test_repro002_one_hot_in_hot_module():
    src = """
        import jax
        def assign_dense(idx, k):
            return jax.nn.one_hot(idx, k)
    """
    assert _rules(_sub_findings(src, "src/repro/core/codebook.py")) == \
        {"REPRO002"}
    # fine outside the hot modules
    assert _sub_findings(src, "src/repro/nn/ffn.py") == []


def test_repro002_einsum_scoping():
    src = """
        import jax.numpy as jnp
        def ctx(a, c):
            return jnp.einsum('nbk,nkf->nbf', a, c)
    """
    assert _rules(_sub_findings(src, "src/repro/core/conv.py")) == \
        {"REPRO002"}
    # the sketch-form einsum of message_passing.py stays sanctioned
    assert _sub_findings(src, "src/repro/core/message_passing.py") == []


def test_repro003_loop_in_kernel_body():
    src = """
        def _my_kernel(x_ref, o_ref):
            for i in range(4):
                o_ref[i] = x_ref[i]
    """
    assert _rules(_sub_findings(src, "src/repro/kernels/my.py")) == \
        {"REPRO003"}
    # host-side dispatch loops (no *_ref params) stay fine
    assert _sub_findings("""
        def _loop_fallback(ids, vals):
            return [vals[i] for i in range(3)]
    """, "src/repro/kernels/ops.py") == []


def test_repro004_unregistered_pytree():
    src = """
        class Box:
            def tree_flatten(self):
                return (self.a,), None
    """
    assert _rules(_sub_findings(src, "src/repro/graph/box.py")) == \
        {"REPRO004"}
    ok = """
        from jax.tree_util import register_pytree_node_class
        @register_pytree_node_class
        class Box:
            def tree_flatten(self):
                return (self.a,), None
    """
    assert _sub_findings(ok, "src/repro/graph/box.py") == []


def test_repro005_import_time_env_mutation():
    src = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_foo"
    """
    assert _rules(_sub_findings(src, "src/repro/launch/bad.py")) == \
        {"REPRO005"}
    guarded = """
        import os
        if __name__ == "__main__":
            os.environ["XLA_FLAGS"] = "--xla_foo"
    """
    assert _sub_findings(guarded, "src/repro/launch/dryrun.py") == []


# ---------------------------------------------------------------------------
# jaxpr rules on seeded regressions
# ---------------------------------------------------------------------------

def test_repro101_dispatch_count_regression():
    """Forcing the per-branch loop fallback explodes the pinned ONE
    context dispatch into one SpMM per branch."""
    ops.configure_context_dispatch(variant="loop")
    try:
        entry = registry._serve_entry("int8")
        findings = jaxpr_checks.check_entry(entry)
    finally:
        ops.configure_context_dispatch(reset=True)
    assert "REPRO101" in _rules(findings)


def test_repro102_callback_in_scan():
    def body_with_callback(x):
        def body(c, _):
            c = c + jax.pure_callback(
                lambda v: v, SDS(c.shape, c.dtype), c)
            return c, None
        out, _ = jax.lax.scan(body, x, None, length=2)
        return out

    entry = registry.Entry(
        name="fixture:callback",
        trace=lambda: jax.make_jaxpr(body_with_callback)(
            SDS((4,), jnp.float32)),
        lower=None)
    assert _rules(jaxpr_checks.check_entry(entry)) == {"REPRO102"}


def test_repro103_dequant_before_kernel():
    """Host-level int8 -> f32 upcast ahead of the kernel: both halves of
    the dtype-flow contract fire (storage dtype never reaches the
    kernel; an out-of-kernel convert_element_type dequantizes)."""
    def dequant_first(q, scale, idx, val):
        x = q.astype(jnp.float32) * scale  # the banned host dequant
        return ops.spmm_ell(idx, val, x)

    args = (SDS((64, 16), jnp.int8), SDS((1, 16), jnp.float32),
            SDS((8, 4), jnp.int32), SDS((8, 4), jnp.float32))
    entry = registry.Entry(
        name="fixture:dequant",
        trace=lambda: jax.make_jaxpr(dequant_first)(*args),
        lower=None, force_pallas=True,
        quantized_dtypes=(jnp.dtype(jnp.int8),))
    assert _rules(jaxpr_checks.check_entry(entry)) == {"REPRO103"}


def test_repro104_dropped_donation():
    def step(x):
        return x + 1.0

    arg = SDS((8, 8), jnp.float32)
    entry = registry.Entry(
        name="fixture:no-donate",
        trace=lambda: jax.make_jaxpr(step)(arg),
        lower=lambda: jax.jit(step).lower(arg),  # donate_argnums dropped
        donated_min=1)
    assert _rules(jaxpr_checks.check_entry(entry)) == {"REPRO104"}
    donating = registry.Entry(
        name="fixture:donate",
        trace=lambda: jax.make_jaxpr(step)(arg),
        lower=lambda: jax.jit(step, donate_argnums=(0,)).lower(arg),
        donated_min=1)
    assert jaxpr_checks.check_entry(donating) == []


def test_repro105_oversized_scan_carry():
    def epoch(table):  # [1024, 8] f32 = 32 KiB riding the carry
        def body(c, _):
            return c * 2.0, None
        out, _ = jax.lax.scan(body, table, None, length=3)
        return out

    entry = registry.Entry(
        name="fixture:big-carry",
        trace=lambda: jax.make_jaxpr(epoch)(SDS((1024, 8), jnp.float32)),
        lower=None, carry_budget=1024)
    assert _rules(jaxpr_checks.check_entry(entry)) == {"REPRO105"}


def test_repro106_dense_residual():
    @jax.custom_vjp
    def f(x):
        return x

    def fwd(x):
        # saves the dense [b, Dr, f] reconstruction the lazy form avoids
        return x, jnp.broadcast_to(x[:, None, :], (16, 8, 8)) * 1.0

    def bwd(res, g):
        return (g + res.sum(1),)

    f.defvjp(fwd, bwd)
    _, vjp_fn = jax.vjp(f, jnp.ones((16, 8), jnp.float32))
    findings = jaxpr_checks.residual_leaf_findings(
        vjp_fn, 16 * 8 * 8 * 4, "<fixture>")
    assert _rules(findings) == {"REPRO106"}


def test_repro107_missing_counter_bump():
    entry = registry.Entry(
        name="fixture:no-bump",
        trace=lambda: jax.make_jaxpr(lambda x: x + 1.0)(
            SDS((4,), jnp.float32)),
        lower=None, counter="layer")
    assert _rules(jaxpr_checks.check_entry(entry)) == {"REPRO107"}


# ---------------------------------------------------------------------------
# VMEM rules on seeded regressions
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def test_repro201_over_budget_blockspec():
    # whole-array blocks: 16 MiB in + 16 MiB out, over the 16 MiB envelope
    def big(x):
        return pl.pallas_call(
            _copy_kernel, out_shape=SDS(x.shape, x.dtype),
            interpret=True)(x)

    cj = jax.make_jaxpr(big)(SDS((2048, 2048), jnp.float32))
    findings = pallas_vmem.check_dispatches(
        cj, "<fixture>", pallas_vmem._envelope_bytes(ops))
    assert _rules(findings) == {"REPRO201"}


def test_repro202_ragged_blockspec():
    def ragged(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(3,),
            in_specs=[pl.BlockSpec((4, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((4, 8), lambda i: (i, 0)),
            out_shape=SDS(x.shape, x.dtype),
            interpret=True)(x)

    cj = jax.make_jaxpr(ragged)(SDS((10, 8), jnp.float32))
    findings = pallas_vmem.check_dispatches(
        cj, "<fixture>", pallas_vmem._envelope_bytes(ops))
    assert _rules(findings) == {"REPRO202"}


def test_repro203_forced_variant_mismatch():
    """Pinning the resident/fused variants past their crossovers is
    exactly the heuristic-vs-footprint mismatch the rule exists for."""
    ops.configure_spmm_dispatch(variant="resident")
    ops.configure_context_dispatch(variant="fused")
    try:
        findings = pallas_vmem._crossover_findings()
    finally:
        ops.configure_spmm_dispatch(reset=True)
        ops.configure_context_dispatch(reset=True)
    assert _rules(findings) == {"REPRO203"}
    spots = {f.path for f in findings}
    assert spots == {"<crossover:spmm_ell>", "<crossover:context_ell>"}


# ---------------------------------------------------------------------------
# the clean tree is exactly clean (the empty-baseline policy)
# ---------------------------------------------------------------------------

def test_ast_pass_clean_tree():
    assert ast_checks.run(ROOT) == []


def test_jaxpr_pass_clean_tree():
    assert jaxpr_checks.run() == []


def test_vmem_pass_clean_tree():
    assert pallas_vmem.run() == []


def test_registry_covers_all_tiers_and_both_widths():
    names = [e.name for e in registry.entries()]
    for tier in ops.PRECISIONS:
        label = "fp32" if tier == "fp32" else tier
        assert f"vq_infer_layer[{label}]" in names
        assert f"vq_serve_batch[{label}]" in names
    # branch-count invariance probes trace a second product-VQ width
    assert any("@f_prod=2" in n for n in names)
    for core in ("vq_train_epoch", "sampler_train_epoch"):
        assert core in names
