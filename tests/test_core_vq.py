"""Core VQ-GNN invariants: codebook learning, Eq. 6/7 exactness oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import codebook as cbm
from repro.core.codebook import CodebookConfig, CodebookState, branch_layout
from repro.core.conv import LayerVQState, refresh_assignment
from repro.graph.batching import full_operands, make_pack
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import (GNNConfig, full_forward, init_gnn,
                              init_vq_states, node_loss, probe_shapes,
                              vq_forward)


@pytest.fixture(scope="module")
def small_graph():
    return synthetic_arxiv(n=250, seed=1)


def test_branch_layout_pairs():
    # equal dims -> f_prod-wide branches
    nb, fb, gb = branch_layout(128, 128, 4)
    assert (nb, fb, gb) == (32, 4, 4)
    # unequal dims -> gcd-constrained branch count, full coverage
    nb, fb, gb = branch_layout(128, 36, 4)
    assert nb * fb == 128 and nb * gb == 36


def test_codebook_update_reduces_error():
    """Streaming EMA k-means on a fixed batch must reduce the VQ relative
    error (Alg. 2 is online k-means; on stationary data it converges)."""
    cfg = CodebookConfig(k=32, f_prod=4, gamma=0.7, beta=0.5)
    key = jax.random.PRNGKey(0)
    # clusterable data: 32 centers + small noise; gradients correlated with
    # features (the realistic regime -- same cluster, same gradient)
    centers = jax.random.normal(key, (32, 16))
    idx = jax.random.randint(jax.random.PRNGKey(1), (256,), 0, 32)
    feats = centers[idx] + 0.05 * jax.random.normal(
        jax.random.PRNGKey(2), (256, 16))
    grads = 0.1 * feats + 0.01 * jax.random.normal(
        jax.random.PRNGKey(3), (256, 16))

    state = cbm.init_codebook(key, 16, 16, cfg)
    errs, werrs = [], []
    for _ in range(30):
        state, stats = cbm.update(state, feats, grads, cfg)
        errs.append(float(cbm.relative_error(state, feats, grads,
                                             stats.assignment, 16, cfg)))
        werrs.append(float(stats.relative_error()))
    assert errs[-1] < 0.75 * errs[0]   # converges from the seeded start
    assert errs[-1] < 0.4              # well below the random-assign ~1.0
    # the free fused monitor (whitened space) must converge alongside the
    # Theorem-2 oracle
    assert werrs[-1] < 0.75 * werrs[0]
    assert werrs[-1] < 0.4


def test_dead_codeword_revival():
    cfg = CodebookConfig(k=16, f_prod=4, revive_threshold=0.05)
    key = jax.random.PRNGKey(0)
    state = cbm.init_codebook(key, 8, 8, cfg)
    # park all codewords far away -> all dead initially
    state = state._replace(codewords_w=state.codewords_w + 100.0)
    feats = jax.random.normal(key, (64, 8))
    grads = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    for _ in range(10):
        state, stats = cbm.update(state, feats, grads, cfg)
    used = len(np.unique(np.asarray(stats.assignment[0])))
    assert used > 4   # revival spread assignments over several codewords


def test_whitening_scale_invariance():
    """With whitening, scaling one half of (X || G) by 1000x must not
    change assignments materially (App. E: whitening stabilizes VQ)."""
    cfg = CodebookConfig(k=8, f_prod=4, beta=0.0)  # beta=0: instant stats
    key = jax.random.PRNGKey(0)
    feats = jax.random.normal(key, (128, 8))
    grads = 1e3 * jax.random.normal(jax.random.PRNGKey(1), (128, 8))
    s1 = cbm.init_codebook(key, 8, 8, cfg)
    s1, st1 = cbm.update(s1, feats, grads, cfg)
    s2 = cbm.init_codebook(key, 8, 8, cfg)
    s2, st2 = cbm.update(s2, feats, grads / 1e3, cfg)
    agree = float((st1.assignment == st2.assignment).mean())
    assert agree > 0.9


def test_refresh_assignment_counts(small_graph):
    g = small_graph
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=1,
                    codebook=CodebookConfig(k=16, f_prod=4))
    vq = init_vq_states(jax.random.PRNGKey(0), cfg, g.n)[0]
    new_assign = jnp.zeros((vq.codebook.n_branches, 50), jnp.int32)
    vq2 = refresh_assignment(vq, jnp.arange(50), new_assign)
    assert float(vq2.counts.sum()) == vq.codebook.n_branches * g.n
    assert (np.asarray(vq2.assignment[:, :50]) == 0).all()


# ---------------------------------------------------------------------------
# Eq. 6 / Eq. 7 exactness oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backbone", ["gcn", "sage", "gin", "gat",
                                      "transformer"])
def test_b_equals_n_recovery(small_graph, backbone):
    """With the whole graph in one batch the approximation terms vanish:
    VQ forward AND gradients == full-graph exactly."""
    g = small_graph
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    cfg = GNNConfig(backbone=backbone, f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(0), cfg, g.n)
    pack = make_pack(g, np.arange(g.n))

    def vq_loss(p):
        probes = [jnp.zeros(s) for s in probe_shapes(cfg, g.n)]
        out, _ = vq_forward(p, x, probes, pack, vq, ops.degrees, cfg)
        return node_loss(out, labels, False)

    def full_loss(p):
        return node_loss(full_forward(p, x, ops, cfg), labels, False)

    g1 = jax.grad(vq_loss)(params)
    g2 = jax.grad(full_loss)(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def _identity_codebook(g, f_feat, f_grad, grads=None):
    """k = n codebook where node i is its own codeword (exact VQ)."""
    nb, fb, gb = branch_layout(f_feat, f_grad, 4)
    x = jnp.asarray(g.features)
    xs = x.reshape(g.n, nb, fb).transpose(1, 0, 2)
    gs = (jnp.zeros((nb, g.n, gb)) if grads is None else
          grads.reshape(g.n, nb, gb).transpose(1, 0, 2))
    cw = jnp.concatenate([xs, gs], -1)
    cb = CodebookState(cw, jnp.ones((nb, g.n)), cw,
                       jnp.zeros((nb, fb + gb)), jnp.ones((nb, fb + gb)),
                       jnp.zeros((), jnp.int32))
    assign = jnp.tile(jnp.arange(g.n, dtype=jnp.int32)[None], (nb, 1))
    return [LayerVQState(cb, assign, jnp.ones((nb, g.n)))]


def test_perfect_codebook_forward_exact(small_graph):
    """k = n identity codebook -> Eq. 6 forward == full-graph rows."""
    g = small_graph
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=0,
                    n_out=g.num_classes, n_layers=1,
                    codebook=CodebookConfig(k=g.n, whiten=False))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = _identity_codebook(g, g.f, g.num_classes)
    bidx = np.arange(60)
    pack = make_pack(g, bidx)
    probes = [jnp.zeros(s) for s in probe_shapes(cfg, 60)]
    out_vq, _ = vq_forward(params, x[bidx], probes, pack, vq,
                           ops.degrees, cfg)
    out_full = full_forward(params, x, ops, cfg)[bidx]
    assert_allclose(np.asarray(out_vq), np.asarray(out_full), rtol=1e-4,
                    atol=1e-4)


def test_eq7_gradient_injection_exact(small_graph):
    """The definitive Eq. 7 oracle: with true gradient codewords the
    VQ-estimated mini-batch gradient equals the full-graph gradient of the
    global (mean over all nodes) loss, including the messages routed
    through out-of-batch nodes."""
    g = small_graph
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=0,
                    n_out=g.num_classes, n_layers=1,
                    codebook=CodebookConfig(k=g.n, whiten=False))
    params = init_gnn(jax.random.PRNGKey(0), cfg)

    def full_loss(xx):
        return node_loss(full_forward(params, xx, ops, cfg), labels, False)
    gx_full = jax.grad(full_loss)(x)

    # true pre-activation gradients (last layer has identity activation)
    z = full_forward(params, x, ops, cfg)
    gz = jax.grad(lambda zz: node_loss(zz, labels, False))(z)

    vq = _identity_codebook(g, g.f, g.num_classes, grads=gz)
    bidx = np.arange(60)
    pack = make_pack(g, bidx)

    def vq_loss(x_b):
        probes = [jnp.zeros(s) for s in probe_shapes(cfg, 60)]
        out, _ = vq_forward(params, x_b, probes, pack, vq, ops.degrees, cfg)
        logp = jax.nn.log_softmax(out, -1)
        per = -jnp.take_along_axis(logp, labels[bidx][:, None], 1)[:, 0]
        return jnp.sum(per) / g.n    # same normalization as the full loss

    gx_vq = jax.grad(vq_loss)(x[bidx])
    assert_allclose(np.asarray(gx_vq), np.asarray(gx_full[bidx]),
                    rtol=1e-4, atol=1e-6)
