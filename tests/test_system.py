"""End-to-end behaviour tests: the paper's training regimes learn, the LM
stack learns, VQ inference agrees with exact inference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codebook import CodebookConfig
from repro.graph.batching import full_operands, inductive_view
from repro.graph.datasets import synthetic_arxiv, synthetic_collab, \
    synthetic_ppi
from repro.models.gnn import GNNConfig, full_predict, node_metric
from repro.train.gnn_trainer import (train_full, train_sampler, train_vq,
                                     vq_inference)


@pytest.fixture(scope="module")
def arxiv():
    return synthetic_arxiv(n=800, seed=0)


def _cfg(g, backbone="gcn", **kw):
    return GNNConfig(backbone=backbone, f_in=g.f, hidden=48,
                     n_out=g.num_classes, n_layers=2,
                     codebook=CodebookConfig(k=128, f_prod=4), **kw)


def test_vq_gnn_learns_and_tracks_full_graph(arxiv):
    g = arxiv
    cfg = _cfg(g)
    rf = train_full(g, cfg, epochs=30, eval_every=30)
    rv = train_vq(g, cfg, epochs=30, batch_size=300, eval_every=30)
    assert rf["final"]["val"] > 0.75          # the task is learnable
    assert rv["final"]["val"] > rf["final"]["val"] - 0.08


def test_sampler_baseline_trains(arxiv):
    g = arxiv
    r = train_sampler(g, _cfg(g), "graphsaint-rw", epochs=20,
                      batch_size=150, eval_every=20)
    assert r["final"]["val"] > 0.6


def test_vq_inference_agrees_with_exact(arxiv):
    g = arxiv
    cfg = _cfg(g)
    r = train_vq(g, cfg, epochs=30, batch_size=300, eval_every=30)
    exact = np.asarray(full_predict(
        r["params"], jnp.asarray(g.features), full_operands(g), cfg))
    approx = vq_inference(r["params"], r["vq_states"], g, cfg, 300)
    agree = (exact.argmax(-1) == approx.argmax(-1)).mean()
    assert agree > 0.85, agree


def test_inductive_ppi_path():
    g = synthetic_ppi(n=500)
    gv = inductive_view(g)
    cfg = GNNConfig(backbone="sage", f_in=g.f, hidden=48,
                    n_out=g.num_classes, n_layers=2, multilabel=True,
                    codebook=CodebookConfig(k=64, f_prod=4))
    r = train_vq(gv, cfg, epochs=15, batch_size=250, eval_every=15)
    # inductive inference: unseen nodes assigned by feature half
    emb = vq_inference(r["params"], r["vq_states"], g, cfg, 250,
                       inductive=True)
    f1 = float(node_metric(jnp.asarray(emb)[g.test_idx],
                           jnp.asarray(g.labels)[g.test_idx], True))
    assert f1 > 0.55, f1


def test_link_prediction_path():
    g = synthetic_collab(n=800)
    cfg = GNNConfig(backbone="sage", f_in=g.f, hidden=48, n_out=48,
                    n_layers=2, task="link",
                    codebook=CodebookConfig(k=64, f_prod=4))
    r = train_vq(g, cfg, epochs=15, batch_size=400, eval_every=15)
    assert r["final"]["val"] > 0.1    # hits@50 well above random


def test_lm_training_loss_decreases():
    from repro.configs.registry import get_smoke
    from repro.train.loop import train
    cfg = get_smoke("granite-3-8b")
    out = train(cfg, steps=80, batch=8, seq_len=64, lr=3e-3, log_every=20)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.4, losses


def test_lm_vq_attention_training_loss_decreases():
    from repro.configs.registry import get_smoke
    from repro.train.loop import train
    cfg = get_smoke("granite-3-8b").with_vq(k=16, window=16)
    out = train(cfg, steps=80, batch=8, seq_len=64, lr=3e-3, log_every=20)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.4, losses
