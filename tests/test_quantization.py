"""int8 weight-only serving quantization: accuracy + size contracts."""
import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from repro.configs.registry import get_smoke
from repro.distributed.quantization import (QTensor, dequantize_tree,
                                            quantize_tensor, quantize_tree,
                                            tree_bytes)
from repro.models.lm import init_lm, init_serve_cache, serve_step


def test_tensor_roundtrip_error_bounded():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    t = quantize_tensor(w)
    err = jnp.abs(t.q.astype(jnp.float32) * t.scale - w)
    assert float(err.max()) <= float(t.scale.max()) * 0.51


def test_matmul_relative_error_small():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (16, 128))
    w = jax.random.normal(k2, (128, 64))
    t = quantize_tensor(w)
    y = x @ w
    yq = x @ (t.q.astype(jnp.float32) * t.scale)
    rel = float(jnp.abs(y - yq).mean() / jnp.abs(y).mean())
    assert rel < 0.01, rel


def test_params_tree_halves_and_serves():
    cfg = get_smoke("granite-3-8b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params)
    # >= 2D weights dominate: int8 + scales < 55% of f32 original
    assert tree_bytes(jax.tree_util.tree_map(
        lambda t: t.q if isinstance(t, QTensor) else t, qparams,
        is_leaf=lambda x: isinstance(x, QTensor))) < \
        0.55 * tree_bytes(params)
    deq = dequantize_tree(qparams, jnp.float32)
    # serving path runs unmodified on dequantized weights with close logits
    cache = init_serve_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    l0, _ = serve_step(params, tok, cache, cfg)
    l1, _ = serve_step(deq, tok, cache, cfg)
    top_match = (jnp.argsort(l0, -1)[:, -5:] ==
                 jnp.argsort(l1, -1)[:, -5:]).mean()
    assert float(top_match) > 0.7
    assert_allclose(np.asarray(l0), np.asarray(l1), rtol=0.3, atol=0.3)
