"""HBM-resident SpMM-ELL variant: parity vs the jnp oracle and the
VMEM-resident kernel (interpret mode), stripe-index construction, and the
resident/HBM dispatch heuristic in kernels/ops.py.

The size sweep deliberately includes ``n_src * f`` shapes above the resident
VMEM envelope used by the dispatch tests (the envelope is configurable, and
the 20000x64 case is ~5 MiB of f32 -- past the 4 MiB budget the dispatch
test pins).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.graph.batching import make_stripe_index
from repro.kernels import ops, ref
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.spmm_ell_hbm import (StripeIndex, spmm_ell_hbm_pallas,
                                        stripe_index_jnp)


def _case(b, deg, n, f, dtype=jnp.float32, seed=None):
    key = jax.random.PRNGKey(seed if seed is not None else b * 31 + deg)
    k1, k2, k3 = jax.random.split(key, 3)
    idx = jax.random.randint(k1, (b, deg), 0, n).astype(jnp.int32)
    val = jax.random.normal(k2, (b, deg), jnp.float32)
    x = jax.random.normal(k3, (n, f), dtype)
    return idx, val, x


# ---------------------------------------------------------------------------
# parity: HBM variant vs oracle vs resident kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,deg,n,f", [
    (1, 1, 1, 1),            # degenerate minimum
    (8, 4, 16, 8),           # everything below one tile/stripe
    (33, 7, 50, 12),         # b and n both non-multiples of bb/stripe
    (128, 32, 300, 64),      # multi-tile, multi-stripe
    (200, 9, 3000, 96),      # many stripes per tile
    (257, 5, 20000, 64),     # above the 4 MiB resident envelope (5 MiB f32)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_ell_hbm_sweep(b, deg, n, f, dtype):
    idx, val, x = _case(b, deg, n, f, dtype)
    got = spmm_ell_hbm_pallas(idx, val, x, interpret=True)
    want = ref.spmm_ell(idx, val, x)
    resident = spmm_ell_pallas(idx, val, x, interpret=True)
    tol = dict(rtol=2e-2, atol=1e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(got), np.asarray(want), **tol)
    assert_allclose(np.asarray(got), np.asarray(resident), **tol)


@pytest.mark.parametrize("bb,stripe", [(8, 8), (16, 64), (128, 512),
                                       (32, 24)])  # incl. non-pow2 stripe
def test_spmm_ell_hbm_tile_sizes(bb, stripe):
    """Non-multiple tile sizes: b % bb != 0 and n % stripe != 0."""
    idx, val, x = _case(53, 6, 210, 16)
    got = spmm_ell_hbm_pallas(idx, val, x, bb=bb, stripe=stripe,
                              interpret=True)
    want = ref.spmm_ell(idx, val, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_spmm_ell_hbm_padding_zero_vals():
    """Padding slots carry val == 0; their index may point anywhere valid --
    they must not contribute, nor force a stripe DMA by themselves."""
    idx = jnp.array([[5, 0], [2, 1]], jnp.int32)
    val = jnp.array([[1.0, 0.0], [0.5, 0.0]])   # second slot is padding
    x = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    got = spmm_ell_hbm_pallas(idx, val, x, interpret=True)
    want = jnp.stack([x[5], 0.5 * x[2]])
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_spmm_ell_hbm_all_padding_rows():
    """Rows whose every slot is padding (val == 0 everywhere) come out 0."""
    idx, val, x = _case(40, 4, 100, 8)
    val = val.at[7].set(0.0).at[23].set(0.0)
    got = spmm_ell_hbm_pallas(idx, val, x, bb=16, stripe=32, interpret=True)
    want = ref.spmm_ell(idx, val, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert np.all(np.asarray(got)[7] == 0) and np.all(np.asarray(got)[23] == 0)


# ---------------------------------------------------------------------------
# int8 source rows consumed natively (x_scale epilogue dequant)
# ---------------------------------------------------------------------------

def _quantize_per_channel(x):
    """Per-channel symmetric int8 quantization of a [n, f] f32 matrix."""
    scale = (jnp.max(jnp.abs(x), axis=0, keepdims=True) / 127.0 + 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@pytest.mark.parametrize("b,deg,n,f", [
    (8, 4, 16, 8),
    (33, 7, 50, 12),          # non-multiple tiles
    (128, 16, 3000, 32),      # many stripes per tile
])
def test_spmm_ell_hbm_int8_scale_parity(b, deg, n, f):
    """int8 stripes DMA natively; the epilogue scale must reproduce the
    dequantize-up-front result (scale commutes with the neighbor sum)."""
    idx, val, x = _case(b, deg, n, f)
    q, scale = _quantize_per_channel(x)
    got = spmm_ell_hbm_pallas(idx, val, q, x_scale=scale, interpret=True)
    want = ref.spmm_ell(idx, val, q.astype(jnp.float32) * scale)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_spmm_ell_hbm_int8_matches_resident_q_kernel():
    """Both variants dequantize in-kernel: HBM int8 output matches the
    resident quantized kernel's on the same operands."""
    idx, val, x = _case(60, 6, 400, 16)
    q, scale = _quantize_per_channel(x)
    hbm = spmm_ell_hbm_pallas(idx, val, q, x_scale=scale, bb=32, stripe=64,
                              interpret=True)
    resident = spmm_ell_pallas(idx, val, q, x_scale=scale, interpret=True)
    assert_allclose(np.asarray(hbm), np.asarray(resident),
                    rtol=1e-6, atol=1e-6)


def test_spmm_ell_hbm_int8_precomputed_index():
    idx, val, x = _case(75, 8, 400, 32)
    q, scale = _quantize_per_channel(x)
    si = make_stripe_index(np.asarray(idx), x.shape[0], bb=32, stripe=64)
    got = spmm_ell_hbm_pallas(idx, val, q, si, x_scale=scale,
                              interpret=True)
    want = ref.spmm_ell(idx, val, q.astype(jnp.float32) * scale)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ops_dispatch_routes_hbm_int8(monkeypatch):
    """ops.spmm_ell with an int8 x + x_scale forced onto the HBM variant:
    no up-front dequant materialization, still oracle-parity."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setenv("REPRO_SPMM_VARIANT", "hbm")
    idx, val, x = _case(60, 6, 333, 16)
    q, scale = _quantize_per_channel(x)
    got = ops.spmm_ell(idx, val, q, x_scale=scale)
    want = ref.spmm_ell(idx, val, q.astype(jnp.float32) * scale)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# stripe index: host builder vs in-jit fallback
# ---------------------------------------------------------------------------

def test_stripe_index_host_matches_jnp():
    idx, val, x = _case(90, 5, 700, 8)
    mask = (val != 0).astype(np.float32)
    host = make_stripe_index(np.asarray(idx), x.shape[0],
                             mask=np.asarray(mask), bb=32, stripe=128)
    injit = stripe_index_jnp(idx, val, x.shape[0], bb=32, stripe=128)
    assert host.bb == injit.bb and host.stripe == injit.stripe
    assert np.array_equal(np.asarray(host.counts), np.asarray(injit.counts))
    for t in range(host.ids.shape[0]):
        c = int(host.counts[t])
        assert np.array_equal(np.asarray(host.ids[t, :c]),
                              np.asarray(injit.ids[t, :c]))


def test_spmm_ell_hbm_precomputed_stripe_index():
    """Pack-time host index and the in-jit fallback give identical output."""
    idx, val, x = _case(75, 8, 400, 32)
    si = make_stripe_index(np.asarray(idx), x.shape[0], bb=32, stripe=64)
    got = spmm_ell_hbm_pallas(idx, val, x, si, interpret=True)
    auto = spmm_ell_hbm_pallas(idx, val, x, bb=32, stripe=64, interpret=True)
    want = ref.spmm_ell(idx, val, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(got), np.asarray(auto), rtol=0, atol=0)


def test_stripe_index_mismatched_tiling_raises():
    idx, val, x = _case(64, 4, 256, 8)
    bad = make_stripe_index(np.asarray(idx)[:32], x.shape[0],
                            bb=8, stripe=64)   # built for 4 tiles, not 8
    with pytest.raises(ValueError, match="tiles"):
        spmm_ell_hbm_pallas(idx, val, x, bad, interpret=True)


def test_stripe_index_mismatched_n_src_raises():
    idx, val, x = _case(64, 4, 256, 8)
    bad = make_stripe_index(np.asarray(idx) % 128, 128, bb=8, stripe=64)
    with pytest.raises(ValueError, match="n_src"):
        spmm_ell_hbm_pallas(idx, val, x, bad, interpret=True)


def test_stripe_index_static_shapes_across_batches():
    """Successive packs of the same dataset shapes must produce identical
    StripeIndex shapes (else jit'd train steps retrace every batch)."""
    rng = np.random.default_rng(0)
    shapes = set()
    for _ in range(5):
        idx = rng.integers(0, 777, (60, 6))
        si = make_stripe_index(idx, 777, bb=16, stripe=64)
        shapes.add((si.ids.shape, si.counts.shape, si.bb, si.stripe))
    assert len(shapes) == 1


def test_stripe_index_max_stripes_cap():
    rng = np.random.default_rng(1)
    idx = rng.integers(0, 1000, (32, 8))
    si = make_stripe_index(idx, 1000, bb=8, stripe=64, max_stripes=8 * 8)
    assert si.ids.shape[1] == 64
    with pytest.raises(ValueError, match="max_stripes"):
        make_stripe_index(idx, 1000, bb=8, stripe=8, max_stripes=2)


# ---------------------------------------------------------------------------
# ops.py dispatch heuristic
# ---------------------------------------------------------------------------

def test_spmm_variant_heuristic(monkeypatch):
    monkeypatch.delenv("REPRO_SPMM_VARIANT", raising=False)
    monkeypatch.setenv("REPRO_SPMM_VMEM_BUDGET_MB", "4")
    assert ops.spmm_ell_variant(512, 64) == "resident"
    assert ops.spmm_ell_variant(20000, 64) == "hbm"       # 5 MiB > 4 MiB
    monkeypatch.setenv("REPRO_SPMM_VARIANT", "resident")
    assert ops.spmm_ell_variant(20000, 64) == "resident"
    monkeypatch.setenv("REPRO_SPMM_VARIANT", "hbm")
    assert ops.spmm_ell_variant(8, 8) == "hbm"


def test_spmm_variant_configure(monkeypatch):
    monkeypatch.delenv("REPRO_SPMM_VARIANT", raising=False)
    monkeypatch.delenv("REPRO_SPMM_VMEM_BUDGET_MB", raising=False)
    try:
        ops.configure_spmm_dispatch(variant="hbm")
        assert ops.spmm_ell_variant(8, 8) == "hbm"
        ops.configure_spmm_dispatch(variant="auto", vmem_budget_mb=0.001)
        assert ops.spmm_ell_variant(64, 64) == "hbm"
        with pytest.raises(ValueError):
            ops.configure_spmm_dispatch(variant="nope")
    finally:
        ops._dispatch_overrides.clear()


def test_spmm_variant_configure_reset(monkeypatch):
    """reset=True drops programmatic overrides instead of leaking them
    between test/benchmark cases."""
    monkeypatch.delenv("REPRO_SPMM_VARIANT", raising=False)
    monkeypatch.delenv("REPRO_SPMM_VMEM_BUDGET_MB", raising=False)
    try:
        ops.configure_spmm_dispatch(variant="hbm", vmem_budget_mb=0.001)
        assert ops.spmm_ell_variant(8, 8) == "hbm"
        ops.configure_spmm_dispatch(reset=True)
        assert not ops._dispatch_overrides
        assert ops.spmm_ell_variant(8, 8) == "resident"   # back to defaults
        # reset composes with new settings in one call
        ops.configure_spmm_dispatch(variant="hbm", reset=True)
        assert ops._dispatch_overrides == {"variant": "hbm"}
    finally:
        ops._dispatch_overrides.clear()


def test_ops_dispatch_routes_hbm(monkeypatch):
    """Forced-pallas + forced-hbm: ops.spmm_ell runs the HBM kernel and
    still matches the oracle."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setenv("REPRO_SPMM_VARIANT", "hbm")
    idx, val, x = _case(60, 6, 333, 16)
    got = ops.spmm_ell(idx, val, x)
    want = ref.spmm_ell(idx, val, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_full_graph_apply_with_stripe_index(monkeypatch):
    """GCN full-graph oracle is unchanged when routed through the HBM
    variant with a pack-time stripe index."""
    from repro.graph.batching import full_operands
    from repro.graph.structure import build_graph
    from repro.nn.gnn_layers import GCN

    rng = np.random.default_rng(0)
    n, m = 120, 600
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    feats = rng.normal(size=(n, 16)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    tr = np.arange(n)
    g = build_graph(src, dst, n, feats, labels, (tr, tr, tr))

    p = GCN.init(jax.random.PRNGKey(0), g.features.shape[1], 8)
    x = jnp.asarray(g.features)
    y_plain = GCN.full_apply(p, x, full_operands(g), jax.nn.relu)

    # now force every spmm through the HBM Pallas kernel (interpret mode)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    monkeypatch.setenv("REPRO_SPMM_VARIANT", "hbm")
    ops_hbm = full_operands(g, stripe_index=True, stripe_bb=32, stripe=32)
    assert isinstance(ops_hbm.stripe_index, StripeIndex)
    y_hbm = GCN.full_apply(p, x, ops_hbm, jax.nn.relu)
    assert_allclose(np.asarray(y_hbm), np.asarray(y_plain),
                    rtol=1e-5, atol=1e-5)
