"""Scenario-matrix registry tests (ISSUE 6): pin the GNN backbone set,
pin the scale-method axis, and enforce the LM-config quarantine -- the
llama/whisper/moe seeds of ``configs.registry`` must never enumerate as
matrix cells."""
import pytest

from repro.configs.scenarios import (MATRIX_BACKBONES, MATRIX_TASKS,
                                     SCENARIO_KNOBS, assert_gnn_only,
                                     matrix_cells)
from repro.nn.gnn_layers import BACKBONES
from repro.train.gnn_trainer import SCALE_METHODS


def test_backbone_set_pinned():
    """The matrix enumerates exactly the paper's Table 2 convolution
    types; a new registration in nn.gnn_layers must be reviewed here
    before it widens the CI matrix."""
    assert set(MATRIX_BACKBONES) == {"gcn", "sage", "gat", "gin",
                                     "transformer"}
    assert set(MATRIX_BACKBONES) == set(BACKBONES)


def test_scale_methods_pinned():
    assert SCALE_METHODS == ("full", "vq", "ns_sage", "labor", "cluster",
                             "saint", "hybrid")
    assert MATRIX_TASKS == ("node", "link")


def test_matrix_cells_enumerate_gnn_only():
    cells = matrix_cells(tasks=("node",))
    assert len(cells) == len(MATRIX_BACKBONES) * len(SCALE_METHODS)
    backbones = {b for b, _, _ in cells}
    assert_gnn_only(backbones)            # no LM arch ids leaked


def test_lm_archs_quarantined():
    """Every id of the generic LM/speech/vision registry must FAIL the
    GNN-only guard -- the quarantine the scenario matrix depends on."""
    from repro.configs.registry import ARCHS, LM_ARCHS
    assert ARCHS is LM_ARCHS              # back-compat alias intact
    assert len(LM_ARCHS) >= 10
    for name in LM_ARCHS:
        with pytest.raises(ValueError, match="leaked|unknown"):
            assert_gnn_only([name])
    # and none of them collides with a GNN backbone name
    assert not set(LM_ARCHS) & set(MATRIX_BACKBONES)


def test_knobs_documented():
    for knob in ("REPRO_SCALE_METHOD", "REPRO_SAMPLER_FANOUT",
                 "REPRO_WALK_LENGTH", "REPRO_N_PARTS", "REPRO_HYBRID_CTX",
                 "REPRO_SAMPLER_EXECUTOR"):
        assert knob in SCENARIO_KNOBS


def test_train_scenario_smoke():
    """One tiny end-to-end cell per trainer family through the dispatch
    front (full / vq / one sampler / hybrid)."""
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import GNNConfig
    from repro.core.codebook import CodebookConfig
    from repro.train.gnn_trainer import train_scenario
    g = synthetic_arxiv(n=200, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=16,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=16, f_prod=4))
    for method in ("full", "vq", "saint", "hybrid"):
        r = train_scenario(g, cfg, method, epochs=1, batch_size=64,
                           eval_every=1)
        assert "val" in r["final"], method
