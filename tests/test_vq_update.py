"""Fused VQ assign+stats kernel (kernels/vq_update.py) validation.

Parity of (assignment, counts, sums, qerr) against the jnp oracle over b/k/f
edge shapes, the optional min-distance output of vq_assign, and the
codebook.update equivalence old-path (one-hot einsum) vs fused-path --
including the dead-codeword revival branch.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import codebook as cbm
from repro.core.codebook import CodebookConfig, CodebookState
from repro.kernels import ref
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_update import vq_assign_update_pallas


# ---------------------------------------------------------------------------
# kernel vs oracle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,f", [
    (1, 1, 1),            # degenerate minimum
    (7, 3, 5),            # everything tiny and non-multiple
    (130, 33, 12),        # non-multiples of bb/kb/lane width
    (64, 16, 4),          # paper-ish f_blk
    (100, 1024, 8),       # b < bb, k spanning two k-tiles
    (256, 300, 128),      # k < kb after clamping, full lane width
    (520, 256, 8),        # b spanning three b-tiles, paper-scale k
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vq_update_parity_sweep(b, k, f, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(b * 131 + k))
    x = jax.random.normal(kx, (b, f), dtype)
    c = jax.random.normal(kc, (k, f), dtype)
    gi, gq, gc, gs = vq_assign_update_pallas(x, c, interpret=True)
    wi, wq, wc, ws = ref.vq_assign_update(x, c)

    assert gi.shape == (b,) and gq.shape == (b,)
    assert gc.shape == (k,) and gs.shape == (k, f)

    # ties can legitimately differ: accept either argmin when distances tie
    x32, c32 = x.astype(jnp.float32), c.astype(jnp.float32)
    d = ((x32[:, None] - c32[None]) ** 2).sum(-1)
    d_got = jnp.take_along_axis(d, gi[:, None].astype(jnp.int32), 1)[:, 0]
    d_want = jnp.take_along_axis(d, wi[:, None].astype(jnp.int32), 1)[:, 0]
    assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-5,
                    atol=1e-5)
    assert_allclose(np.asarray(gq), np.asarray(wq), rtol=1e-4, atol=1e-4)
    # stats compare exactly when assignments agree (random normals: no ties)
    if (np.asarray(gi) == np.asarray(wi)).all():
        assert_allclose(np.asarray(gc), np.asarray(wc), rtol=0, atol=0)
        assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-5, atol=1e-5)
    assert float(gc.sum()) == b   # every (unpadded) row counted exactly once


def test_vq_update_qerr_is_true_distance():
    """qerr must equal the squared distance to the assigned codeword."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (97, 24))
    c = jax.random.normal(jax.random.PRNGKey(1), (40, 24))
    gi, gq, _, _ = vq_assign_update_pallas(x, c, interpret=True)
    want = ((np.asarray(x) - np.asarray(c)[np.asarray(gi)]) ** 2).sum(-1)
    assert_allclose(np.asarray(gq), want, rtol=1e-4, atol=1e-4)


def test_vq_update_padded_rows_excluded_from_stats():
    """b far from a bb multiple: padded rows must not leak into counts."""
    b, k, f = 9, 5, 3
    x = jax.random.normal(jax.random.PRNGKey(2), (b, f))
    c = jax.random.normal(jax.random.PRNGKey(3), (k, f))
    _, _, counts, sums = vq_assign_update_pallas(x, c, interpret=True)
    assert float(counts.sum()) == b
    assert_allclose(np.asarray(sums.sum(0)), np.asarray(x.sum(0)),
                    rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# vq_assign optional min-distance output (the former `del val` dead output)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,f", [(7, 3, 5), (130, 33, 12), (100, 300, 8)])
def test_vq_assign_want_min(b, k, f):
    kx, kc = jax.random.split(jax.random.PRNGKey(b + k))
    x = jax.random.normal(kx, (b, f))
    c = jax.random.normal(kc, (k, f))
    idx, mind = vq_assign_pallas(x, c, interpret=True, want_min=True)
    idx_only = vq_assign_pallas(x, c, interpret=True)
    assert (np.asarray(idx) == np.asarray(idx_only)).all()
    want = ((np.asarray(x) - np.asarray(c)[np.asarray(idx)]) ** 2).sum(-1)
    assert_allclose(np.asarray(mind), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# codebook.update equivalence: old one-hot path vs fused path
# ---------------------------------------------------------------------------

def _reference_update(state, feats, grads, cfg):
    """The pre-fusion update math: separate assign, one-hot einsum stats,
    recomputed revival distances.  Kept here as the equivalence oracle."""
    n = state.n_branches
    v = jnp.concatenate(
        [cbm._split_branches(feats.astype(jnp.float32), n),
         cbm._split_branches(grads.astype(jnp.float32), n)], axis=-1)
    b = v.shape[1]
    batch_mean = jnp.mean(v, axis=1)
    batch_var = jnp.var(v, axis=1)
    if cfg.whiten:
        new_mean = state.mean * cfg.beta + batch_mean * (1.0 - cfg.beta)
        new_var = state.var * cfg.beta + batch_var * (1.0 - cfg.beta)
        vw = jax.vmap(lambda x, m, s: cbm._whiten(x, m, s, cfg.eps))(
            v, new_mean, new_var)
    else:
        new_mean, new_var = state.mean, state.var
        vw = v
    assignment = jax.vmap(ref.vq_assign)(vw, state.codewords_w)
    onehot = jax.nn.one_hot(assignment, cfg.k, dtype=vw.dtype)
    counts = jnp.sum(onehot, axis=1)
    sums = jnp.einsum('nbk,nbf->nkf', onehot, vw)
    new_size = state.cluster_size * cfg.gamma + counts * (1.0 - cfg.gamma)
    new_sum = state.cluster_sum * cfg.gamma + sums * (1.0 - cfg.gamma)
    new_cw = new_sum / jnp.maximum(new_size, cfg.eps)[..., None]
    alive = (new_size > 1e-3)[..., None]
    new_cw = jnp.where(alive, new_cw, state.codewords_w)
    if cfg.revive_threshold > 0:
        # true per-row quantization error ||vw_i - c_{a_i}||^2.  (The
        # pre-fusion code gathered vv[aa] -- batch rows indexed by CODEWORD
        # id -- which ranked the wrong rows for revival; the fused kernel's
        # emitted qerr is the correct per-row quantity, so the reference
        # uses the corrected formula here.)
        sel = jax.vmap(lambda vv, cc, aa: vv - cc[aa])(
            vw, state.codewords_w, assignment)
        qerr = jnp.sum(sel * sel, axis=-1)
        n_rev = min(cfg.k, b)
        _, worst = jax.lax.top_k(qerr, n_rev)
        worst_rows = jax.vmap(lambda vv, ww: vv[ww])(vw, worst)
        dead = new_size < cfg.revive_threshold
        rank = jnp.cumsum(dead.astype(jnp.int32), axis=1) - 1
        rank = jnp.clip(rank, 0, n_rev - 1)
        repl = jax.vmap(lambda wr, rk: wr[rk])(worst_rows, rank)
        new_cw = jnp.where(dead[..., None], repl, new_cw)
        new_size = jnp.where(dead, 1.0, new_size)
        new_sum = jnp.where(dead[..., None], repl, new_sum)
    return CodebookState(new_cw, new_size, new_sum, new_mean, new_var,
                         state.step + 1), assignment


def _states_allclose(got: CodebookState, want: CodebookState,
                     tol: float = 1e-4):
    for name, a, b in [("codewords_w", got.codewords_w, want.codewords_w),
                       ("cluster_size", got.cluster_size, want.cluster_size),
                       ("cluster_sum", got.cluster_sum, want.cluster_sum),
                       ("mean", got.mean, want.mean),
                       ("var", got.var, want.var)]:
        assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol,
                        err_msg=name)


@pytest.mark.parametrize("revive", [0.0, 0.05])
def test_update_equivalence_old_vs_fused(revive):
    """cbm.update (fused stats) == the unfused one-hot reference,
    including the revival branch.  For revive > 0 the codebook starts far
    away AND with near-zero EMA sizes so codewords genuinely die and the
    revival branch actually executes (asserted below, not assumed)."""
    cfg = CodebookConfig(k=16, f_prod=4, revive_threshold=revive)
    key = jax.random.PRNGKey(0)
    state = cbm.init_codebook(key, 8, 8, cfg)
    if revive > 0:   # far-away codewords + starved EMA sizes -> real deaths
        state = state._replace(
            codewords_w=state.codewords_w + 100.0,
            cluster_size=jnp.full_like(state.cluster_size, 1e-4))
    feats = jax.random.normal(key, (64, 8))
    grads = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    revived_any = False
    for _ in range(3):
        got_state, got_stats = cbm.update(state, feats, grads, cfg)
        want_state, want_assign = _reference_update(state, feats, grads, cfg)
        assert (np.asarray(got_stats.assignment)
                == np.asarray(want_assign)).all()
        _states_allclose(got_state, want_state)
        new_size = state.cluster_size * cfg.gamma \
            + jax.vmap(lambda a: jnp.zeros((cfg.k,)).at[a].add(1.0))(
                got_stats.assignment) * (1.0 - cfg.gamma)
        revived_any |= bool((np.asarray(new_size) < revive).any())
        state = got_state
    if revive > 0:
        assert revived_any   # the branch under test actually fired


def test_update_fused_pallas_path_matches_cpu_path(monkeypatch):
    """REPRO_FORCE_PALLAS=1 routes the update through the interpret-mode
    fused kernel; the resulting state must match the CPU (oracle) path."""
    cfg = CodebookConfig(k=16, f_prod=4)
    key = jax.random.PRNGKey(0)
    state = cbm.init_codebook(key, 8, 8, cfg)
    feats = jax.random.normal(key, (48, 8))
    grads = jax.random.normal(jax.random.PRNGKey(1), (48, 8))

    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    cpu_state, cpu_stats = cbm.update(state, feats, grads, cfg)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    pls_state, pls_stats = cbm.update(state, feats, grads, cfg)

    assert (np.asarray(cpu_stats.assignment)
            == np.asarray(pls_stats.assignment)).all()
    assert_allclose(np.asarray(cpu_stats.qerr), np.asarray(pls_stats.qerr),
                    rtol=1e-4, atol=1e-4)
    _states_allclose(pls_state, cpu_state)


@pytest.mark.skipif(
    os.environ.get("REPRO_FORCE_PALLAS", "0") == "1",
    reason="end-to-end trainer test: reverse-mode AD has no rule for the "
    "interpret-mode SpMM pallas_call; kernel parity is covered above and "
    "this test runs in tier-1")
def test_train_vq_small_graph_pads_single_batch(monkeypatch):
    """batch_size > n used to yield NO mini-batch (the tail-drop bug, and a
    jnp.mean(None) crash risk in the vq_err monitor).  epoch_slices now
    clamps to one full-pool batch, so the epoch trains and the monitor is
    present -- on both executor paths."""
    from repro.graph.datasets import synthetic_arxiv
    from repro.models.gnn import GNNConfig
    from repro.train.gnn_trainer import train_vq
    g = synthetic_arxiv(n=60, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=8, n_out=g.num_classes,
                    n_layers=1, codebook=CodebookConfig(k=8, f_prod=4))
    r = train_vq(g, cfg, epochs=1, batch_size=g.n + 40, eval_every=1)
    assert "val" in r["final"] and "vq_err" in r["final"]
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "0")
    r = train_vq(g, cfg, epochs=1, batch_size=g.n + 40, eval_every=1)
    assert "val" in r["final"] and "vq_err" in r["final"]


def test_update_stats_relative_error_matches_manual():
    cfg = CodebookConfig(k=8, f_prod=4, whiten=False, beta=0.0)
    key = jax.random.PRNGKey(0)
    state = cbm.init_codebook(key, 8, 8, cfg)
    feats = jax.random.normal(key, (32, 8))
    grads = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    _, stats = cbm.update(state, feats, grads, cfg)
    n = state.n_branches
    v = jnp.concatenate(
        [cbm._split_branches(feats, n), cbm._split_branches(grads, n)], -1)
    recon = jax.vmap(lambda c, a: c[a])(state.codewords_w, stats.assignment)
    want = jnp.sqrt(((v - recon) ** 2).sum() / (v ** 2).sum())
    assert_allclose(float(stats.relative_error()), float(want), rtol=1e-4)
