"""Epoch-executor tests (DESIGN.md section 9): vectorized packing oracle,
in-jit plan batches vs the host packer, scan-vs-per-step-loop numerical
equivalence, tail-batch padding semantics, and single-vs-multi-device
shard_map parity (natively when >= 2 devices exist -- the CI tier-1 matrix
2-device entry -- and via an XLA_FLAGS subprocess everywhere else)."""
import os
import subprocess
import sys
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core.codebook import CodebookConfig
from repro.core.conv import init_layer_vq_state
from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                  full_operands, make_pack, minibatch_stream,
                                  plan_batch)
from repro.graph.datasets import synthetic_arxiv
from repro.graph.structure import CSR
from repro.models.gnn import (GNNConfig, init_gnn, init_vq_states,
                              vq_train_epoch, vq_train_step)
from repro.train.optimizer import rmsprop

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
PACK_FIELDS = ("batch_ids", "nbr_ids", "nbr_mask", "nbr_pos",
               "rev_ids", "rev_mask", "rev_pos")


def _copy(tree):
    """vq_train_epoch donates its carry buffers; tests that reuse the same
    initial state across paths must hand each call its own copy."""
    return jax.tree_util.tree_map(lambda a: a.copy(), tree)


@pytest.fixture(scope="module")
def g():
    return synthetic_arxiv(n=300, seed=0)


@pytest.fixture(scope="module")
def setup(g):
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=32,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=32, f_prod=4))
    ops = full_operands(g)
    tm = np.zeros(g.n, np.float32)
    tm[g.train_idx] = 1.0
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    opt = rmsprop(3e-3)
    return dict(cfg=cfg, ops=ops, x=jnp.asarray(g.features),
                labels=jnp.asarray(g.labels), tm_np=tm,
                tm=jnp.asarray(tm), params=params, vq=vq, opt=opt,
                ost=opt.init(params), plan=build_epoch_plan(g))


# ---------------------------------------------------------------------------
# packing layer
# ---------------------------------------------------------------------------

def test_vectorized_pack_rows_matches_loop_reference(g):
    """The CSR-sliced _pack_rows equals the per-row reference on real and
    degree-capped rows."""
    from repro.graph.batching import _pack_rows
    rng = np.random.default_rng(3)
    ids = rng.permutation(g.n)[:64]
    inv = np.full(g.n, -1, np.int32)
    inv[ids] = np.arange(len(ids), dtype=np.int32)
    for csr, cap in [(g.in_csr, g.max_degree()), (g.out_csr, 3)]:
        nbr, mask, pos = _pack_rows(csr, ids, cap, inv)
        for r, i in enumerate(ids):
            ns = csr.neighbors(i)[:cap]
            d = len(ns)
            assert np.array_equal(nbr[r, :d], ns)
            assert np.all(nbr[r, d:] == 0)
            assert np.all(mask[r, :d] == 1.0) and np.all(mask[r, d:] == 0)
            assert np.array_equal(pos[r, :d], inv[ns])
            assert np.all(pos[r, d:] == -1)


def test_pack_rows_empty_graph():
    from repro.graph.batching import _pack_rows
    csr = CSR(indptr=np.zeros(5, np.int64), indices=np.zeros(0, np.int32))
    nbr, mask, pos = _pack_rows(csr, np.arange(4), 3, np.zeros(4, np.int32))
    assert nbr.shape == (4, 3) and not mask.any() and (pos == -1).all()


def test_plan_batch_matches_make_pack(g, setup):
    ids = np.random.default_rng(0).permutation(g.n)[:64]
    host = make_pack(g, ids)
    jit_pack = jax.jit(plan_batch)(setup["plan"],
                                   jnp.asarray(ids.astype(np.int32)))
    for name in PACK_FIELDS:
        assert np.array_equal(np.asarray(getattr(host, name)),
                              np.asarray(getattr(jit_pack, name))), name


# ---------------------------------------------------------------------------
# tail-batch padding (the old stream silently dropped up to b-1 nodes)
# ---------------------------------------------------------------------------

def test_epoch_slices_cover_pool_and_mask_padding():
    perm = np.random.default_rng(1).permutation(10)
    ids, smask = epoch_slices(perm, 4)
    assert ids.shape == (3, 4) and smask.shape == (3, 4)
    # every pool node appears among the unmasked slots exactly once
    real = ids[smask > 0]
    assert sorted(real.tolist()) == sorted(perm.tolist())
    # padding wraps to the start of the permutation and is masked
    assert np.array_equal(ids[-1, 2:], perm[:2])
    assert np.array_equal(smask[-1], [1, 1, 0, 0])


def test_epoch_slices_pool_smaller_than_batch():
    """batch_size clamps to the pool: one duplicate-free unpadded batch
    (duplicate ids inside a batch would corrupt the refresh counts)."""
    ids, smask = epoch_slices(np.asarray([7, 3]), 8)
    assert ids.shape == (1, 2)
    assert smask.sum() == 2.0
    assert sorted(ids[0].tolist()) == [3, 7]


def test_epoch_slices_batches_never_contain_duplicates():
    rng = np.random.default_rng(2)
    for n, b in [(10, 4), (10, 10), (10, 99), (7, 3), (300, 128)]:
        ids, smask = epoch_slices(rng.permutation(n), b)
        for row in ids:
            assert len(set(row.tolist())) == len(row), (n, b)


def test_minibatch_stream_traverses_all_nodes(g):
    rng = np.random.default_rng(0)
    seen = np.zeros(g.n, np.int64)
    n_batches = 0
    for pack in minibatch_stream(g, 128, rng):
        assert pack.slot_mask is not None
        bidx = np.asarray(pack.batch_ids)
        sm = np.asarray(pack.slot_mask)
        seen[bidx[sm > 0]] += 1
        n_batches += 1
    assert n_batches == -(-g.n // 128)     # ceil: the tail is not dropped
    assert (seen == 1).all()               # the node_loss freshness contract


# ---------------------------------------------------------------------------
# scan epoch vs per-step loop (fixed seed -> same states)
# ---------------------------------------------------------------------------

def test_scan_epoch_matches_per_step_loop(g, setup):
    s = setup
    bids, smask = epoch_slices(
        np.random.default_rng(7).permutation(g.n), 128)

    p_l, vq_l, o_l = _copy((s["params"], s["vq"], s["ost"]))
    for i in range(bids.shape[0]):
        pack = make_pack(g, bids[i], slot_mask=smask[i])
        lm = jnp.asarray(s["tm_np"][bids[i]] * smask[i])
        p_l, vq_l, o_l, _, _, _ = vq_train_step(
            p_l, vq_l, o_l, pack, s["x"][bids[i]], s["labels"][bids[i]],
            s["ops"].degrees, s["cfg"], s["opt"], loss_mask=lm)

    p_s, vq_s, o_s, losses, errs = vq_train_epoch(
        *_copy((s["params"], s["vq"], s["ost"])), s["plan"],
        jnp.asarray(bids.astype(np.int32)), jnp.asarray(smask), s["x"],
        s["labels"], s["tm"], s["ops"].degrees, s["cfg"], s["opt"])

    assert losses.shape == (bids.shape[0],)
    assert errs.shape == (bids.shape[0], s["cfg"].n_layers)
    for a, b in zip(jax.tree_util.tree_leaves((p_l, vq_l, o_l)),
                    jax.tree_util.tree_leaves((p_s, vq_s, o_s))):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)


def test_trainer_rejects_mesh_without_epoch_executor(g, setup, monkeypatch):
    """An explicit data-parallel request must never silently fall back to
    single-device training."""
    from repro.distributed.data_parallel import graph_dp_mesh
    from repro.train.gnn_trainer import train_vq
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "0")
    with pytest.raises(ValueError, match="epoch executor"):
        train_vq(g, setup["cfg"], epochs=1, batch_size=128,
                 mesh=graph_dp_mesh(1))


def test_trainer_env_gate_paths_agree(g, setup, monkeypatch):
    """train_vq end-to-end: epoch executor (default) vs the
    REPRO_EPOCH_EXECUTOR=0 per-step fallback on the same seed."""
    from repro.train.gnn_trainer import train_vq
    cfg = setup["cfg"]
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "0")
    r_loop = train_vq(g, cfg, epochs=2, batch_size=128, eval_every=2)
    monkeypatch.setenv("REPRO_EPOCH_EXECUTOR", "1")
    r_scan = train_vq(g, cfg, epochs=2, batch_size=128, eval_every=2)
    for a, b in zip(jax.tree_util.tree_leaves(r_loop["params"]),
                    jax.tree_util.tree_leaves(r_scan["params"])):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=1e-5)
    assert r_loop["final"]["val"] == pytest.approx(
        r_scan["final"]["val"], abs=0.02)


# ---------------------------------------------------------------------------
# PRNG hygiene
# ---------------------------------------------------------------------------

def test_init_vq_state_key_is_split():
    """The codebook init and the random assignment must not consume the
    same key (the seed-repo bug reused it verbatim)."""
    key = jax.random.PRNGKey(5)
    cfg = CodebookConfig(k=16, f_prod=4)
    st = init_layer_vq_state(key, 50, 8, 8, cfg)
    reused = jax.random.randint(
        key, (st.codebook.n_branches, 50), 0, cfg.k).astype(jnp.int32)
    assert not np.array_equal(np.asarray(st.assignment), np.asarray(reused))


# ---------------------------------------------------------------------------
# shard_map data parallelism
# ---------------------------------------------------------------------------

def test_dp_single_device_mesh_matches_scan(g, setup):
    """ndev=1 instantiation of the dp executor == vq_train_epoch."""
    from repro.distributed.data_parallel import (graph_dp_mesh,
                                                 vq_train_epoch_dp)
    s = setup
    bids, smask = epoch_slices(
        np.random.default_rng(7).permutation(g.n), 128)
    bids_d = jnp.asarray(bids.astype(np.int32))
    smask_d = jnp.asarray(smask)
    args = (s["plan"], bids_d, smask_d, s["x"], s["labels"], s["tm"],
            s["ops"].degrees, s["cfg"], s["opt"])
    out_dp = vq_train_epoch_dp(graph_dp_mesh(1),
                               *_copy((s["params"], s["vq"], s["ost"])),
                               *args)
    out = vq_train_epoch(*_copy((s["params"], s["vq"], s["ost"])), *args)
    for a, b in zip(jax.tree_util.tree_leaves(out_dp[:4]),
                    jax.tree_util.tree_leaves(out[:4])):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)


def test_dp_codebook_revival_identical_across_replicas():
    """Dead-codeword revival must pick replacement rows from the GLOBAL
    batch under data parallelism: the dead mask is replica-identical
    (psum'd sizes), so replica-local picks would silently diverge the
    'replicated' codebooks.  Exercised via the vmap collective oracle with
    an extreme revive threshold that marks every codeword dead."""
    from repro.core import codebook as cbm
    cfg = CodebookConfig(k=8, f_prod=4, revive_threshold=2.0)
    key = jax.random.PRNGKey(0)
    state = cbm.init_codebook(key, 8, 8, cfg)
    feats = jax.random.normal(key, (2, 16, 8))          # 2 replica shards
    grads = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    new_state, _ = jax.vmap(
        lambda f, g: cbm.update(state, f, g, cfg, axis_name="i"),
        axis_name="i")(feats, grads)
    for leaf in jax.tree_util.tree_leaves(new_state):
        lanes = np.asarray(leaf)
        assert_allclose(lanes[0], lanes[1], rtol=0, atol=0)


def test_graph_dp_mesh_rejects_overprovisioning():
    from repro.distributed.sharding import graph_dp_mesh
    with pytest.raises(ValueError, match="device"):
        graph_dp_mesh(len(jax.devices()) + 1)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=2)")
def test_dp_two_device_mesh_matches_vmap_oracle(g, setup):
    """shard_map over a 2-device mesh == the same body under
    jax.vmap(axis_name=...): all cross-replica math (grad psum, codebook
    stats psum, assignment all_gather) agrees with the collective-free
    oracle."""
    from repro.distributed.data_parallel import (graph_dp_mesh,
                                                 vq_train_epoch_dp)
    from repro.models.gnn import _vq_epoch_body
    s = setup
    bids, smask = epoch_slices(
        np.random.default_rng(7).permutation(g.n), 128)
    bids_d = jnp.asarray(bids.astype(np.int32))
    smask_d = jnp.asarray(smask)
    out2 = vq_train_epoch_dp(
        graph_dp_mesh(2), *_copy((s["params"], s["vq"], s["ost"])),
        s["plan"], bids_d, smask_d, s["x"], s["labels"], s["tm"],
        s["ops"].degrees, s["cfg"], s["opt"])

    S, b = bids.shape
    bl = b // 2
    perm_sh = bids_d.reshape(S, 2, bl).transpose(1, 0, 2)
    sm_sh = smask_d.reshape(S, 2, bl).transpose(1, 0, 2)
    body = functools.partial(_vq_epoch_body, cfg=s["cfg"], opt=s["opt"],
                             axis_name="data")
    ref = jax.vmap(body, in_axes=(None, None, None, None, 0, 0,
                                  None, None, None, None),
                   axis_name="data")(
        *_copy((s["params"], s["vq"], s["ost"])), s["plan"], perm_sh,
        sm_sh, s["x"], s["labels"], s["tm"], s["ops"].degrees)
    for a, b_ in zip(jax.tree_util.tree_leaves(out2[:4]),
                     jax.tree_util.tree_leaves(ref[:4])):
        # vmap stacks the (identical) replicas; compare against lane 0
        assert_allclose(np.asarray(a), np.asarray(b_)[0],
                        rtol=5e-4, atol=1e-5)


@pytest.mark.skipif(len(jax.devices()) >= 2,
                    reason="runs natively on this host")
def test_dp_two_device_parity_subprocess():
    """Single-device hosts still exercise the 2-device parity: rerun the
    native test above in a subprocess with two virtual CPU devices (the
    XLA_FLAGS override must precede jax init, hence the fresh process)."""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__),
         "-k", "dp_two_device_mesh_matches_vmap_oracle"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(SRC))
    assert "1 passed" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
