"""Per-kernel validation: shape/dtype sweeps vs the ref.py jnp oracles,
executed in interpret mode (the sanctioned CPU path for Pallas TPU kernels).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

try:  # property tests are optional: skip (not error) without hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas


# ---------------------------------------------------------------------------
# vq_assign
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,f", [(1, 1, 1), (7, 3, 5), (64, 16, 4),
                                   (130, 33, 12), (256, 512, 128),
                                   (100, 1024, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vq_assign_sweep(b, k, f, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(b * 131 + k))
    x = jax.random.normal(kx, (b, f), dtype)
    c = jax.random.normal(kc, (k, f), dtype)
    got = vq_assign_pallas(x, c, interpret=True)
    want = ref.vq_assign(x, c)
    # ties can legitimately differ: accept either when distances are equal
    x32, c32 = x.astype(jnp.float32), c.astype(jnp.float32)
    d = ((x32[:, None] - c32[None]) ** 2).sum(-1)
    d_got = jnp.take_along_axis(d, got[:, None].astype(jnp.int32), 1)[:, 0]
    d_want = jnp.take_along_axis(d, want[:, None].astype(jnp.int32), 1)[:, 0]
    assert_allclose(np.asarray(d_got), np.asarray(d_want), rtol=1e-5,
                    atol=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(b=st.integers(1, 40), k=st.integers(1, 40), f=st.integers(1, 24))
    def test_vq_assign_hypothesis(b, k, f):
        kx, kc = jax.random.split(jax.random.PRNGKey(b * 7919 + k * 31 + f))
        x = jax.random.normal(kx, (b, f))
        c = jax.random.normal(kc, (k, f))
        got = vq_assign_pallas(x, c, interpret=True)
        assert got.shape == (b,)
        assert int(got.min()) >= 0 and int(got.max()) < k
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_vq_assign_hypothesis():
        pass


# ---------------------------------------------------------------------------
# spmm_ell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,deg,n,f", [(1, 1, 1, 1), (8, 4, 16, 8),
                                       (33, 7, 50, 12), (128, 32, 300, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_ell_sweep(b, deg, n, f, dtype):
    key = jax.random.PRNGKey(b + deg * 100)
    k1, k2, k3 = jax.random.split(key, 3)
    idx = jax.random.randint(k1, (b, deg), 0, n)
    val = jax.random.normal(k2, (b, deg), jnp.float32)
    x = jax.random.normal(k3, (n, f), dtype)
    got = spmm_ell_pallas(idx, val, x, interpret=True)
    want = ref.spmm_ell(idx, val, x)
    assert_allclose(np.asarray(got), np.asarray(want),
                    rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                    atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_spmm_ell_padding_zero_vals():
    idx = jnp.array([[5, 0], [2, 1]], jnp.int32)
    val = jnp.array([[1.0, 0.0], [0.5, 0.0]])   # second slot is padding
    x = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    got = spmm_ell_pallas(idx, val, x, interpret=True)
    want = jnp.stack([x[5], 0.5 * x[2]])
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 32), (2, 3, 256, 64),
                                     (1, 2, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, s, d, causal):
    key = jax.random.PRNGKey(s + d)
    q, k, v = (jax.random.normal(kk, (b, h, s, d))
               for kk in jax.random.split(key, 3))
    got = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (1, 2, 256, 64), jnp.bfloat16)
               for kk in jax.random.split(key, 3))
    got = flash_attention_pallas(q, k, v, causal=True, bq=128, bk=128,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# vq_attention decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,g,d,kcb,w", [(1, 1, 8, 4, 4), (4, 2, 32, 16, 8),
                                         (6, 4, 64, 128, 32)])
def test_vq_attention_decode_sweep(n, g, d, kcb, w):
    key = jax.random.PRNGKey(n * 17 + kcb)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (n, g, d))
    cbk = jax.random.normal(ks[1], (n, kcb, d))
    cbv = jax.random.normal(ks[2], (n, kcb, d))
    mass = jnp.abs(jax.random.normal(ks[3], (n, kcb))) + 0.1
    wk = jax.random.normal(ks[4], (n, w, d))
    wv = jax.random.normal(ks[5], (n, w, d))
    wm = jnp.ones((n, w))
    got = vq_attention_decode_pallas(q, cbk, cbv, mass, wk, wv, wm,
                                     interpret=True)
    want = jax.vmap(lambda *a: ref.vq_attention_decode(*a))(
        q, cbk, cbv, mass, wk, wv, wm)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_vq_attention_decode_masked_window():
    """Masked window slots and zero-mass clusters must not contribute."""
    n, g, d, kcb, w = 2, 2, 16, 8, 4
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (n, g, d))
    cbk = jax.random.normal(ks[1], (n, kcb, d))
    cbv = jax.random.normal(ks[2], (n, kcb, d))
    mass = jnp.zeros((n, kcb)).at[:, 0].set(2.0)
    wk = jax.random.normal(ks[4], (n, w, d))
    wv = jax.random.normal(ks[5], (n, w, d))
    wm = jnp.zeros((n, w)).at[:, 0].set(1.0)
    got = vq_attention_decode_pallas(q, cbk, cbv, mass, wk, wv, wm,
                                     interpret=True)
    want = jax.vmap(lambda *a: ref.vq_attention_decode(*a))(
        q, cbk, cbv, mass, wk, wv, wm)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert np.isfinite(np.asarray(got)).all()
