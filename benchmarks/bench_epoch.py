"""Epoch-executor throughput bench (DESIGN.md section 9): host-driven
per-step loop vs the device-resident ``lax.scan`` executor vs the
``shard_map`` data-parallel executor, in steps/s on the synthetic
benchmark graph.

Two entry points (the ``benchmarks/run.py`` convention):

  run_structured() -> rows for BENCH_epoch.json.  The dispatch-bound shape
      (small batch: per-step overhead dominates) carries a THROUGHPUT GATE:
      the scan executor must be >= 2x the host loop's steps/s
      (``scan_over_loop <= 0.5``; ISSUE 3 acceptance).  The compute-bound
      shape (large batch) is reported ungated -- there the two paths
      necessarily converge because model compute dominates.
  run() -> legacy (name, us, derived) tuples for the CSV printer.

The 2-device ``shard_map`` row needs >= 2 devices, so this module forces
two virtual CPU devices BEFORE the first jax import (each bench suite runs
in its own subprocess); if jax was already initialized with one device the
row is skipped rather than mis-measured.
"""
from __future__ import annotations

import benchmarks._device_env  # noqa: F401  (sets XLA_FLAGS; precedes jax)

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_kernels import _entry
from repro.core.codebook import CodebookConfig
from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                  full_operands, minibatch_stream)
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import (GNNConfig, init_gnn, init_vq_states,
                              vq_train_epoch, vq_train_step)
from repro.train.optimizer import rmsprop

_GATE = {"scan_over_loop": 0.5}   # scan must be >= 2x the host loop
# row-sharded graph state (DESIGN.md section 14): per-device graph-state
# bytes must drop to <= 0.6x the replicated footprint on 2 devices, and
# the cross-shard gathers may cost at most 1/0.8 of replicated DP's time
_SHARD_GATE = {"graph_state_ratio": 0.6, "sharded_over_dp": 1.25}


class _Env:
    """One benchmark configuration: graph, model, plan, fresh state."""

    def __init__(self, n: int, batch: int, hidden: int, k: int):
        self.g = synthetic_arxiv(n=n, seed=0)
        self.batch = batch
        self.cfg = GNNConfig(backbone="gcn", f_in=self.g.f, hidden=hidden,
                             n_out=self.g.num_classes, n_layers=2,
                             codebook=CodebookConfig(k=k, f_prod=4))
        self.ops = full_operands(self.g)
        self.x = jnp.asarray(self.g.features)
        self.labels = jnp.asarray(self.g.labels)
        tm = np.zeros(self.g.n, np.float32)
        tm[self.g.train_idx] = 1.0
        self.train_mask_np = tm
        self.train_mask = jnp.asarray(tm)
        self.opt = rmsprop(3e-3)
        self.plan = build_epoch_plan(self.g)
        self.steps = -(-self.g.n // batch)

    def fresh(self):
        params = init_gnn(jax.random.PRNGKey(0), self.cfg)
        vq = init_vq_states(jax.random.PRNGKey(1), self.cfg, self.g.n)
        return [params, vq, self.opt.init(params)]


def _time_epochs(run_epoch, reps: int = 3) -> float:
    """Best-of-reps wall seconds per epoch, after one warmup (compile) --
    the shared ``bench_kernels.time_best_s`` measurement policy."""
    from benchmarks.bench_kernels import time_best_s
    return time_best_s(run_epoch, reps)


def _host_loop_epoch_s(env: _Env) -> float:
    rng = np.random.default_rng(0)
    st = env.fresh()

    def epoch():
        loss = None
        for pack in minibatch_stream(env.g, env.batch, rng):
            bidx = np.asarray(pack.batch_ids)
            lm = env.train_mask_np[bidx] * np.asarray(pack.slot_mask)
            st[0], st[1], st[2], loss, _, _ = vq_train_step(
                st[0], st[1], st[2], pack, env.x[bidx], env.labels[bidx],
                env.ops.degrees, env.cfg, env.opt,
                loss_mask=jnp.asarray(lm))
        jax.block_until_ready(loss)
    return _time_epochs(epoch)


def _scan_epoch_s(env: _Env) -> float:
    rng = np.random.default_rng(0)
    st = env.fresh()

    def epoch():
        ids, sm = epoch_slices(rng.permutation(np.arange(env.g.n)),
                               env.batch)
        st[0], st[1], st[2], losses, _ = vq_train_epoch(
            st[0], st[1], st[2], env.plan,
            jnp.asarray(ids.astype(np.int32)), jnp.asarray(sm), env.x,
            env.labels, env.train_mask, env.ops.degrees, env.cfg, env.opt)
        jax.block_until_ready(losses)
    return _time_epochs(epoch)


def _scan_dp_epoch_s(env: _Env, n_devices: int) -> float:
    from repro.distributed.data_parallel import (graph_dp_mesh,
                                                 vq_train_epoch_dp)
    mesh = graph_dp_mesh(n_devices)
    rng = np.random.default_rng(0)
    st = env.fresh()

    def epoch():
        ids, sm = epoch_slices(rng.permutation(np.arange(env.g.n)),
                               env.batch)
        st[0], st[1], st[2], losses, _ = vq_train_epoch_dp(
            mesh, st[0], st[1], st[2], env.plan,
            jnp.asarray(ids.astype(np.int32)), jnp.asarray(sm), env.x,
            env.labels, env.train_mask, env.ops.degrees, env.cfg, env.opt)
        jax.block_until_ready(losses)
    return _time_epochs(epoch)


def _replicated_state_bytes(env: _Env) -> int:
    """Per-device graph-state bytes of the replicated DP path (every
    device holds the full node tables)."""
    return int(sum(int(t.nbytes) for t in (
        env.plan.nbr_ids, env.plan.nbr_mask, env.plan.rev_ids,
        env.plan.rev_mask, env.x, env.labels, env.train_mask,
        env.ops.degrees)))


def _scan_sharded_epoch_s(env: _Env, n_devices: int) -> tuple[float, int]:
    """(epoch seconds, per-device graph-state bytes) of the row-sharded
    executor."""
    from repro.distributed.data_parallel import (ShardedGraphState,
                                                 graph_dp_mesh,
                                                 vq_train_epoch_sharded)
    mesh = graph_dp_mesh(n_devices)
    state = ShardedGraphState(mesh, env.plan, env.x, env.ops.degrees,
                              labels=env.labels,
                              train_mask=env.train_mask)
    rng = np.random.default_rng(0)
    st = env.fresh()

    def epoch():
        ids, sm = epoch_slices(rng.permutation(np.arange(env.g.n)),
                               env.batch)
        st[0], st[1], st[2], losses, _ = vq_train_epoch_sharded(
            state, st[0], st[1], st[2], jnp.asarray(ids.astype(np.int32)),
            jnp.asarray(sm), env.cfg, env.opt)
        jax.block_until_ready(losses)
    return _time_epochs(epoch), state.per_device_bytes()


def run_structured() -> list[dict]:
    fast = os.environ.get("REPRO_BENCH_FAST", "1") != "0"
    # (n, batch, hidden, k, gated): gate only the dispatch-bound shape
    grids = [(2048, 32, 32, 32, True), (2048, 256, 32, 32, False)]
    if not fast:
        grids.append((8192, 128, 64, 64, False))

    rows: list[dict] = []
    gated_env = None
    for n, batch, hidden, k, gated in grids:
        env = _Env(n, batch, hidden, k)
        if gated:
            gated_env = env
        t_loop = _host_loop_epoch_s(env)
        t_scan = _scan_epoch_s(env)
        tag = f"n{n}_b{batch}"
        _entry(rows, f"epoch/host_loop_{tag}", t_loop * 1e6,
               {"steps_per_s": env.steps / t_loop})
        _entry(rows, f"epoch/scan_{tag}", t_scan * 1e6,
               {"steps_per_s": env.steps / t_scan,
                "speedup": t_loop / t_scan,
                "scan_over_loop": t_scan / t_loop},
               tolerance=_GATE if gated else None)

    if len(jax.devices()) >= 2 and gated_env is not None:
        t_dp = _scan_dp_epoch_s(gated_env, 2)
        _entry(rows, "epoch/scan_dp2_n2048_b32", t_dp * 1e6,
               {"steps_per_s": gated_env.steps / t_dp})
        t_sh, dev_bytes = _scan_sharded_epoch_s(gated_env, 2)
        _entry(rows, "epoch/scan_sharded2_n2048_b32", t_sh * 1e6,
               {"steps_per_s": gated_env.steps / t_sh,
                "sharded_over_dp": t_sh / t_dp,
                "per_device_bytes": dev_bytes,
                "graph_state_ratio":
                    dev_bytes / _replicated_state_bytes(gated_env)},
               tolerance=_SHARD_GATE)
    return rows


def run() -> list[tuple]:
    out = []
    for e in run_structured():
        out.append((e["name"], f"{e['us_per_call']:.0f}",
                    ";".join(f"{k}={v:.3g}"
                             for k, v in e["metrics"].items())))
    return out
