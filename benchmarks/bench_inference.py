"""Paper Sec. 6 inference claim: VQ-GNN mini-batch inference vs the
samplers' full-L-hop-neighborhood inference (their O(d^L) term).

Measures wall time of (a) VQ codeword inference per batch, (b) full-graph
layer inference (what samplers must do), plus the agreement between VQ
inference and exact inference."""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig, full_predict, node_metric
from repro.graph.batching import full_operands
from repro.train.gnn_trainer import train_vq, vq_inference

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"


def run() -> list[tuple]:
    g = synthetic_arxiv(n=1000 if FAST else 4000)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=256, f_prod=4))
    r = train_vq(g, cfg, epochs=15 if FAST else 60, batch_size=400,
                 eval_every=100)
    params, vq = r["params"], r["vq_states"]
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)

    # exact full-graph inference (timed)
    t0 = time.time()
    exact = full_predict(params, x, ops, cfg)
    exact.block_until_ready()
    t_full = time.time() - t0

    # VQ mini-batched inference (timed)
    t0 = time.time()
    approx = vq_inference(params, vq, g, cfg, batch_size=400)
    t_vq = time.time() - t0

    acc_exact = float(node_metric(exact[g.val_idx], labels[g.val_idx],
                                  False))
    acc_vq = float(node_metric(jnp.asarray(approx)[g.val_idx],
                               labels[g.val_idx], False))
    agree = float((np.argmax(np.asarray(exact), -1) ==
                   np.argmax(approx, -1)).mean())
    return [
        ("inference/full_graph", t_full * 1e6, f"acc={acc_exact:.4f}"),
        ("inference/vq_minibatch", t_vq * 1e6, f"acc={acc_vq:.4f}"),
        ("inference/agreement", 0.0, f"agree={agree:.4f}"),
        ("inference/vq_fetch_per_batch", 0.0,
         "O(b) features + codebooks (no L-hop neighborhood)"),
    ]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
