"""Paper Sec. 6 inference claim: VQ-GNN mini-batch inference vs the
samplers' full-L-hop-neighborhood inference (their O(d^L) term), plus the
executor-vs-eager-loop comparison of the device-resident inference
executor (DESIGN.md section 11).

Two entry points (the ``benchmarks/run.py`` convention):

  run_structured() -> rows for BENCH_inference.json.  The dispatch-bound
      shape (small batch -> many batches: per-dispatch overhead dominates)
      carries a THROUGHPUT GATE: the jitted executor must be >= 2x the
      eager per-(batch, layer) loop (``executor_over_eager <= 0.5``;
      ISSUE 5 acceptance).  The compute-bound shape (large batch) is
      reported ungated.  Agreement/accuracy rows vs exact full-graph
      inference ride along (the paper's Sec. 6 quality check).
  run() -> legacy (name, us, derived) tuples for the CSV printer.
"""
from __future__ import annotations

import benchmarks._device_env  # noqa: F401  (sets XLA_FLAGS; precedes jax)

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_kernels import _entry, time_best_s
from repro.core.codebook import CodebookConfig
from repro.distributed.quantization import tree_bytes
from repro.graph.batching import (build_epoch_plan, full_operands,
                                  inference_slices)
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import (GNNConfig, full_predict, init_gnn,
                              init_vq_states, node_metric,
                              quantize_vq_states, vq_infer_epoch)
from repro.train.gnn_trainer import (eager_inference_loop, train_vq,
                                     vq_inference)

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"
_GATE = {"executor_over_eager": 0.5}   # executor >= 2x the eager loop
# row-sharded inference state (DESIGN.md section 14): per-device bytes of
# the plan + activation tables must drop to <= 0.6x replicated on 2 devices
_SHARD_GATE = {"graph_state_ratio": 0.6}
_INT8_GATE = {"int8_acc_drop": 0.02}   # int8 serving parity (ISSUE 7)
_MEM_GATE = {"int8_state_ratio": 0.5}  # quantized operands <= half fp32
_FP8_GATE = {"fp8_acc_drop": 0.02}     # fp8 serving parity (ISSUE 9)
_A4_GATE = {"disagreement_vs_int8": 0.0,   # nibble packing is lossless
            "a4_table_ratio": 0.5}         # packed table <= half uint8


def _executor_vs_eager_rows(rows: list, n: int, batch: int, hidden: int,
                            k: int, gated: bool) -> None:
    g = synthetic_arxiv(n=n, seed=0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=hidden,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=k, f_prod=4))
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    ops = full_operands(g)
    plan = build_epoch_plan(g, full_ops=ops)
    x = jnp.asarray(g.features)
    ids, smask = inference_slices(g.n, batch)
    perm = jnp.asarray(ids.astype(np.int32))
    sm = jnp.asarray(smask)

    def run_executor():
        acts, _ = vq_infer_epoch(params, vq, plan, perm, sm, x,
                                 ops.degrees, cfg)
        jax.block_until_ready(acts)

    def run_eager():
        eager_inference_loop(params, vq, plan, ids, smask, x,
                             ops.degrees, cfg)

    t_exec = time_best_s(run_executor)
    t_eager = time_best_s(run_eager)
    tag = f"n{n}_b{batch}"
    _entry(rows, f"inference/eager_loop_{tag}", t_eager * 1e6,
           {"batches": ids.shape[0]})
    _entry(rows, f"inference/executor_{tag}", t_exec * 1e6,
           {"batches": ids.shape[0],
            "speedup": t_eager / t_exec,
            "executor_over_eager": t_exec / t_eager},
           tolerance=_GATE if gated else None)

    # --- row-sharded inference state (the --mesh capacity mode) ---
    if gated and len(jax.devices()) >= 2:
        from repro.distributed.data_parallel import (ShardedGraphState,
                                                     graph_dp_mesh,
                                                     vq_infer_epoch_sharded)
        state = ShardedGraphState(graph_dp_mesh(2), plan, x, ops.degrees)

        def run_sharded():
            acts, _ = vq_infer_epoch_sharded(state, params, vq, perm, sm,
                                             cfg)
            jax.block_until_ready(acts)

        t_sh = time_best_s(run_sharded)
        repl = int(sum(int(t.nbytes) for t in (
            plan.nbr_ids, plan.nbr_mask, plan.rev_ids, plan.rev_mask, x,
            ops.degrees)))
        dev_bytes = state.per_device_bytes()
        _entry(rows, f"inference/executor_sharded2_{tag}", t_sh * 1e6,
               {"batches": ids.shape[0],
                "sharded_over_executor": t_sh / t_exec,
                "per_device_bytes": dev_bytes,
                "graph_state_ratio": dev_bytes / repl},
               tolerance=_SHARD_GATE)


def run_structured() -> list[dict]:
    rows: list[dict] = []

    # --- executor vs the eager per-(batch, layer) loop ---
    # dispatch-bound (gated): small batch -> many batches, eager dispatch
    # overhead dominates; compute-bound (ungated): few large batches
    _executor_vs_eager_rows(rows, n=2048, batch=64, hidden=32, k=32,
                            gated=True)
    _executor_vs_eager_rows(rows, n=2048, batch=1024, hidden=32, k=32,
                            gated=False)

    # --- quality: trained model, VQ inference vs exact full-graph ---
    g = synthetic_arxiv(n=1000 if FAST else 4000)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=256, f_prod=4))
    r = train_vq(g, cfg, epochs=15 if FAST else 60, batch_size=400,
                 eval_every=100)
    params, vq = r["params"], r["vq_states"]
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)

    t0 = time.time()
    exact = full_predict(params, x, ops, cfg)
    exact.block_until_ready()
    t_full = time.time() - t0

    t0 = time.time()
    approx = vq_inference(params, vq, g, cfg, batch_size=400)
    t_vq = time.time() - t0

    acc_exact = float(node_metric(exact[g.val_idx], labels[g.val_idx],
                                  False))
    acc_vq = float(node_metric(jnp.asarray(approx)[g.val_idx],
                               labels[g.val_idx], False))
    agree = float((np.argmax(np.asarray(exact), -1) ==
                   np.argmax(approx, -1)).mean())
    _entry(rows, "inference/full_graph", t_full * 1e6, {"acc": acc_exact})
    _entry(rows, "inference/vq_minibatch", t_vq * 1e6,
           {"acc": acc_vq, "agreement": agree})

    # --- int8 serving path: the same trained model with quantized VQ
    # operands (uint8 assignment + int8 codeword snapshots, DESIGN.md
    # section 13).  Gated on accuracy parity vs the fp32 VQ inference and
    # on the state-bytes ratio (the VMEM-envelope win the int8 path buys).
    vq8 = quantize_vq_states(vq, cfg)
    t0 = time.time()
    approx8 = vq_inference(params, vq8, g, cfg, batch_size=400)
    t_vq8 = time.time() - t0
    acc8 = float(node_metric(jnp.asarray(approx8)[g.val_idx],
                             labels[g.val_idx], False))
    agree8 = float((np.argmax(approx, -1) ==
                    np.argmax(np.asarray(approx8), -1)).mean())
    fp32_b = int8_b = 0
    for st in vq8:
        fp32_b += st.assignment.size * 4            # int32 table
        int8_b += st.assignment.size                # uint8 table
        for qt in (st.qcw.feat, st.qcw.grad):
            fp32_b += qt.q.size * 4                 # dense f32 codewords
            int8_b += qt.q.size + qt.scale.size * 4
    _entry(rows, "inference/int8_vq_minibatch", t_vq8 * 1e6,
           {"acc": acc8, "agreement_vs_fp32": agree8,
            "int8_acc_drop": max(0.0, acc_vq - acc8)},
           tolerance=_INT8_GATE)
    _entry(rows, "inference/int8_state_bytes", 0.0,
           {"fp32_bytes": fp32_b, "int8_bytes": int8_b,
            "int8_state_ratio": int8_b / fp32_b},
           tolerance=_MEM_GATE)

    # --- fp8 serving tier: the SAME trained model with float8_e4m3fn
    # codeword snapshots (uint8 assignment tables, identical wire bytes to
    # int8).  Same accuracy-parity gate as the int8 row (ISSUE 9) ---
    vqf8 = quantize_vq_states(vq, cfg, precision="fp8")
    t0 = time.time()
    approxf8 = vq_inference(params, vqf8, g, cfg, batch_size=400)
    t_vqf8 = time.time() - t0
    accf8 = float(node_metric(jnp.asarray(approxf8)[g.val_idx],
                              labels[g.val_idx], False))
    agreef8 = float((np.argmax(approx, -1) ==
                     np.argmax(np.asarray(approxf8), -1)).mean())
    _entry(rows, "inference/fp8_vq_minibatch", t_vqf8 * 1e6,
           {"acc": accf8, "agreement_vs_fp32": agreef8,
            "fp8_acc_drop": max(0.0, acc_vq - accf8)},
           tolerance=_FP8_GATE)

    # --- +a4 nibble-packed assignment tables (k <= 16): packing is
    # LOSSLESS, so int8+a4 inference must agree with plain-int8 inference
    # prediction-for-prediction, while the packed tables halve the uint8
    # tier's assignment bytes (exact sub-byte accounting via tree_bytes) ---
    cfg16 = GNNConfig(backbone="gcn", f_in=g.f, hidden=64,
                      n_out=g.num_classes, n_layers=2,
                      codebook=CodebookConfig(k=16, f_prod=4))
    params16 = init_gnn(jax.random.PRNGKey(2), cfg16)
    vq16 = init_vq_states(jax.random.PRNGKey(3), cfg16, g.n)
    vq16_int8 = quantize_vq_states(vq16, cfg16, precision="int8")
    vq16_a4 = quantize_vq_states(vq16, cfg16, precision="int8+a4")
    y_int8 = vq_inference(params16, vq16_int8, g, cfg16, batch_size=400)
    t0 = time.time()
    y_a4 = vq_inference(params16, vq16_a4, g, cfg16, batch_size=400)
    t_a4 = time.time() - t0
    disagree = float((np.argmax(np.asarray(y_int8), -1) !=
                      np.argmax(np.asarray(y_a4), -1)).mean())
    u8_tab = sum(tree_bytes((st.assignment,)) for st in vq16_int8)
    a4_tab = sum(tree_bytes((st.assignment,)) for st in vq16_a4)
    _entry(rows, "inference/int8_a4_vq_minibatch", t_a4 * 1e6,
           {"disagreement_vs_int8": disagree,
            "uint8_table_bytes": u8_tab, "a4_table_bytes": a4_tab,
            "a4_table_ratio": a4_tab / u8_tab},
           tolerance=_A4_GATE)
    return rows


def run() -> list[tuple]:
    out = []
    for e in run_structured():
        out.append((e["name"], f"{e['us_per_call']:.0f}",
                    ";".join(f"{k}={v:.4g}"
                             for k, v in e["metrics"].items())))
    out.append(("inference/vq_fetch_per_batch", 0.0,
                "O(b) features + codebooks (no L-hop neighborhood)"))
    return out


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
