"""Fused VQ-context bench (DESIGN.md section 10): the one-pass multi-branch
codeword SpMM forward vs the pre-fusion per-branch loop, and the streaming
Eq. 7 backward vs the materialized-residual injection.

Two entry points (the ``benchmarks/run.py`` convention):

  run_structured() -> rows for BENCH_context.json.  Gated rows:
      * ``context/fused_vs_loop/nb4_k256_b4096`` -- the fused forward
        (ONE dispatch: ``ops.context_ell``) must be >= 1.5x the pre-fusion
        per-branch path at the OP-DISPATCH level: a Python loop issuing one
        SpMM dispatch per product-VQ branch + concat, eagerly -- which is
        how the pre-PR mini-batched inference path (``vq_inference``:
        un-jitted per-layer ``vq_apply`` calls) actually paid for it, and
        the CPU analogue of the nb-kernel-launch cost a TPU pays even
        inside jit (pallas_call boundaries don't fuse).
        ``fused_over_loop <= 1/1.5`` (ISSUE 4 acceptance).  The companion
        ``.../jit`` row reports the ratio with BOTH forms compiled into
        one XLA program (the jitted-train-step regime, where the two
        necessarily converge on CPU because XLA fuses the loop's ops
        itself) -- reported ungated so a within-jit regression stays
        visible in the artifact without a wall-clock-noise gate on a ~1x
        ratio.
      * ``context/bwd_residual/...`` -- the measured vjp residual bytes of
        the streaming backward must be <= 0.5x the materialized form's
        (deterministic: counted from the residual arrays jax actually
        saves, no wall-clock noise).
      * ``context/a4_*`` -- the nibble-packed assignment tier (DESIGN.md
        section 15): fused-kernel parity on a packed table + fp8
        codewords, exact packed-table bytes (<= 0.5x uint8, <= 0.125x
        int32), the fused-dispatch crossover extension (>= 2x the uint8
        tier's node count, probed from ``context_ell_variant`` itself),
        and the loop-vs-fused regime timing at a budget between the two
        thresholds.
      * interpret-mode kernel parity vs the oracle (maxerr), the
        bench_kernels convention.
  run() -> legacy (name, us, derived) tuples for the CSV printer.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.bench_kernels import _entry, _time
from repro.core.message_passing import (ConvOperands, approx_message_passing,
                                        context_messages_reconstruct,
                                        inject_context_grad_materialized,
                                        intra_messages, reconstruct)
from repro.distributed.quantization import (PackedAssignment,
                                            quantize_codewords, tree_bytes)
from repro.kernels import ops, ref
from repro.kernels.context_ell import context_ell_pallas

_FWD_GATE = {"fused_over_loop": 1.0 / 1.5}   # fused must be >= 1.5x
_RES_GATE = {"residual_ratio": 0.5}          # streaming residual <= 0.5x
_INT8_GATE = {"int8_over_fp32": 1.0 / 1.3}   # int8 path must be >= 1.3x
_MEM_GATE = {"int8_operand_ratio": 0.5}      # int8 operand bytes <= 0.5x
_A4_GATE = {"a4_over_uint8": 1.0 / 1.3}      # packed path must be >= 1.3x
_A4_MEM_GATE = {"a4_over_uint8_bytes": 0.5,  # packed table <= 0.5x uint8
                "a4_over_int32_bytes": 0.125}    # ... <= 0.125x int32
_A4_CROSS_GATE = {"uint8_over_a4_crossover": 0.5}    # crossover n >= 2x


def _context_case(b, deg, n, nb, k, f_blk, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    ids = jax.random.randint(ks[0], (b, deg), 0, n).astype(jnp.int32)
    val = jax.random.normal(ks[1], (b, deg))
    assign = jax.random.randint(ks[2], (nb, n), 0, k).astype(jnp.int32)
    cw = jax.random.normal(ks[3], (nb, k, f_blk))
    return ids, val, assign, cw


def _legacy_loop(out_ids, out_vals, assignment, codewords):
    """The pre-fusion context forward: a Python loop issuing one SpMM per
    branch after materializing the [nb, b, D] gathered-assignment tensor,
    then a concat -- exactly ``ops._context_ell_loop``, the shipped 'loop'
    dispatch fallback, so the baseline can never drift from the code path
    it represents.  Timed eagerly it reproduces the pre-PR
    ``vq_inference`` dispatch cost; under ``jax.jit`` it reproduces the
    pre-PR train-step regime (module docstring)."""
    return ops._context_ell_loop(out_ids, out_vals, assignment, codewords,
                                 None)


def _legacy_amp(ops_, x_b, fcw, gcw, assignment, w):
    """Pre-PR approx_message_passing: the Eq. 7 injection materializes the
    reconstructed [b, Dr, f_grad] gradient-codeword tensor in the forward
    pass and carries it as the vjp residual."""
    grad_hat = jax.lax.stop_gradient(
        reconstruct(gcw, assignment, ops_.rev_ids))
    x_b = inject_context_grad_materialized(x_b, ops_.rev_vals, grad_hat, w)
    m = intra_messages(ops_.in_pos, ops_.in_vals, x_b, ops_.stripe_index)
    return m + context_messages_reconstruct(
        ops_.out_vals, ops_.out_ids, fcw, assignment)


def _residual_bytes(vjp_fn) -> int:
    """Bytes of the residual arrays jax saved for this vjp."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(vjp_fn):
        if leaf.dtype == jax.dtypes.float0:
            continue
        total += leaf.size * leaf.dtype.itemsize
    return total


def _amp_case(b, deg, dr, n, nb, k, f_blk, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 10)
    f_in = nb * f_blk
    in_pos = jax.random.randint(ks[0], (b, deg), -1, b).astype(jnp.int32)
    in_vals = jnp.where(in_pos >= 0, jax.random.normal(ks[1], (b, deg)), 0.0)
    out_ids = jax.random.randint(ks[2], (b, deg), 0, n).astype(jnp.int32)
    out_vals = jnp.where(in_pos < 0,
                         jax.random.normal(ks[3], (b, deg)), 0.0)
    rev_ids = jax.random.randint(ks[4], (b, dr), 0, n).astype(jnp.int32)
    rev_vals = jax.random.normal(ks[5], (b, dr))
    fcw = jax.random.normal(ks[6], (nb, k, f_blk))
    gcw = jax.random.normal(ks[7], (nb, k, f_blk))
    assign = jax.random.randint(ks[8], (nb, n), 0, k).astype(jnp.int32)
    x_b = jax.random.normal(ks[9], (b, f_in))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (f_in, nb * f_blk))
    ops_ = ConvOperands(in_pos, in_vals, out_ids, out_vals,
                        rev_ids, rev_vals)
    return ops_, x_b, fcw, gcw, assign, w


def run_structured() -> list[dict]:
    fast = os.environ.get("REPRO_BENCH_FAST", "1") != "0"
    rows: list[dict] = []

    # --- interpret-mode kernel parity vs oracle (small shape: interpret
    # execution is the sanctioned CPU validation path, not a speed path) ---
    ids, val, assign, cw = _context_case(512, 8, 5000, 4, 256, 8)
    got = context_ell_pallas(ids, val, assign, cw, interpret=True)
    want = ref.context_ell(ids, val, assign, cw)
    us = _time(lambda a, b_, c, d: context_ell_pallas(
        a, b_, c, d, interpret=True), ids, val, assign, cw)
    _entry(rows, "context/kernel_parity/512x8_nb4_k256", us,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})
    w_t = jax.random.normal(jax.random.PRNGKey(9), (4 * 8, 32))
    got = context_ell_pallas(ids, val, assign, cw, w_t=w_t, interpret=True)
    want = ref.context_ell(ids, val, assign, cw, w_t)
    _entry(rows, "context/kernel_parity_wt/512x8_nb4_k256", 0.0,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})

    # --- fused forward vs the per-branch loop.  The gate shape is the
    # ISSUE 4 acceptance shape (nb=4, k=256, b=4096); the loop baseline is
    # the pre-PR dispatch sequence (one SpMM dispatch per branch from
    # Python, eager -- the pre-PR vq_inference regime), the fused path is
    # the ONE ``ops.context_ell`` dispatch.  The jit-vs-jit companion row
    # is reported ungated (module docstring) ---
    grids = [(4096, 16, 100_000, 4, 256, 8, True),
             (1024, 16, 100_000, 2, 256, 8, False)]
    if not fast:
        grids.append((16384, 16, 500_000, 4, 256, 8, False))
    loop_jit = jax.jit(_legacy_loop)
    for b, deg, n, nb, k, f_blk, gated in grids:
        ids, val, assign, cw = _context_case(b, deg, n, nb, k, f_blk)
        us_loop = _time(_legacy_loop, ids, val, assign, cw)
        us_fused = _time(ops.context_ell, ids, val, assign, cw)
        _entry(rows, f"context/fused_vs_loop/nb{nb}_k{k}_b{b}", us_fused,
               {"us_fused": us_fused, "us_loop": us_loop,
                "speedup": us_loop / max(us_fused, 1e-9),
                "fused_over_loop": us_fused / max(us_loop, 1e-9)},
               tolerance=_FWD_GATE if gated else None)
        if gated:
            us_loop_jit = _time(loop_jit, ids, val, assign, cw)
            _entry(rows, f"context/fused_vs_loop/nb{nb}_k{k}_b{b}/jit",
                   us_fused,
                   {"us_fused": us_fused, "us_loop_jit": us_loop_jit,
                    "fused_over_loop_jit":
                        us_fused / max(us_loop_jit, 1e-9)})

    # --- int8 operand path (DESIGN.md section 13).  Parity first: the
    # int8 fused kernel (uint8 assignment + int8 codewords + epilogue
    # dequant) vs the oracle on the DEQUANTIZED tables -- the kernel must
    # reproduce its own quantization grid exactly, so the gate is a tight
    # kernel-correctness bound, not a loose quantization-error bound ---
    ids, val, assign, cw = _context_case(512, 8, 5000, 4, 256, 8)
    qcw = quantize_codewords(cw)
    deq = qcw.q.astype(jnp.float32) * qcw.scale
    ua = assign.astype(jnp.uint8)
    got = context_ell_pallas(ids, val, ua, qcw.q, cw_scale=qcw.scale,
                             interpret=True)
    want = ref.context_ell(ids, val, assign, deq)
    us = _time(lambda a, b_, c, d, e: context_ell_pallas(
        a, b_, c, d, cw_scale=e, interpret=True), ids, val, ua, qcw.q,
        qcw.scale)
    _entry(rows, "context/int8_kernel_parity/512x8_nb4_k256", us,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})
    w_t8 = jax.random.normal(jax.random.PRNGKey(9), (4 * 8, 32))
    got = context_ell_pallas(ids, val, ua, qcw.q, cw_scale=qcw.scale,
                             w_t=w_t8, interpret=True)
    want = ref.context_ell(ids, val, assign, deq, w_t8)
    _entry(rows, "context/int8_kernel_parity_wt/512x8_nb4_k256", 0.0,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})

    # --- the ISSUE 7 serving-shape gate: int8 operands vs the fp32 path
    # at the VMEM-envelope crossover.  With a 1 MiB dispatch budget the
    # fp32 [4, 100k] int32 assignment table (1.6 MiB) exceeds the fused
    # kernel's envelope -> the dispatch layer takes the eager per-branch
    # loop; the uint8 table (0.4 MiB) still fits -> ONE fused dispatch.
    # That dispatch-regime difference IS the int8 claim (the table is the
    # envelope lever), and it is exactly what ``context_ell_variant``
    # decides on a real TPU -- the bench times each regime's op-dispatch
    # cost (the existing fused_vs_loop convention: dispatch-level, eager
    # loop vs single fused call; within one jit the forms converge on CPU)
    b, deg, n, nb, k, f_blk = 4096, 16, 100_000, 4, 256, 8
    ids, val, assign, cw = _context_case(b, deg, n, nb, k, f_blk)
    qcw = quantize_codewords(cw)
    ua = assign.astype(jnp.uint8)
    ops.configure_context_dispatch(reset=True, vmem_budget_mb=1.0)
    v32 = ops.context_ell_variant(n, nb, assign.dtype.itemsize)
    v8 = ops.context_ell_variant(n, nb, ua.dtype.itemsize)
    assert v32 == "loop" and v8 == "fused", (v32, v8)
    us_fp32 = _time(_legacy_loop, ids, val, assign, cw)
    us_int8 = _time(ops.context_ell, ids, val, ua, qcw)
    ops.configure_context_dispatch(reset=True)
    fp32_bytes = assign.size * 4 + cw.size * 4
    int8_bytes = ua.size + qcw.q.size + qcw.scale.size * 4
    _entry(rows, f"context/int8_vs_fp32_dispatch/nb{nb}_k{k}_b{b}", us_int8,
           {"us_int8": us_int8, "us_fp32": us_fp32,
            "speedup": us_fp32 / max(us_int8, 1e-9),
            "int8_over_fp32": us_int8 / max(us_fp32, 1e-9),
            "fp32_variant_at_1mb": 1.0 if v32 == "loop" else 0.0,
            "int8_variant_at_1mb": 0.0 if v8 == "fused" else 1.0},
           tolerance=_INT8_GATE)
    _entry(rows, f"context/int8_operand_bytes/nb{nb}_k{k}_n100k", 0.0,
           {"fp32_mb": fp32_bytes / 2**20, "int8_mb": int8_bytes / 2**20,
            "int8_operand_ratio": int8_bytes / fp32_bytes},
           tolerance=_MEM_GATE)

    # --- nibble-packed int4 assignment tables + fp8 codewords (the +a4 /
    # fp8 tiers, DESIGN.md section 15).  Parity first, the int8 convention:
    # the fused kernel on a PACKED uint4 table (shift/mask unpack inside
    # the kernel) + fp8 codewords must reproduce the oracle on the
    # dequantized tables exactly ---
    ids, val, assign, cw = _context_case(512, 8, 5000, 4, 16, 8)
    qcw8 = quantize_codewords(cw, dtype=jnp.float8_e4m3fn)
    deq8 = qcw8.q.astype(jnp.float32) * qcw8.scale
    pa = PackedAssignment.pack(assign.astype(jnp.uint8))
    got = context_ell_pallas(ids, val, pa, qcw8.q, cw_scale=qcw8.scale,
                             interpret=True)
    want = ref.context_ell(ids, val, assign, deq8)
    us = _time(lambda a, b_, c, d, e: context_ell_pallas(
        a, b_, c, d, cw_scale=e, interpret=True), ids, val, pa, qcw8.q,
        qcw8.scale)
    _entry(rows, "context/a4_fp8_kernel_parity/512x8_nb4_k16", us,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})

    # --- table bytes: 2 ids/byte halves the uint8 tier's table (8x vs
    # int32); exact sub-byte accounting via the shared tree_bytes ---
    b, deg, n, nb, k, f_blk = 4096, 16, 200_000, 4, 16, 8
    ids, val, assign, cw = _context_case(b, deg, n, nb, k, f_blk)
    qcw = quantize_codewords(cw)
    ua = assign.astype(jnp.uint8)
    pa = PackedAssignment.pack(ua)
    a4_bytes = tree_bytes((pa,))
    u8_bytes = tree_bytes((ua,))
    i32_bytes = tree_bytes((assign,))
    _entry(rows, f"context/a4_table_bytes/nb{nb}_k{k}_n200k", 0.0,
           {"int32_mb": i32_bytes / 2**20, "uint8_mb": u8_bytes / 2**20,
            "a4_mb": a4_bytes / 2**20,
            "a4_over_uint8_bytes": a4_bytes / u8_bytes,
            "a4_over_int32_bytes": a4_bytes / i32_bytes},
           tolerance=_A4_MEM_GATE)

    # --- the tentpole dispatch claim: at a fixed VMEM budget the packed
    # table's fused-dispatch crossover sits at >= 2x the uint8 tier's
    # node count (found by probing ``context_ell_variant`` itself, so the
    # gate can never drift from the shipped heuristic).  At a budget
    # between the two thresholds ([4, 200k]: uint8 0.76 MiB > 0.5 MiB,
    # packed 0.38 MiB < 0.5 MiB) the uint8 table falls back to the
    # per-branch loop while the packed table keeps the ONE fused dispatch;
    # the timing compares those regimes at the op-dispatch level (the
    # int8_vs_fp32 convention: eager ``_context_ell_loop`` vs one
    # ``ops.context_ell`` call), both on the SAME int8 codewords so the
    # row isolates the assignment-packing lever ---
    def _crossover(itemsize, dt):
        lo, hi = 1, 1
        while ops.context_ell_variant(hi, nb, itemsize, dtype=dt) == "fused":
            lo, hi = hi, hi * 2
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if ops.context_ell_variant(mid, nb, itemsize, dtype=dt) == "fused":
                lo = mid
            else:
                hi = mid
        return lo

    ops.configure_context_dispatch(reset=True, vmem_budget_mb=0.5)
    cross_u8 = _crossover(1, jnp.uint8)
    cross_a4 = _crossover(0.5, jnp.uint4)
    v8 = ops.context_ell_variant(n, nb, 1, dtype=jnp.uint8)
    v4 = ops.context_ell_variant(n, nb, 0.5, dtype=jnp.uint4)
    assert v8 == "loop" and v4 == "fused", (v8, v4)
    us_u8 = _time(lambda a, v_, s, q, sc: ops._context_ell_loop(
        a, v_, s, q, None, sc), ids, val, ua, qcw.q, qcw.scale)
    us_a4 = _time(ops.context_ell, ids, val, pa, qcw)
    ops.configure_context_dispatch(reset=True)
    _entry(rows, f"context/a4_vs_uint8_dispatch/nb{nb}_k{k}_b{b}", us_a4,
           {"us_a4": us_a4, "us_uint8": us_u8,
            "speedup": us_u8 / max(us_a4, 1e-9),
            "a4_over_uint8": us_a4 / max(us_u8, 1e-9),
            "uint8_variant_at_0p5mb": 1.0 if v8 == "loop" else 0.0,
            "a4_variant_at_0p5mb": 0.0 if v4 == "fused" else 1.0},
           tolerance=_A4_GATE)
    _entry(rows, f"context/a4_crossover/nb{nb}_budget0p5mb", 0.0,
           {"crossover_n_uint8": float(cross_u8),
            "crossover_n_a4": float(cross_a4),
            "extension": cross_a4 / max(cross_u8, 1),
            "uint8_over_a4_crossover": cross_u8 / max(cross_a4, 1)},
           tolerance=_A4_CROSS_GATE)

    # --- streaming vs materialized Eq. 7 backward: wall time of the full
    # jitted value_and_grad, plus the MEASURED vjp residual bytes (what the
    # forward pass actually keeps alive until the backward runs) ---
    b, deg, dr, n, nb, k, f_blk = 4096, 16, 16, 100_000, 4, 256, 8
    ops_, x_b, fcw, gcw, assign, w = _amp_case(b, deg, dr, n, nb, k, f_blk)

    def loss_stream(x):
        return jnp.sum(approx_message_passing(ops_, x, fcw, gcw, assign, w))

    def loss_mat(x):
        return jnp.sum(_legacy_amp(ops_, x, fcw, gcw, assign, w))

    us_stream = _time(jax.jit(jax.value_and_grad(loss_stream)), x_b)
    us_mat = _time(jax.jit(jax.value_and_grad(loss_mat)), x_b)
    _, vjp_stream = jax.vjp(loss_stream, x_b)
    _, vjp_mat = jax.vjp(loss_mat, x_b)
    res_stream = _residual_bytes(vjp_stream)
    res_mat = _residual_bytes(vjp_mat)
    tag = f"b{b}_dr{dr}_nb{nb}_k{k}"
    _entry(rows, f"context/bwd_stream_vs_materialized/{tag}", us_stream,
           {"us_streaming": us_stream, "us_materialized": us_mat,
            "speedup": us_mat / max(us_stream, 1e-9)})
    _entry(rows, f"context/bwd_residual/{tag}", 0.0,
           {"residual_mb_streaming": res_stream / 2**20,
            "residual_mb_materialized": res_mat / 2**20,
            "materialized_tensor_mb": b * dr * nb * f_blk * 4 / 2**20,
            "residual_ratio": res_stream / max(res_mat, 1)},
           tolerance=_RES_GATE)
    return rows


def run() -> list[tuple]:
    out = []
    for e in run_structured():
        derived = ";".join(
            f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in e["metrics"].items())
        if not e["pass"]:
            derived += ";PARITY_FAIL"
        out.append((e["name"], e["us_per_call"], derived))
    return out


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
