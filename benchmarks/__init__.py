"""Benchmarks: one module per paper table/figure + roofline/kernels."""
