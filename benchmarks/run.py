"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scale with REPRO_BENCH_FAST=0
for the full (paper-sized) grids; default is the fast grid (CPU-friendly).

Machine-readable mode (the CI bench job):

    python -m benchmarks.run kernels --json BENCH_kernels.json --check

runs one suite, writes its structured rows (each {name, us_per_call,
metrics, tolerance, pass}) as JSON, and with ``--check`` exits non-zero
when any row with a tolerance is out of tolerance (kernel-vs-oracle parity
deltas).  Suites expose ``run_structured()`` for this; suites that only
have ``run()`` are wrapped with pass=True rows.

  Table 2  -> bench_complexity
  Table 3  -> bench_memory
  Fig. 4   -> bench_convergence
  Table 4/7-> bench_performance
  Sec. 6   -> bench_inference
  App. G   -> bench_ablation (the scenario matrix: backbone x scale method
              x task with per-cell accuracy floors vs the full-graph
              oracle, + the CI-gated sampler-executor throughput row;
              the CI ``scenario-matrix`` job runs it with --check and
              uploads BENCH_ablation.json)
  (ours)   -> bench_roofline (from the multi-pod dry-run artifacts)
  (ours)   -> bench_kernels (Pallas kernels, interpret mode, vs oracles)
  (ours)   -> bench_context (fused VQ-context fwd/bwd vs per-branch loop)
  (ours)   -> bench_epoch (epoch executor: host loop vs scan vs shard_map)

Each suite runs in its own subprocess: a single long-lived process
accumulating hundreds of distinct jit executables eventually trips XLA's
CPU JIT ("Failed to materialize symbols"); per-suite isolation bounds that
state and also keeps wall-time numbers independent.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SUITES = ["complexity", "memory", "kernels", "context", "epoch", "roofline",
          "inference", "convergence", "ablation", "performance"]


def run_suite_inline(name: str) -> None:
    import importlib
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    for row in mod.run():
        print(",".join(str(x) for x in row))


def run_suite_structured(name: str, json_path: str | None,
                         check: bool) -> None:
    import importlib
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    if hasattr(mod, "run_structured"):
        rows = mod.run_structured()
    else:
        rows = [{"name": n, "us_per_call": us, "metrics": {"derived": d},
                 "tolerance": None, "pass": True} for n, us, d in mod.run()]
    failures = [r["name"] for r in rows if not r.get("pass", True)]
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": name, "rows": rows, "failures": failures},
                      f, indent=2)
            f.write("\n")
    for r in rows:
        status = "ok" if r.get("pass", True) else "PARITY_FAIL"
        print(f"{r['name']},{r['us_per_call']},{status}")
    if failures:
        sys.stderr.write(
            f"{len(failures)} row(s) out of tolerance: {failures}\n")
        if check:
            raise SystemExit(1)


def main() -> None:
    argv = sys.argv[1:]
    json_path = None
    check = False
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
            raise SystemExit("--json requires a path operand")
        json_path = argv[i + 1]
        del argv[i:i + 2]
    if "--check" in argv:
        check = True
        argv.remove("--check")
    if json_path or check:
        # gate flags must never fail open: a mistyped suite name has to be
        # a hard error, not a silent fall-through to the run-all path
        if len(argv) != 1 or argv[0] not in SUITES:
            raise SystemExit(
                f"--json/--check require exactly one suite of {SUITES}, "
                f"got {argv!r}")
        run_suite_structured(argv[0], json_path, check)
        return
    if argv and argv[0] in SUITES:
        run_suite_inline(argv[0])
        return
    print("name,us_per_call,derived")
    sys.stdout.flush()
    failures = 0
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here,
         env.get("PYTHONPATH", "")])
    for name in SUITES:
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", name],
            capture_output=True, text=True, env=env, cwd=here,
            timeout=3600)
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-2000:])
            print(f"{name}/SUITE_FAILED,0,error")
            failures += 1
        else:
            sys.stdout.write(proc.stdout)
        print(f"{name}/suite_wall,{(time.time()-t0)*1e6:.0f},ok")
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
