"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scale with REPRO_BENCH_FAST=0
for the full (paper-sized) grids; default is the fast grid (CPU-friendly).

Machine-readable mode (the CI bench job):

    python -m benchmarks.run kernels --json BENCH_kernels.json --check

runs one suite, writes its structured rows (each {name, us_per_call,
metrics, tolerance, pass}) as JSON, and with ``--check`` exits non-zero
when any row with a tolerance is out of tolerance (kernel-vs-oracle parity
deltas).  Suites expose ``run_structured()`` for this; suites that only
have ``run()`` are wrapped with pass=True rows.

Baseline refresh (after a PR intentionally moves gated metrics):

    python -m benchmarks.run --update-baselines [suite ...]

re-runs each named suite (default: every suite with a committed snapshot
under ``benchmarks/baselines/``) and rewrites its BENCH_<suite>.json from
the fresh rows.  It REFUSES to run on a dirty git tree, so a refreshed
baseline always corresponds to an exact committed code state -- commit the
code first, regenerate, then commit the baselines on top.

  Table 2  -> bench_complexity
  Table 3  -> bench_memory
  Fig. 4   -> bench_convergence
  Table 4/7-> bench_performance
  Sec. 6   -> bench_inference
  App. G   -> bench_ablation (the scenario matrix: backbone x scale method
              x task with per-cell accuracy floors vs the full-graph
              oracle, + the CI-gated sampler-executor throughput row;
              the CI ``scenario-matrix`` job runs it with --check and
              uploads BENCH_ablation.json)
  (ours)   -> bench_roofline (from the multi-pod dry-run artifacts)
  (ours)   -> bench_kernels (Pallas kernels, interpret mode, vs oracles)
  (ours)   -> bench_context (fused VQ-context fwd/bwd vs per-branch loop)
  (ours)   -> bench_epoch (epoch executor: host loop vs scan vs shard_map)

Each suite runs in its own subprocess: a single long-lived process
accumulating hundreds of distinct jit executables eventually trips XLA's
CPU JIT ("Failed to materialize symbols"); per-suite isolation bounds that
state and also keeps wall-time numbers independent.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SUITES = ["complexity", "memory", "kernels", "context", "epoch", "roofline",
          "inference", "convergence", "ablation", "performance"]


def run_suite_inline(name: str) -> None:
    import importlib
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    for row in mod.run():
        print(",".join(str(x) for x in row))


def baseline_failures(rows, baseline: dict, *, rel: float = 1.2,
                      floor: float = 0.05, slack: float = 0.02) -> list[str]:
    """Gated metrics regressed >(rel - 1) against a committed baseline.

    The bench-trend gate: every *tolerance-bearing* metric (the CI-gated
    ratios/parity deltas, all "smaller is better" by the ``_entry``
    convention) is compared row-by-name against ``baseline`` (a prior
    BENCH_*.json).  A metric regresses iff the current value exceeds the
    baseline by BOTH the relative factor ``rel`` AND the absolute margin
    ``slack``, AND has consumed more than half its headroom to the hard
    tolerance -- timing ratios deep inside the safe region jitter ~2x
    run-to-run on shared CI hosts, so a trend alarm only means something
    once the metric is actually approaching its gate.  Baselines below
    ``floor`` are skipped for the same reason (any multiple of noise is
    still noise).  Rows absent from the baseline (new benches) never
    fail -- they start the trend.
    """
    base_rows = {r.get("name"): r for r in baseline.get("rows", [])}
    out = []
    for r in rows:
        tol = r.get("tolerance") or {}
        base = base_rows.get(r.get("name"))
        if not tol or base is None:
            continue
        bmet = base.get("metrics") or {}
        for m in tol:
            cur_v, base_v = (r.get("metrics") or {}).get(m), bmet.get(m)
            if cur_v is None or base_v is None:
                continue
            cur_v, base_v = float(cur_v), float(base_v)
            if base_v < floor:
                continue
            try:
                half_gate = float(tol[m]) / 2.0
            except (TypeError, ValueError):
                half_gate = 0.0
            if cur_v > base_v * rel and cur_v > base_v + slack \
                    and cur_v > half_gate:
                out.append(f"{r['name']}:{m} {base_v:.4g}->{cur_v:.4g}")
    return out


def run_suite_structured(name: str, json_path: str | None, check: bool,
                         baseline_path: str | None = None) -> None:
    import importlib
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    if hasattr(mod, "run_structured"):
        rows = mod.run_structured()
    else:
        rows = [{"name": n, "us_per_call": us, "metrics": {"derived": d},
                 "tolerance": None, "pass": True} for n, us, d in mod.run()]
    failures = [r["name"] for r in rows if not r.get("pass", True)]
    trend = []
    if baseline_path:
        with open(baseline_path) as f:
            trend = baseline_failures(rows, json.load(f))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": name, "rows": rows, "failures": failures,
                       "trend_failures": trend}, f, indent=2)
            f.write("\n")
    for r in rows:
        status = "ok" if r.get("pass", True) else "PARITY_FAIL"
        print(f"{r['name']},{r['us_per_call']},{status}")
    if failures:
        sys.stderr.write(
            f"{len(failures)} row(s) out of tolerance: {failures}\n")
    if trend:
        # passing --baseline IS opting into the trend gate: fail even
        # without --check (gate flags must never fail open)
        sys.stderr.write(
            f"{len(trend)} gated metric(s) regressed >20% vs "
            f"{baseline_path}: {trend}\n")
        raise SystemExit(1)
    if failures and check:
        raise SystemExit(1)


def update_baselines(suites: list[str]) -> None:
    """Re-run ``suites`` and rewrite their committed baseline snapshots.

    Refuses on a dirty git tree (module docstring): the trend gate
    compares against "the metrics at commit X", which only means something
    when the snapshot was generated from exactly that tree.
    """
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base_dir = os.path.join(here, "benchmarks", "baselines")
    dirty = subprocess.run(
        ["git", "status", "--porcelain"], capture_output=True, text=True,
        cwd=here).stdout.strip()
    if dirty:
        raise SystemExit(
            "--update-baselines refuses to run on a dirty git tree "
            "(baselines must snapshot a committed code state); commit or "
            f"stash first:\n{dirty}")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here, env.get("PYTHONPATH", "")])
    # same reasoning as the dirty-tree refusal: a baseline snapshotted
    # from a tree that fails its own static contracts (repro.analysis:
    # dispatch counts, VMEM budgets, lint rules) pins numbers the CI
    # gate would reject anyway
    checker = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root", here],
        env=env, cwd=here, timeout=1800)
    if checker.returncode != 0:
        raise SystemExit(
            "--update-baselines refuses to run: repro.analysis reports "
            "findings (fix the tree before snapshotting baselines)")
    if not suites:
        suites = sorted(
            f[len("BENCH_"):-len(".json")]
            for f in os.listdir(base_dir)
            if f.startswith("BENCH_") and f.endswith(".json"))
    unknown = [s for s in suites if s not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suite(s) {unknown}; want {SUITES}")
    for name in suites:
        path = os.path.join(base_dir, f"BENCH_{name}.json")
        print(f"regenerating {path} ...")
        sys.stdout.flush()
        # per-suite subprocess isolation, the run-all convention
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", name,
             "--json", path, "--check"],
            env=env, cwd=here, timeout=3600)
        if proc.returncode != 0:
            raise SystemExit(
                f"suite {name!r} failed its own tolerances; baseline NOT "
                f"to be committed in this state")


def main() -> None:
    argv = sys.argv[1:]
    if "--update-baselines" in argv:
        argv.remove("--update-baselines")
        update_baselines(argv)
        return
    json_path = None
    baseline_path = None
    check = False
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
            raise SystemExit("--json requires a path operand")
        json_path = argv[i + 1]
        del argv[i:i + 2]
    if "--baseline" in argv:
        i = argv.index("--baseline")
        if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
            raise SystemExit("--baseline requires a path operand")
        baseline_path = argv[i + 1]
        if not os.path.exists(baseline_path):
            # fail closed: a moved/renamed snapshot must not skip the gate
            raise SystemExit(f"--baseline {baseline_path}: no such file")
        del argv[i:i + 2]
    if "--check" in argv:
        check = True
        argv.remove("--check")
    if json_path or check or baseline_path:
        # gate flags must never fail open: a mistyped suite name has to be
        # a hard error, not a silent fall-through to the run-all path
        if len(argv) != 1 or argv[0] not in SUITES:
            raise SystemExit(
                f"--json/--check/--baseline require exactly one suite of "
                f"{SUITES}, got {argv!r}")
        run_suite_structured(argv[0], json_path, check, baseline_path)
        return
    if argv and argv[0] in SUITES:
        run_suite_inline(argv[0])
        return
    print("name,us_per_call,derived")
    sys.stdout.flush()
    failures = 0
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here,
         env.get("PYTHONPATH", "")])
    for name in SUITES:
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", name],
            capture_output=True, text=True, env=env, cwd=here,
            timeout=3600)
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-2000:])
            print(f"{name}/SUITE_FAILED,0,error")
            failures += 1
        else:
            sys.stdout.write(proc.stdout)
        print(f"{name}/suite_wall,{(time.time()-t0)*1e6:.0f},ok")
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
