"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scale with REPRO_BENCH_FAST=0
for the full (paper-sized) grids; default is the fast grid (CPU-friendly).

  Table 2  -> bench_complexity
  Table 3  -> bench_memory
  Fig. 4   -> bench_convergence
  Table 4/7-> bench_performance
  Sec. 6   -> bench_inference
  App. G   -> bench_ablation
  (ours)   -> bench_roofline (from the multi-pod dry-run artifacts)
  (ours)   -> bench_kernels (Pallas kernels, interpret mode, vs oracles)

Each suite runs in its own subprocess: a single long-lived process
accumulating hundreds of distinct jit executables eventually trips XLA's
CPU JIT ("Failed to materialize symbols"); per-suite isolation bounds that
state and also keeps wall-time numbers independent.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

SUITES = ["complexity", "memory", "kernels", "roofline", "inference",
          "convergence", "ablation", "performance"]


def run_suite_inline(name: str) -> None:
    import importlib
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    for row in mod.run():
        print(",".join(str(x) for x in row))


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] in SUITES:
        run_suite_inline(sys.argv[1])
        return
    print("name,us_per_call,derived")
    sys.stdout.flush()
    failures = 0
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), here,
         env.get("PYTHONPATH", "")])
    for name in SUITES:
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", name],
            capture_output=True, text=True, env=env, cwd=here,
            timeout=3600)
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-2000:])
            print(f"{name}/SUITE_FAILED,0,error")
            failures += 1
        else:
            sys.stdout.write(proc.stdout)
        print(f"{name}/suite_wall,{(time.time()-t0)*1e6:.0f},ok")
        sys.stdout.flush()
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
