"""Pallas kernel micro-benchmarks: interpret-mode correctness deltas vs the
jnp oracles + host-side call timings (TPU wall-times are N/A on this host;
the roofline projections live in bench_roofline).

Two entry points:

  run_structured() -> list of dicts {name, us_per_call, metrics, tolerance,
      pass} -- the machine-readable form ``benchmarks/run.py --json`` writes
      to BENCH_kernels.json; entries with a tolerance are PARITY GATES (CI
      fails the bench job when any is out of tolerance via ``--check``).
  run() -> the legacy (name, us, derived) tuples for the CSV printer.

The fused-vs-unfused comparison rows time the CPU execution paths of the
two codebook-update formulations (the dispatch layer's actual CPU code):
fused = one distance pass + scatter-add stats (ref.vq_assign_update);
baseline = assign, then one-hot + einsum stats + recomputed revival
distances (the pre-fusion math).  The fused pass must be no slower.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.distributed.quantization import (pack_nibbles, quantize_tensor,
                                            unpack_nibbles)
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.spmm_ell_hbm import spmm_ell_hbm_pallas
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas
from repro.kernels.vq_update import vq_assign_update_pallas


def _time(fn, *args, reps=5):
    """Best-of-reps single-call wall time in us (min is the robust
    microbenchmark statistic on a noisy shared host)."""
    jax.block_until_ready(fn(*args))  # compile + warm caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best * 1e6


def time_best_s(fn, reps: int = 3) -> float:
    """Best-of-reps wall seconds of ``fn()`` after one warmup call (compile
    + caches) -- the ONE steady-state measurement policy shared by the
    CI-gated whole-loop benches (epoch executor, inference executor):
    gates compare serving regimes, not cold starts, and must not drift
    apart on warmup/reps/clock handling."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        fn()
        best = min(best, time.time() - t0)
    return best


def _entry(rows, name, us, metrics, tolerance=None):
    ok = True
    if tolerance:
        ok = all(float(metrics[k]) <= float(v) for k, v in tolerance.items())
    rows.append({"name": name, "us_per_call": us, "metrics": metrics,
                 "tolerance": tolerance, "pass": bool(ok)})


def _unfused_update_baseline(x, c):
    """The pre-fusion per-branch update math: assign, then one-hot einsum
    stats, then the revival qerr as a recomputed reconstruction distance."""
    a = ref.vq_assign(x, c)
    onehot = jax.nn.one_hot(a, c.shape[0], dtype=jnp.float32)     # [b, k]
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x.astype(jnp.float32)
    sel = x.astype(jnp.float32) - c.astype(jnp.float32)[a]
    qerr = jnp.sum(sel * sel, axis=-1)
    return a, qerr, counts, sums


def run_structured() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows: list[dict] = []

    # --- vq_assign: interpret kernel vs oracle (tie-tolerant) ---
    x = jax.random.normal(key, (512, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (256, 8))
    got = vq_assign_pallas(x, c, interpret=True)
    want = ref.vq_assign(x, c)
    d = ((x[:, None] - c[None]) ** 2).sum(-1)
    delta = float(jnp.abs(
        jnp.take_along_axis(d, got[:, None].astype(jnp.int32), 1)
        - jnp.take_along_axis(d, want[:, None].astype(jnp.int32), 1)).max())
    us = _time(lambda a, b: vq_assign_pallas(a, b, interpret=True), x, c)
    _entry(rows, "kernel/vq_assign/512x256x8", us,
           {"match": float((got == want).mean()), "dist_delta": delta},
           tolerance={"dist_delta": 1e-5})

    # --- fused vq_update: interpret kernel vs oracle parity.  The gate is
    # tie-tolerant like the vq_assign row: chosen-distance delta + qerr are
    # always gated; counts/sums are gated strictly only when the argmins
    # agree exactly (a legitimate tie-break divergence shifts integer
    # counts, which must not redden CI) ---
    gi, gq, gc, gs = vq_assign_update_pallas(x, c, interpret=True)
    wi, wq, wc, ws = ref.vq_assign_update(x, c)
    delta = float(jnp.abs(
        jnp.take_along_axis(d, gi[:, None].astype(jnp.int32), 1)
        - jnp.take_along_axis(d, wi[:, None].astype(jnp.int32), 1)).max())
    us = _time(lambda a, b: vq_assign_update_pallas(a, b, interpret=True),
               x, c)
    tol = {"dist_delta": 1e-5, "qerr_maxerr": 1e-4}
    if bool((gi == wi).all()):
        tol.update({"counts_maxerr": 0.0, "sums_maxerr": 1e-4})
    _entry(rows, "kernel/vq_update/512x256x8", us,
           {"idx_match": float((gi == wi).mean()), "dist_delta": delta,
            "qerr_maxerr": float(jnp.abs(gq - wq).max()),
            "counts_maxerr": float(jnp.abs(gc - wc).max()),
            "sums_maxerr": float(jnp.abs(gs - ws).max())},
           tolerance=tol)

    # --- fused assign+stats vs unfused assign-then-einsum (CPU paths) at
    # the paper-scale codebook (k=256, f_blk=8) and production batch sizes.
    # The expectation is fused no slower than baseline (typically 1.3-2x
    # faster); the gate is a loose gross-inversion tripwire (2x) rather
    # than a tight bar, because a wall-clock ratio on shared CI runners
    # must not redden the build on scheduling noise ---
    fused = jax.jit(ref.vq_assign_update)
    baseline = jax.jit(_unfused_update_baseline)
    for b in (4096, 65536):
        kx = jax.random.PRNGKey(b)
        xb = jax.random.normal(kx, (b, 8))
        cb = jax.random.normal(jax.random.PRNGKey(b + 1), (256, 8))
        us_fused = _time(fused, xb, cb)
        us_base = _time(baseline, xb, cb)
        _entry(rows, f"kernel/vq_update_fused_vs_unfused/b{b}_k256_f8",
               us_fused,
               {"us_fused": us_fused, "us_baseline": us_base,
                "slowdown": us_fused / max(us_base, 1e-9)},
               tolerance={"slowdown": 2.0})

    # --- spmm_ell resident vs HBM variant sweep over source-matrix sizes.
    # The last shapes exceed the default 8 MiB resident VMEM envelope (the
    # dispatch in kernels/ops.py would pick 'hbm' for them); both variants
    # report so the crossover is visible in one run ---
    for (b, deg, n, f) in [(256, 16, 512, 64),       # resident regime
                           (256, 16, 4096, 128),     # 2 MiB source
                           (512, 16, 16384, 128),    # 8 MiB boundary
                           (512, 16, 32768, 128)]:   # 16 MiB -> HBM regime
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + f), 3)
        idx = jax.random.randint(k1, (b, deg), 0, n)
        val = jax.random.normal(k2, (b, deg))
        xs = jax.random.normal(k3, (n, f))
        variant = ops.spmm_ell_variant(n, f, 4)
        got_r = spmm_ell_pallas(idx, val, xs, interpret=True)
        got_h = spmm_ell_hbm_pallas(idx, val, xs, interpret=True)
        want = ref.spmm_ell(idx, val, xs)
        us_r = _time(lambda a, cc, x_: spmm_ell_pallas(
            a, cc, x_, interpret=True), idx, val, xs)
        us_h = _time(lambda a, cc, x_: spmm_ell_hbm_pallas(
            a, cc, x_, interpret=True), idx, val, xs)
        tag = f"{b}x{deg}_src{n}x{f}"
        _entry(rows, f"kernel/spmm_ell_resident/{tag}", us_r,
               {"maxerr": float(jnp.abs(got_r - want).max())},
               tolerance={"maxerr": 1e-3})
        _entry(rows, f"kernel/spmm_ell_hbm/{tag}", us_h,
               {"maxerr": float(jnp.abs(got_h - want).max()),
                "dispatch": variant},
               tolerance={"maxerr": 1e-3})

    # --- fp8 operand tier: both spmm variants on float8_e4m3fn source rows
    # + f32 per-channel scales must match the oracle on the DEQUANTIZED
    # rows (the int8-parity convention: upcast-in-kernel + one f32 dequant
    # epilogue reproduce the quantization grid exactly) ---
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(77), 3)
    idx = jax.random.randint(k1, (256, 16), 0, 4096)
    val = jax.random.normal(k2, (256, 16))
    xs = jax.random.normal(k3, (4096, 64))
    qt = quantize_tensor(xs, dtype=jnp.float8_e4m3fn)
    deq = qt.q.astype(jnp.float32) * qt.scale
    want = ref.spmm_ell(idx, val, deq)
    got_r = spmm_ell_pallas(idx, val, qt.q, x_scale=qt.scale, interpret=True)
    got_h = spmm_ell_hbm_pallas(idx, val, qt.q, x_scale=qt.scale,
                                interpret=True)
    us_r = _time(lambda a, cc, x_, s: spmm_ell_pallas(
        a, cc, x_, x_scale=s, interpret=True), idx, val, qt.q, qt.scale)
    _entry(rows, "kernel/spmm_ell_fp8_resident/256x16_src4096x64", us_r,
           {"maxerr": float(jnp.abs(got_r - want).max())},
           tolerance={"maxerr": 1e-3})
    _entry(rows, "kernel/spmm_ell_fp8_hbm/256x16_src4096x64", 0.0,
           {"maxerr": float(jnp.abs(got_h - want).max())},
           tolerance={"maxerr": 1e-3})

    # --- uint4 assignment emission (the +a4 tiers): the kernel's narrow
    # emit must agree with the int32 emit id-for-id at k <= 16, and the
    # packed table must round-trip through pack/unpack bit-exactly ---
    xq = jax.random.normal(jax.random.PRNGKey(78), (512, 8))
    cq = jax.random.normal(jax.random.PRNGKey(79), (16, 8))
    i32, _, _, _ = vq_assign_update_pallas(xq, cq, interpret=True)
    i4, _, _, _ = vq_assign_update_pallas(xq, cq, interpret=True,
                                          emit_dtype=jnp.uint4)
    packed = pack_nibbles(i4[None].astype(jnp.uint8))
    round_trip = unpack_nibbles(packed, i4.shape[0])[0]
    us4 = _time(lambda a, b_: vq_assign_update_pallas(
        a, b_, interpret=True, emit_dtype=jnp.uint4), xq, cq)
    _entry(rows, "kernel/vq_update_emit_uint4/512x16x8", us4,
           {"idx_match": float((i4.astype(jnp.int32) == i32).mean()),
            "pack_roundtrip_match":
                float((round_trip.astype(jnp.int32) == i32).mean()),
            "idx_mismatches": float((i4.astype(jnp.int32) != i32).sum())},
           tolerance={"idx_mismatches": 0.0})

    # --- flash attention ---
    q, k, v = (jax.random.normal(kk, (1, 4, 512, 64))
               for kk in jax.random.split(key, 3))
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    _entry(rows, "kernel/flash_attention/1x4x512x64", 0.0,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})

    # --- vq attention decode ---
    n, g, d, kcb, w = 8, 4, 64, 256, 64
    ks = jax.random.split(key, 6)
    qd = jax.random.normal(ks[0], (n, g, d))
    cbk = jax.random.normal(ks[1], (n, kcb, d))
    cbv = jax.random.normal(ks[2], (n, kcb, d))
    mass = jnp.abs(jax.random.normal(ks[3], (n, kcb))) + 0.1
    wk = jax.random.normal(ks[4], (n, w, d))
    wv = jax.random.normal(ks[5], (n, w, d))
    wm = jnp.ones((n, w))
    got = vq_attention_decode_pallas(qd, cbk, cbv, mass, wk, wv, wm,
                                     interpret=True)
    want = jax.vmap(lambda *a: ref.vq_attention_decode(*a))(
        qd, cbk, cbv, mass, wk, wv, wm)
    _entry(rows, "kernel/vq_attention/8x4x64_k256_w64", 0.0,
           {"maxerr": float(jnp.abs(got - want).max())},
           tolerance={"maxerr": 1e-3})
    return rows


def run() -> list[tuple]:
    out = []
    for e in run_structured():
        derived = ";".join(
            f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in e["metrics"].items())
        if not e["pass"]:
            derived += ";PARITY_FAIL"
        out.append((e["name"], e["us_per_call"], derived))
    return out


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
