"""Pallas kernel micro-benchmarks: interpret-mode correctness deltas vs the
jnp oracles + host-side call timings (TPU wall-times are N/A on this host;
the roofline projections live in bench_roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.spmm_ell_hbm import spmm_ell_hbm_pallas
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    rows = []

    x = jax.random.normal(key, (512, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (256, 8))
    got = vq_assign_pallas(x, c, interpret=True)
    want = ref.vq_assign(x, c)
    us = _time(lambda a, b: vq_assign_pallas(a, b, interpret=True), x, c)
    rows.append(("kernel/vq_assign/512x256x8", us,
                 f"match={float((got == want).mean()):.3f}"))

    idx = jax.random.randint(key, (256, 16), 0, 512)
    val = jax.random.normal(key, (256, 16))
    xs = jax.random.normal(key, (512, 64))
    got = spmm_ell_pallas(idx, val, xs, interpret=True)
    want = ref.spmm_ell(idx, val, xs)
    us = _time(lambda a, b, cc: spmm_ell_pallas(a, b, cc, interpret=True),
               idx, val, xs)
    rows.append(("kernel/spmm_ell/256x16x64", us,
                 f"maxerr={float(jnp.abs(got-want).max()):.2e}"))

    # resident vs HBM variant sweep over source-matrix sizes.  The last
    # shapes exceed the default 8 MiB resident VMEM envelope (the dispatch
    # in kernels/ops.py would pick 'hbm' for them); both variants report so
    # the crossover is visible in one run.
    for (b, deg, n, f) in [(256, 16, 512, 64),       # resident regime
                           (256, 16, 4096, 128),     # 2 MiB source
                           (512, 16, 16384, 128),    # 8 MiB boundary
                           (512, 16, 32768, 128)]:   # 16 MiB -> HBM regime
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n + f), 3)
        idx = jax.random.randint(k1, (b, deg), 0, n)
        val = jax.random.normal(k2, (b, deg))
        xs = jax.random.normal(k3, (n, f))
        variant = ops.spmm_ell_variant(n, f, 4)
        got_r = spmm_ell_pallas(idx, val, xs, interpret=True)
        got_h = spmm_ell_hbm_pallas(idx, val, xs, interpret=True)
        want = ref.spmm_ell(idx, val, xs)
        us_r = _time(lambda a, c, x_: spmm_ell_pallas(
            a, c, x_, interpret=True), idx, val, xs)
        us_h = _time(lambda a, c, x_: spmm_ell_hbm_pallas(
            a, c, x_, interpret=True), idx, val, xs)
        tag = f"{b}x{deg}_src{n}x{f}"
        rows.append((f"kernel/spmm_ell_resident/{tag}", us_r,
                     f"maxerr={float(jnp.abs(got_r-want).max()):.2e}"))
        rows.append((f"kernel/spmm_ell_hbm/{tag}", us_h,
                     f"maxerr={float(jnp.abs(got_h-want).max()):.2e},"
                     f"dispatch={variant}"))

    q, k, v = (jax.random.normal(kk, (1, 4, 512, 64))
               for kk in jax.random.split(key, 3))
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    rows.append(("kernel/flash_attention/1x4x512x64", 0.0,
                 f"maxerr={float(jnp.abs(got-want).max()):.2e}"))

    n, g, d, kcb, w = 8, 4, 64, 256, 64
    ks = jax.random.split(key, 6)
    qd = jax.random.normal(ks[0], (n, g, d))
    cbk = jax.random.normal(ks[1], (n, kcb, d))
    cbv = jax.random.normal(ks[2], (n, kcb, d))
    mass = jnp.abs(jax.random.normal(ks[3], (n, kcb))) + 0.1
    wk = jax.random.normal(ks[4], (n, w, d))
    wv = jax.random.normal(ks[5], (n, w, d))
    wm = jnp.ones((n, w))
    got = vq_attention_decode_pallas(qd, cbk, cbv, mass, wk, wv, wm,
                                     interpret=True)
    want = jax.vmap(lambda *a: ref.vq_attention_decode(*a))(
        qd, cbk, cbv, mass, wk, wv, wm)
    rows.append(("kernel/vq_attention/8x4x64_k256_w64", 0.0,
                 f"maxerr={float(jnp.abs(got-want).max()):.2e}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
