"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section
Roofline).  One row per (arch x shape x mesh): the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and the projected
roofline fraction (compute term / dominant term)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import ARCHS
from repro.launch.input_specs import arch_for_cell
from repro.launch.roofline import terms_from_cell


def load_cells(dry_dir: str = "experiments/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def build_table(dry_dir: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for cell in load_cells(dry_dir):
        cfg = arch_for_cell(ARCHS[cell["arch"]], cell["shape"])
        t = terms_from_cell(cell, cfg)
        rows.append({
            "arch": cell["arch"], "shape": cell["shape"],
            "mesh": cell["mesh"], "strategy": cell["strategy"],
            "vq": cell["vq_attn"],
            "compute_s": t.compute_s, "memory_s": t.memory_s,
            "collective_s": t.collective_s,
            "bottleneck": t.bottleneck,
            "model_flops": t.model_flops,
            "hlo_flops_dev": t.hlo_flops,
            "flops_ratio": t.flops_ratio,
            "roofline_fraction": t.details["roofline_fraction"],
            "temp_gib": cell["memory"]["temp_bytes"] / 2**30,
            "hlo_coll_gib": t.details["hlo_coll_bytes"] / 2**30,
        })
    return rows


def markdown_table(rows: list[dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | strat | compute s | memory s | collective s | "
        "bottleneck | MF/HLO | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']}{'+vq' if r['vq'] else ''} | "
            f"{r['strategy']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} |"
            f" {r['collective_s']:.3e} | **{r['bottleneck']}** | "
            f"{r['flops_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['temp_gib']:.1f} |")
    return "\n".join(lines)


def run(out_json: str = "experiments/roofline.json") -> list[tuple]:
    rows = build_table()
    if rows:
        os.makedirs(os.path.dirname(out_json), exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    out = []
    for r in rows:
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((name, dom * 1e6,
                    f"bottleneck={r['bottleneck']};frac="
                    f"{r['roofline_fraction']:.2f}"))
    return out


if __name__ == "__main__":
    rows = build_table()
    print(markdown_table(rows))
    print()
    print(markdown_table(rows, mesh="pod2x16x16"))
