"""Paper Table 3: peak memory at fixed nodes/batch vs fixed messages/batch.

CPU host has no CUDA allocator, so this evaluates the byte-accounting model
at the PAPER's operating point (Reddit: n=232 965, avg degree d=49.8,
hidden f=128, L=3, k=1024; fixed 85K nodes / fixed 1.5M messages -- the
exact Table 3 setting).  Accounting: activations L*nodes*f*4 bytes + edge
structures + method extras (VQ: codebooks + [b,k] sketches; NS: the r^L
neighborhood blow-up).  The claim under test is the *ordering*: VQ pays a
small premium at fixed nodes (it never drops edges) and wins at fixed
messages (Table 3's punchline).
"""
from __future__ import annotations

N = 232_965          # Reddit nodes
DEG = 49.8           # avg degree
F0 = 602             # input feature width (Table 6)
F = 128              # hidden width
L = 3
K = 1024
R = 5                # NS-SAGE fanout


def _act_bytes(nodes: float) -> float:
    # layer-0 input features dominate on Reddit (602-wide) + hidden acts
    return min(nodes, N) * 4 * (F0 + F * (L - 1))


def _edges_bytes(msgs: float) -> float:
    return msgs * 8


def _vq_extras(b: float) -> float:
    branches = 2 * F // 4
    books = L * branches * K * 4 * 4 * 2
    # the sketch of a SPARSE convolution is sparse (paper Sec. 3): its
    # nonzeros track the message count, not b*k
    sketch = b * DEG * 4
    return books + sketch


def run() -> list[tuple]:
    rows = []

    # --- fixed NODES per batch: b = 85K for every method ---
    b = 85_000
    ns_nodes = min(N, b * (1 + R + R * R * 0.4))   # dedup'd r^L blow-up
    cases = {
        "vq-gnn": _act_bytes(b) + _edges_bytes(b * DEG) + _vq_extras(b),
        "ns-sage": _act_bytes(ns_nodes) + _edges_bytes(b * R ** 2),
        "cluster-gcn": _act_bytes(b) + _edges_bytes(b * DEG * 0.6),
        "graphsaint-rw": _act_bytes(b * 1.2) + _edges_bytes(b * L),
    }
    for name, bytes_ in cases.items():
        rows.append((f"memory/fixed_nodes/{name}", 0.0,
                     f"MB={bytes_/2**20:.1f}"))
    ok1 = cases["vq-gnn"] < cases["ns-sage"]

    # --- fixed MESSAGES per batch: every method passes M = 1.5M messages ---
    m = 1_500_000
    cases = {
        "vq-gnn": _act_bytes(m / DEG) + _edges_bytes(m)
        + _vq_extras(m / DEG),                       # keeps ALL b*d messages
        "ns-sage": _act_bytes(min(N, m / (R ** 2) * (1 + R + R * R * 0.4)))
        + _edges_bytes(m),
        "cluster-gcn": _act_bytes(m / (DEG * 0.6)) + _edges_bytes(m),
        "graphsaint-rw": _act_bytes(min(N, m / L)) + _edges_bytes(m),
    }
    for name, bytes_ in cases.items():
        rows.append((f"memory/fixed_messages/{name}", 0.0,
                     f"MB={bytes_/2**20:.1f}"))
    ok2 = all(cases["vq-gnn"] <= v * 1.01 for v in cases.values())
    rows.append(("memory/claim/vq_wins_fixed_messages", 0.0,
                 f"holds={ok2};premium_at_fixed_nodes={ok1}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
