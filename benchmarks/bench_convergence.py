"""Paper Fig. 4: convergence (val accuracy vs training time) for VQ-GNN vs
the sampling baselines, GCN + SAGE backbones on the arxiv look-alike."""
from __future__ import annotations

import json
import os

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_sampler, train_vq

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"


def run(out_json: str = "experiments/convergence.json") -> list[tuple]:
    g = synthetic_arxiv(n=1000 if FAST else 4000)
    epochs = 20 if FAST else 100
    rows, curves = [], {}
    for backbone in (["gcn"] if FAST else ["gcn", "sage"]):
        cfg = GNNConfig(backbone=backbone, f_in=g.f, hidden=64,
                        n_out=g.num_classes, n_layers=2,
                        codebook=CodebookConfig(k=256, f_prod=4))
        runs = {
            "full": train_full(g, cfg, epochs=epochs, eval_every=5),
            "vq": train_vq(g, cfg, epochs=epochs, batch_size=400,
                           eval_every=5),
            "graphsaint-rw": train_sampler(g, cfg, "graphsaint-rw",
                                           epochs=epochs, batch_size=200,
                                           eval_every=5),
            "cluster-gcn": train_sampler(g, cfg, "cluster-gcn",
                                         epochs=epochs, batch_size=200,
                                         eval_every=5),
        }
        for m, r in runs.items():
            curves[f"{backbone}/{m}"] = r["history"]
            # time-to-threshold: first wall-time hitting 90% of final full
            target = 0.9 * runs["full"]["final"]["val"]
            t_hit = next((h["time"] for h in r["history"]
                          if h["val"] >= target), float("inf"))
            rows.append((f"convergence/{backbone}/{m}",
                         r["history"][-1]["time"] * 1e6 / epochs,
                         f"final={r['final']['val']:.4f};"
                         f"t90={t_hit:.1f}s"))
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(curves, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
