"""Paper Fig. 4: convergence (val accuracy vs training time) for VQ-GNN vs
the sampling baselines, GCN + SAGE backbones on the arxiv look-alike.

``run_structured()`` adds the int8 training-parity gate (ISSUE 7): VQ
training with int8 codeword/assignment operands (uint8 table + quantized
codeword snapshots carried through every update step) must match the fp32
VQ run's final val accuracy within ``int8_train_acc_drop <= 0.06`` (the
single-FAST-seed drop spreads 0.00-0.04 across seeds; the bound clears the
observed worst case while still catching a broken quantized update path,
which collapses accuracy to chance)."""
from __future__ import annotations

import json
import os

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_sampler, train_vq

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"
_INT8_GATE = {"int8_train_acc_drop": 0.06}


def run_structured() -> list[dict]:
    from benchmarks.bench_kernels import _entry
    from repro.kernels import ops as kops

    rows: list[dict] = []
    g = synthetic_arxiv(n=1000 if FAST else 4000)
    epochs = 15 if FAST else 60
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=256, f_prod=4))
    r32 = train_vq(g, cfg, epochs=epochs, batch_size=400, eval_every=100)
    # int8 from scratch: precision is read once at state construction, so
    # the override only needs to cover init inside train_vq (the uint8
    # assignment + qcw then flow through updates data-driven)
    kops.configure_kernel_precision("int8")
    try:
        r8 = train_vq(g, cfg, epochs=epochs, batch_size=400,
                      eval_every=100)
    finally:
        kops.configure_kernel_precision(reset=True)
    acc32 = float(r32["final"]["val"])
    acc8 = float(r8["final"]["val"])
    wall32 = r32["history"][-1]["time"] * 1e6 / epochs
    wall8 = r8["history"][-1]["time"] * 1e6 / epochs
    _entry(rows, "convergence/vq_fp32", wall32, {"final_val": acc32})
    _entry(rows, "convergence/vq_int8", wall8,
           {"final_val": acc8,
            "int8_train_acc_drop": max(0.0, acc32 - acc8)},
           tolerance=_INT8_GATE)
    return rows


def run(out_json: str = "experiments/convergence.json") -> list[tuple]:
    g = synthetic_arxiv(n=1000 if FAST else 4000)
    epochs = 20 if FAST else 100
    rows, curves = [], {}
    for backbone in (["gcn"] if FAST else ["gcn", "sage"]):
        cfg = GNNConfig(backbone=backbone, f_in=g.f, hidden=64,
                        n_out=g.num_classes, n_layers=2,
                        codebook=CodebookConfig(k=256, f_prod=4))
        runs = {
            "full": train_full(g, cfg, epochs=epochs, eval_every=5),
            "vq": train_vq(g, cfg, epochs=epochs, batch_size=400,
                           eval_every=5),
            "graphsaint-rw": train_sampler(g, cfg, "graphsaint-rw",
                                           epochs=epochs, batch_size=200,
                                           eval_every=5),
            "cluster-gcn": train_sampler(g, cfg, "cluster-gcn",
                                         epochs=epochs, batch_size=200,
                                         eval_every=5),
        }
        for m, r in runs.items():
            curves[f"{backbone}/{m}"] = r["history"]
            # time-to-threshold: first wall-time hitting 90% of final full
            target = 0.9 * runs["full"]["final"]["val"]
            t_hit = next((h["time"] for h in r["history"]
                          if h["val"] >= target), float("inf"))
            rows.append((f"convergence/{backbone}/{m}",
                         r["history"][-1]["time"] * 1e6 / epochs,
                         f"final={r['final']['val']:.4f};"
                         f"t90={t_hit:.1f}s"))
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(curves, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
