"""Side-effect module: force two virtual CPU devices for the epoch bench.

Must be imported BEFORE the first jax import (XLA reads XLA_FLAGS at
backend init); kept as its own module so bench_epoch's imports stay at the
top of the file.  A no-op when the operator already set XLA_FLAGS.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
