"""Paper App. G ablations: #layers, codebook size, mini-batch size, and
mini-batch sampling strategy (+ ours: gradient-injection on/off -- the
reproduction nuance recorded in EXPERIMENTS.md)."""
from __future__ import annotations

import os

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_vq

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"
EPOCHS = 15 if FAST else 100
N = 1000 if FAST else 4000


def _cfg(g, layers=2, k=256, inject=True):
    return GNNConfig(backbone="gcn", f_in=g.f, hidden=64,
                     n_out=g.num_classes, n_layers=layers,
                     grad_inject=inject,
                     codebook=CodebookConfig(k=k, f_prod=4))


def run() -> list[tuple]:
    g = synthetic_arxiv(n=N)
    rows = []
    for layers in (1, 2, 3):
        r = train_vq(g, _cfg(g, layers=layers), epochs=EPOCHS,
                     batch_size=400, eval_every=EPOCHS)
        rows.append((f"ablation/layers/{layers}", 0.0,
                     f"val={r['final']['val']:.4f}"))
    for k in (64, 256, 512):
        r = train_vq(g, _cfg(g, k=k), epochs=EPOCHS, batch_size=400,
                     eval_every=EPOCHS)
        rows.append((f"ablation/codebook/{k}", 0.0,
                     f"val={r['final']['val']:.4f}"))
    for b in (200, 400, 800):
        r = train_vq(g, _cfg(g), epochs=EPOCHS, batch_size=b,
                     eval_every=EPOCHS)
        rows.append((f"ablation/batch/{b}", 0.0,
                     f"val={r['final']['val']:.4f}"))
    for inject in (True, False):
        r = train_vq(g, _cfg(g, inject=inject), epochs=EPOCHS,
                     batch_size=400, eval_every=EPOCHS)
        rows.append((f"ablation/grad_inject/{inject}", 0.0,
                     f"val={r['final']['val']:.4f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
