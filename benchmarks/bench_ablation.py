"""Scenario matrix (DESIGN.md section 12): backbone x scale method x task.

Every cell trains the same synthetic benchmark graph with one (backbone,
scale method) pair through ``train_scenario`` and reports val accuracy,
the accuracy drop vs the full-graph oracle of the SAME backbone, and
steps/s.  Two kinds of CI gate ride on the emitted rows
(``BENCH_ablation.json``, the ``scenario-matrix`` job):

  - per-cell accuracy floor: ``acc_drop <= ACC_FLOOR`` for every node-task
    (backbone x scale method) cell -- including the LABOR baseline and the
    VQ/sampling hybrid (ISSUE 6 acceptance);
  - sampler-executor throughput: on the dispatch-bound shape (many small
    subgraph batches) the pack-once ``lax.scan`` sampler executor must be
    >= 2x the per-batch host loop's steps/s (``exec_over_loop <= 0.5``),
    timed over IDENTICAL pre-sampled batches so sampling cost cancels.

The paper App. G ablation rows (codebook size, gradient injection) are
kept, ungated, at the tail.  ``REPRO_BENCH_FAST=1`` (default) runs the
small matrix (2 backbones, node task + a link sub-matrix); the full run
sweeps all ``MATRIX_BACKBONES``.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.bench_kernels import _entry, time_best_s
from repro.core.codebook import CodebookConfig
from repro.configs.scenarios import MATRIX_BACKBONES, assert_gnn_only
from repro.graph.batching import pack_sampler_epoch, pad_bucket, \
    subgraph_operands
from repro.graph.datasets import synthetic_arxiv, synthetic_collab
from repro.graph.sampling import sample_epoch
from repro.models.gnn import (GNNConfig, full_train_step, init_gnn,
                              sampler_train_epoch)
from repro.train.gnn_trainer import SCALE_METHODS, train_scenario
from repro.train.optimizer import adam

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"
EPOCHS = 30 if FAST else 100
N = 600 if FAST else 2000
BATCH = 128 if FAST else 400
NODE_BACKBONES = ("gcn", "sage") if FAST else MATRIX_BACKBONES

ACC_FLOOR = {"acc_drop": 0.15}       # node cells: within 15 pts of oracle
LINK_FLOOR = {"acc_drop": 0.30}      # link hits@50 is noisier at this size
EXEC_GATE = {"exec_over_loop": 0.5}  # executor >= 2x host loop


def _cfg(g, backbone, task="node", inject=True, k=256):
    return GNNConfig(backbone=backbone, f_in=g.f, hidden=32,
                     n_out=(g.num_classes if task == "node" else 32),
                     n_layers=2, heads=2, task=task, grad_inject=inject,
                     codebook=CodebookConfig(k=k, f_prod=4))


def _cell(g, cfg, method, **knobs):
    """Train one matrix cell; returns (final metrics, steps, seconds).

    One shared lr for every mini-batched method (the train_sampler default
    1e-3 undertrains the ns_sage/labor cells within the small-matrix epoch
    budget); the full-graph oracle keeps its own default."""
    t0 = time.time()
    lr = None if method == "full" else 3e-3
    r = train_scenario(g, cfg, method, epochs=EPOCHS, batch_size=BATCH,
                       seed=0, eval_every=EPOCHS, lr=lr, **knobs)
    dt = time.time() - t0
    if "losses" in r:                       # samplers: actual step count
        steps = int(sum(len(l) for l in r["losses"]))
    elif method == "full":
        steps = EPOCHS
    else:                                   # vq / hybrid: S fixed per epoch
        steps = EPOCHS * -(-g.n // BATCH)
    return r["final"], steps, dt


def _matrix_rows(rows):
    assert_gnn_only(NODE_BACKBONES)
    g = synthetic_arxiv(n=N, seed=0)
    knobs = {"n_parts": 8, "parts_per_batch": 2}
    for backbone in NODE_BACKBONES:
        cfg = _cfg(g, backbone)
        ref, _, _ = _cell(g, cfg, "full")
        for method in SCALE_METHODS:
            kn = knobs if method == "cluster" else {}
            fin, steps, dt = _cell(g, cfg, method, **kn)
            _entry(rows, f"ablation/matrix/{backbone}/{method}/node",
                   dt * 1e6,
                   {"val": fin["val"], "acc_drop": ref["val"] - fin["val"],
                    "steps_per_s": steps / max(dt, 1e-9)},
                   tolerance=ACC_FLOOR)

    # link-task sub-matrix: the methods whose link path exists end-to-end
    # (sampler link training mines pairs host-side; one backbone keeps the
    # job's wall-clock sane in FAST mode)
    gl = synthetic_collab(n=max(600, N), seed=4)
    for backbone in ("gcn",) if FAST else ("gcn", "sage"):
        cfgl = _cfg(gl, backbone, task="link")
        refl, _, _ = _cell(gl, cfgl, "full")
        for method in ("vq", "ns_sage"):
            fin, steps, dt = _cell(gl, cfgl, method)
            _entry(rows, f"ablation/matrix/{backbone}/{method}/link",
                   dt * 1e6,
                   {"val": fin["val"], "acc_drop": refl["val"] - fin["val"],
                    "steps_per_s": steps / max(dt, 1e-9)},
                   tolerance=LINK_FLOOR)


def _sampler_exec_rows(rows):
    """Throughput gate: per-batch host loop vs pack-once scan executor over
    the SAME pre-sampled epoch (dispatch-bound: many small batches)."""
    import jax
    import jax.numpy as jnp

    g = synthetic_arxiv(n=2048, seed=0)
    cfg = _cfg(g, "gcn")
    rng = np.random.default_rng(0)
    batches = sample_epoch(g, "ns-sage", batch_size=32, rng=rng,
                           fanouts=[3, 3])
    steps = len(batches)
    deg_cap = g.max_degree()
    x = jnp.asarray(g.features)
    labels_np = g.labels
    labels = jnp.asarray(labels_np)
    opt = adam(1e-3)

    def fresh():
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        return [params, opt.init(params)]

    st = fresh()

    def host_epoch():
        loss = None
        for src, dst, nodes, seed_pos, seed_w in batches:
            n_real = len(nodes)
            n_pad = pad_bucket(n_real)
            sub_ops = subgraph_operands(src, dst, n_pad, deg_cap)
            xs = jnp.zeros((n_pad, g.f), jnp.float32
                           ).at[:n_real].set(x[nodes])
            lpad = np.zeros((n_pad,) + labels_np.shape[1:], labels_np.dtype)
            lpad[:n_real] = labels_np[nodes]
            mask = np.zeros(n_pad, np.float32)
            mask[seed_pos] = seed_w
            st[0], st[1], loss = full_train_step(
                st[0], st[1], xs, sub_ops, jnp.asarray(lpad),
                jnp.asarray(mask), cfg, opt)
        jax.block_until_ready(loss)

    t_loop = time_best_s(host_epoch, 3)

    st = fresh()

    def exec_epoch():
        # repacking is part of the executor's per-epoch cost
        splan = pack_sampler_epoch(batches, deg_cap)
        st[0], st[1], losses = sampler_train_epoch(
            st[0], st[1], splan, x, labels, cfg, opt)
        jax.block_until_ready(losses)

    t_exec = time_best_s(exec_epoch, 3)

    _entry(rows, "ablation/sampler_exec/host_loop_n2048_b32",
           t_loop * 1e6, {"steps_per_s": steps / t_loop})
    _entry(rows, "ablation/sampler_exec/scan_n2048_b32", t_exec * 1e6,
           {"steps_per_s": steps / t_exec, "speedup": t_loop / t_exec,
            "exec_over_loop": t_exec / t_loop}, tolerance=EXEC_GATE)


def _legacy_ablation_rows(rows):
    """Paper App. G ablations kept from the pre-matrix bench (ungated)."""
    g = synthetic_arxiv(n=N, seed=0)
    for k in (64, 256) if FAST else (64, 256, 512):
        fin, _, dt = _cell(g, _cfg(g, "gcn", k=k), "vq")
        _entry(rows, f"ablation/codebook/{k}", dt * 1e6,
               {"val": fin["val"]})
    for inject in (True, False):
        fin, _, dt = _cell(g, _cfg(g, "gcn", inject=inject), "vq")
        _entry(rows, f"ablation/grad_inject/{inject}", dt * 1e6,
               {"val": fin["val"]})


def run_structured() -> list[dict]:
    rows: list[dict] = []
    _matrix_rows(rows)
    _sampler_exec_rows(rows)
    _legacy_ablation_rows(rows)
    return rows


def run() -> list[tuple]:
    out = []
    for e in run_structured():
        out.append((e["name"], f"{e['us_per_call']:.0f}",
                    ";".join(f"{k}={v:.3g}"
                             for k, v in e["metrics"].items())))
    return out


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
