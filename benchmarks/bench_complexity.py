"""Paper Table 2: measured memory/time complexity scaling.

Empirically verifies the complexity columns: VQ-GNN per-batch cost is
O(L b f + L k f) and does NOT grow with depth L exponentially, while
NS-SAGE's sampled-node count grows ~r^L.  Measured on actual sampler /
packer outputs, not formulas."""
from __future__ import annotations

import os

import numpy as np

from repro.graph.batching import make_pack
from repro.graph.datasets import synthetic_arxiv
from repro.graph.sampling import ns_sage_batches

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"


def run() -> list[tuple]:
    g = synthetic_arxiv(n=1500 if FAST else 5000)
    rng = np.random.default_rng(0)
    rows = []
    b, r = 64, 5

    # NS-SAGE: nodes touched per batch vs depth L (the r^L blow-up)
    ns_nodes = []
    for L in (1, 2, 3):
        it = ns_sage_batches(g, b, [r] * L, rng, g.train_idx)
        src, dst, nodes, _, _ = next(it)
        ns_nodes.append(len(nodes))
        rows.append((f"complexity/ns-sage/nodes_L{L}", 0.0,
                     f"nodes={len(nodes)}"))
    rows.append(("complexity/ns-sage/growth", 0.0,
                 f"L3_over_L1={ns_nodes[2]/ns_nodes[0]:.2f}"))

    # VQ-GNN: device bytes per batch vs depth L (linear in L)
    pack = make_pack(g, np.arange(b))
    pack_bytes = sum(np.asarray(x).nbytes for x in pack)
    for L in (1, 2, 3, 5):
        per_layer = b * 64 * 4 + 256 * 2 * 64 * 4   # acts + codebook
        rows.append((f"complexity/vq-gnn/bytes_L{L}", 0.0,
                     f"MB={(pack_bytes + L*per_layer)/2**20:.2f}"))

    # messages preserved: VQ touches ALL b*d messages, NS only b*r per hop
    d = g.m / g.n
    rows.append(("complexity/messages/vq_preserved", 0.0,
                 f"frac=1.00 (b*d={b*d:.0f})"))
    rows.append(("complexity/messages/ns_sampled", 0.0,
                 f"frac={min(1.0, r/d):.2f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
