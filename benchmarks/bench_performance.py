"""Paper Tables 4 + 7: accuracy parity across datasets/backbones/methods.

Grid: dataset x backbone x {full-graph, VQ-GNN, NS-SAGE, Cluster-GCN,
GraphSAINT-RW}.  Synthetic look-alike datasets (DESIGN.md section 8); the
claims under test are the paper's *relative* ones:
  - VQ-GNN ~ full-graph on every cell (bounded approximation),
  - samplers are inconsistent across cells (NS-SAGE x GCN is N/A, etc.).
"""
from __future__ import annotations

import json
import os
import time

from repro.core.codebook import CodebookConfig
from repro.graph.batching import inductive_view
from repro.graph.datasets import (synthetic_arxiv, synthetic_collab,
                                  synthetic_flickr, synthetic_ppi,
                                  synthetic_reddit)
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_sampler, train_vq

FAST = os.environ.get("REPRO_BENCH_FAST", "1") == "1"

N = 1000 if FAST else 4000
EPOCHS = 20 if FAST else 120
BATCH = 400


def _datasets():
    ds = {
        "arxiv": synthetic_arxiv(n=N),
        "ppi": synthetic_ppi(n=max(800, N // 2)),
        "collab": synthetic_collab(n=N),
    }
    if not FAST:
        ds["reddit"] = synthetic_reddit(n=N)
        ds["flickr"] = synthetic_flickr(n=N)
    return ds


def _cfg(g, backbone, name):
    task = "link" if name == "collab" else "node"
    n_out = 64 if task == "link" else g.num_classes
    return GNNConfig(backbone=backbone, f_in=g.f, hidden=64, n_out=n_out,
                     n_layers=2, task=task, multilabel=g.multilabel,
                     codebook=CodebookConfig(k=256, f_prod=4))


def run(out_json: str = "experiments/performance.json") -> list[tuple]:
    rows = []
    results = {}
    backbones = ["gcn", "sage", "gat"]
    for dname, g0 in _datasets().items():
        g_train = inductive_view(g0) if dname == "ppi" else g0
        for backbone in backbones:
            cfg = _cfg(g0, backbone, dname)
            cell = {}
            t0 = time.time()
            cell["full"] = train_full(g0 if dname != "ppi" else g_train,
                                      cfg, epochs=EPOCHS,
                                      eval_every=EPOCHS)["final"]
            cell["vq"] = train_vq(g_train, cfg, epochs=EPOCHS,
                                  batch_size=BATCH,
                                  eval_every=EPOCHS)["final"]
            for m in ("ns-sage", "cluster-gcn", "graphsaint-rw"):
                if m == "ns-sage" and backbone == "gcn":
                    cell[m] = {"val": float("nan"), "test": float("nan")}
                    continue   # paper: NS-SAGE incompatible with GCN
                cell[m] = train_sampler(g_train, cfg, m, epochs=EPOCHS,
                                        batch_size=200,
                                        eval_every=EPOCHS)["final"]
            wall = time.time() - t0
            results[f"{dname}/{backbone}"] = cell
            for m, r in cell.items():
                rows.append((f"performance/{dname}/{backbone}/{m}",
                             wall * 1e6 / max(EPOCHS, 1),
                             f"val={r['val']:.4f}"))
    # paper-claim check: VQ within tolerance of full-graph on every cell
    gaps = [results[k]["full"]["val"] - results[k]["vq"]["val"]
            for k in results]
    rows.append(("performance/claim/vq_parity_max_gap", 0.0,
                 f"max_gap={max(gaps):.4f}"))
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(results, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
