"""Host-snapshot environment knobs (the env-read-once contract).

Every ``REPRO_*`` knob that steers code reachable from a jit trace MUST be
read through :func:`env_knob` instead of ``os.environ`` directly.  The
contract (DESIGN.md section 16): dispatch decisions made while tracing a
jitted body must not depend on the live environment, because jax's
executable cache is keyed on (function, shapes, statics) only -- an env
var mutated between two calls of the same shape would silently NOT take
effect on the cached executable but WOULD take effect on the next new
shape, leaving one epoch running a mix of regimes.

:func:`env_knob` therefore reads ``os.environ`` only while no trace is
active (``jax.core.trace_state_clean()``): host-side calls -- tests
monkeypatching ``REPRO_SPMM_VARIANT``, the trainer choosing an executor,
an eager kernel call -- always see the live environment, while calls made
during jit tracing reuse the most recent host-side snapshot.  The one
deliberate exception is the cold-start bootstrap: a knob whose very first
read in the process happens under a trace is snapshotted there (there is
no earlier host-side value to prefer, and refusing would break
``python -c "jax.jit(train)(...)"`` one-liners).

``repro.hostenv`` is the single module in the package allowed to touch
``os.environ`` from jit-reachable code; the ``repro.analysis`` REPRO001
lint rule enforces exactly that.
"""
from __future__ import annotations

import os
from typing import Optional

try:  # public since jax 0.4.x
    from jax.core import trace_state_clean as _trace_state_clean
except ImportError:  # pragma: no cover - older/newer layout
    from jax._src.core import trace_state_clean as _trace_state_clean

# name -> raw value (None records "unset"); refreshed on every host-side
# read, frozen while a trace is active
_snapshot: dict[str, Optional[str]] = {}


def _refresh(name: str) -> None:
    if name not in _snapshot or _trace_state_clean():
        _snapshot[name] = os.environ.get(name)


def env_knob(name: str, default=None):
    """``os.environ.get(name, default)`` with trace-frozen semantics.

    Host-side: a live read (and the snapshot refreshes).  Under a jax
    trace: the last host-side snapshot, so the traced computation is a
    pure function of its operands plus the host-side configuration state.
    """
    _refresh(name)
    val = _snapshot[name]
    return default if val is None else val


def env_knob_set(name: str) -> bool:
    """``name in os.environ`` under the same trace-frozen semantics."""
    _refresh(name)
    return _snapshot[name] is not None


def reset_env_snapshot() -> None:
    """Drop every snapshotted knob (tests; forces fresh host-side reads)."""
    _snapshot.clear()
