"""Approximated forward & backward message passing (paper Eq. 6 / Eq. 7).

The paper splits the messages of a mini-batch into
  * intra-batch messages  C_in X_B            -- computed exactly,
  * out-of-batch messages C~_out X~           -- approximated via codewords,
and back-propagates with the *transposed* approximated weight matrix, using
gradient codewords G~ for the "blue" messages that flow from out-of-batch
nodes (Fig. 2).  Autodiff cannot produce that rule (the codebook is streaming
EMA state), so the backward injection is a ``jax.custom_vjp``.

Two implementation forms, mathematically identical (DESIGN.md section 3):
  * reconstruction form (sparse convolutions): out-of-batch neighbor j's
    features are reconstructed from its per-branch codewords,
    X^_j = concat_beta X~^beta[R^beta[j]], and messages are passed per edge --
    this is the paper's App. E "another implementation" and equals the
    [b, k] sketch because  sum_j C_ij X^_j = sum_v (C_out R)_iv X~_v.
  * sketch form (dense/global convolutions, VQ-Attention): the [b, k]
    cluster-level mixing matrix C~_out = C_out R directly.

Gradient extraction for the codebook update uses the *probe trick*: a zeros
input added at the pre-activation; its cotangent under jax.grad is exactly
G^(l+1) = grad_Z loss (Alg. 1 line 15 needs it for the VQ update).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.spmm_ell_hbm import StripeIndex


# ---------------------------------------------------------------------------
# the custom backward rule (Eq. 7's out-of-batch gradient messages)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def inject_context_grad(x_b: jax.Array, rev_vals: jax.Array,
                        grad_hat: jax.Array, w: Optional[jax.Array]) -> jax.Array:
    """Identity on ``x_b`` in the forward pass.

    In the backward pass, adds the paper's out-of-batch gradient messages

        grad_X_B  +=  ( sum_d rev_vals[:, d] * grad_hat[:, d, :] ) @ W^T

    where ``rev_vals[i, d] = C_{j_d, i}`` are the weights of the reverse
    (batch -> out-of-batch) edges and ``grad_hat[i, d] = G~[c(j_d)]`` are the
    reconstructed gradient codewords of the receiving nodes.  This is the
    ``D_out G~ W^T`` term of Eq. 7 (``D_out = (C^T)_out R``).

    ``w=None`` skips the W^T factor -- used by row-normalized convolutions
    where the probe (and hence the gradient codewords) live at the
    pre-normalization message level (paper App. E decoupling trick).
    """
    del rev_vals, grad_hat, w
    return x_b


def _inject_fwd(x_b, rev_vals, grad_hat, w):
    return x_b, (rev_vals, grad_hat, w)


def _inject_bwd(res, g):
    rev_vals, grad_hat, w = res
    phantom = jnp.einsum('bd,bdf->bf', rev_vals.astype(jnp.float32),
                         grad_hat.astype(jnp.float32))
    if w is not None:
        phantom = phantom @ w.astype(jnp.float32).T
    return (g + phantom.astype(g.dtype), jnp.zeros_like(rev_vals),
            jnp.zeros_like(grad_hat),
            None if w is None else jnp.zeros_like(w))


inject_context_grad.defvjp(_inject_fwd, _inject_bwd)


# ---------------------------------------------------------------------------
# codeword reconstruction (gather per-branch codewords, merge to full width)
# ---------------------------------------------------------------------------

def reconstruct(codewords: jax.Array, assignment: jax.Array,
                node_ids: jax.Array) -> jax.Array:
    """Rebuild full-width vectors for arbitrary nodes from product-VQ state.

    codewords:  [n_branches, k, f_blk]  (feature *or* gradient codewords)
    assignment: [n_branches, n]         per-branch codeword ids of all nodes
    node_ids:   [...] int               global node ids to reconstruct
    returns     [..., n_branches * f_blk]
    """
    n_branches = codewords.shape[0]
    ids = assignment[:, node_ids]                       # [nb, ...]
    gathered = jax.vmap(lambda cw, a: cw[a])(codewords, ids)  # [nb, ..., f_blk]
    out = jnp.moveaxis(gathered, 0, -2)                 # [..., nb, f_blk]
    return out.reshape(*out.shape[:-2], n_branches * codewords.shape[-1])


# ---------------------------------------------------------------------------
# forward context messages
# ---------------------------------------------------------------------------

def context_messages_reconstruct(out_vals: jax.Array, out_ids: jax.Array,
                                 feat_codewords: jax.Array,
                                 assignment: jax.Array) -> jax.Array:
    """Out-of-batch forward messages, reconstruction form.

    out_vals: [b, D]   C_{i, j_d} for out-of-batch neighbors (0 = padding)
    out_ids:  [b, D]   their global node ids
    feat_codewords: [n_branches, k, f_blk];  assignment: [n_branches, n]
    returns   [b, f]   =  sum_d out_vals[:, d] * X^_{j_d}

    Routed per branch through the SpMM-ELL dispatch: the gather source is
    the branch's [k, f_blk] codeword table, so per-branch memory stays
    O(k * f_blk) regardless of graph size and the [b, D, f] reconstructed
    intermediate of the naive form is never materialized on device
    (DESIGN.md section 3) -- sum_d val[:, d] * cw[assign[out_ids[:, d]]]
    is exactly an ELLPACK SpMM with the assignment as the index map.
    """
    cw = jax.lax.stop_gradient(feat_codewords)
    branch_ids = assignment[:, out_ids]                   # [nb, b, D]
    per_branch = [kops.spmm_ell(branch_ids[i], out_vals, cw[i])
                  for i in range(feat_codewords.shape[0])]
    return jnp.concatenate(per_branch, axis=-1)


def context_messages_sketch(c_out_sketch: jax.Array,
                            feat_codewords: jax.Array) -> jax.Array:
    """Out-of-batch forward messages, sketch form (dense convolutions).

    c_out_sketch:  [n_branches, b, k]   C~_out = C_out R, per branch
    feat_codewords:[n_branches, k, f_blk]
    returns        [b, n_branches * f_blk]
    """
    cw = jax.lax.stop_gradient(feat_codewords.astype(jnp.float32))
    per_branch = jnp.einsum('nbk,nkf->nbf',
                            c_out_sketch.astype(jnp.float32), cw)
    nb, b, fb = per_branch.shape
    return per_branch.transpose(1, 0, 2).reshape(b, nb * fb)


# ---------------------------------------------------------------------------
# exact intra-batch messages
# ---------------------------------------------------------------------------

def intra_messages(in_pos: jax.Array, in_vals: jax.Array,
                   x_b: jax.Array,
                   stripe_index: Optional[StripeIndex] = None) -> jax.Array:
    """Exact intra-mini-batch messages  C_in X_B.

    in_pos:  [b, D] int32 -- neighbor position inside the batch (-1 padding /
             out-of-batch; those slots must carry in_vals == 0)
    in_vals: [b, D]
    x_b:     [b, f]
    stripe_index: pack-time tile->stripes metadata for the HBM SpMM variant
             (inference-scale batches where b * f exceeds VMEM)
    """
    idx = jnp.maximum(in_pos, 0)
    return kops.spmm_ell(idx, in_vals, x_b, stripe_index)


# ---------------------------------------------------------------------------
# the assembled approximated message passing of one convolution
# ---------------------------------------------------------------------------

class ConvOperands(NamedTuple):
    """Per-mini-batch operands of one convolution's approximated MP.

    Built by ``repro.core.conv`` from the mini-batch pack + current VQ state.
    """
    in_pos: jax.Array      # [b, D]   intra-batch neighbor positions (-1 pad)
    in_vals: jax.Array     # [b, D]   C_in values (0 on padding)
    out_ids: jax.Array     # [b, D]   out-of-batch neighbor global ids
    out_vals: jax.Array    # [b, D]   C_out values (0 on padding)
    rev_ids: jax.Array     # [b, Dr]  reverse-edge (batch -> out) target ids
    rev_vals: jax.Array    # [b, Dr]  C^T_out values (0 on padding)
    stripe_index: Optional[StripeIndex] = None  # intra-term HBM metadata


def approx_message_passing(ops_: ConvOperands, x_b: jax.Array,
                           feat_codewords: jax.Array,
                           grad_codewords: jax.Array,
                           assignment: jax.Array,
                           w: Optional[jax.Array],
                           inject: bool = True) -> jax.Array:
    """Full Eq. 6 forward with the Eq. 7 backward injection attached.

    Returns M = C_in X_B + C~_out X~  of shape [b, f]; its cotangent under
    autodiff is  C_in^T G_B (+ exact learnable-h paths)  and the custom rule
    adds  D_out G~ (W^T).
    """
    if inject:
        grad_hat = reconstruct(grad_codewords, assignment, ops_.rev_ids)
        grad_hat = jax.lax.stop_gradient(grad_hat)      # [b, Dr, f_grad]
        x_b = inject_context_grad(x_b, ops_.rev_vals, grad_hat, w)
    m = intra_messages(ops_.in_pos, ops_.in_vals, x_b, ops_.stripe_index)
    m = m + context_messages_reconstruct(
        ops_.out_vals, ops_.out_ids, feat_codewords, assignment)
    return m
