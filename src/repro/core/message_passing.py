"""Approximated forward & backward message passing (paper Eq. 6 / Eq. 7).

The paper splits the messages of a mini-batch into
  * intra-batch messages  C_in X_B            -- computed exactly,
  * out-of-batch messages C~_out X~           -- approximated via codewords,
and back-propagates with the *transposed* approximated weight matrix, using
gradient codewords G~ for the "blue" messages that flow from out-of-batch
nodes (Fig. 2).  Autodiff cannot produce that rule (the codebook is streaming
EMA state), so the backward injection is a ``jax.custom_vjp``.

Two implementation forms, mathematically identical (DESIGN.md section 3):
  * reconstruction form (sparse convolutions): out-of-batch neighbor j's
    features are reconstructed from its per-branch codewords,
    X^_j = concat_beta X~^beta[R^beta[j]], and messages are passed per edge --
    this is the paper's App. E "another implementation" and equals the
    [b, k] sketch because  sum_j C_ij X^_j = sum_v (C_out R)_iv X~_v.
  * sketch form (dense/global convolutions, VQ-Attention): the [b, k]
    cluster-level mixing matrix C~_out = C_out R directly.

Both context directions route through ``kops.context_ell`` -- ONE fused
multi-branch kernel dispatch (DESIGN.md section 10).  The Eq. 7 injection
carries *lazy* residuals: instead of materializing the reconstructed
gradient-codeword tensor ``[b, Dr, f_grad]`` in the forward pass, the
residual is ``(rev_vals, rev_ids, grad_codewords, assignment, w)`` --
O(b * Dr) edge operands plus the O(k * f) codebook the step keeps resident
anyway -- and the backward pass streams the phantom term through the same
fused kernel (optionally with the ``@ W^T`` epilogue fused in).

Gradient extraction for the codebook update uses the *probe trick*: a zeros
input added at the pre-activation; its cotangent under jax.grad is exactly
G^(l+1) = grad_Z loss (Alg. 1 line 15 needs it for the VQ update).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.quantization import PackedAssignment
from repro.kernels import ops as kops
from repro.kernels.spmm_ell_hbm import StripeIndex


# ---------------------------------------------------------------------------
# the custom backward rule (Eq. 7's out-of-batch gradient messages)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def inject_context_grad(x_b: jax.Array, rev_vals: jax.Array,
                        rev_ids: jax.Array, grad_codewords: jax.Array,
                        assignment: jax.Array,
                        w: Optional[jax.Array]) -> jax.Array:
    """Identity on ``x_b`` in the forward pass; lazy Eq. 7 residuals.

    In the backward pass, adds the paper's out-of-batch gradient messages

        grad_X_B  +=  ( sum_d rev_vals[:, d] * G~[c(rev_ids[:, d])] ) @ W^T

    where ``rev_vals[i, d] = C_{j_d, i}`` are the weights of the reverse
    (batch -> out-of-batch) edges and ``G~[c(j)]`` is the branch-concat
    gradient codeword of node j under ``assignment``.  This is the
    ``D_out G~ W^T`` term of Eq. 7 (``D_out = (C^T)_out R``), computed by
    the streaming ``kops.context_ell`` kernel at backward time -- the
    forward pass saves only ``(rev_vals, rev_ids, grad_codewords,
    assignment, w)``, never a ``[b, Dr, f_grad]`` reconstruction.

    ``w=None`` skips the W^T factor -- used by row-normalized convolutions
    where the probe (and hence the gradient codewords) live at the
    pre-normalization message level (paper App. E decoupling trick).
    """
    del rev_vals, rev_ids, grad_codewords, assignment, w
    return x_b


def _inject_fwd(x_b, rev_vals, rev_ids, grad_codewords, assignment, w):
    return x_b, (rev_vals, rev_ids, grad_codewords, assignment, w)


def _inject_bwd(res, g):
    rev_vals, rev_ids, grad_codewords, assignment, w = res
    w_t = None if w is None else w.astype(jnp.float32).T
    phantom = kops.context_ell(rev_ids, rev_vals, assignment,
                               grad_codewords, w_t)
    # tree_map: grad_codewords may be a QTensor (int8 values + f32 scales)
    return (g + phantom.astype(g.dtype), jnp.zeros_like(rev_vals), None,
            jax.tree_util.tree_map(jnp.zeros_like, grad_codewords), None,
            None if w is None else jnp.zeros_like(w))


inject_context_grad.defvjp(_inject_fwd, _inject_bwd)


@jax.custom_vjp
def inject_context_grad_materialized(x_b: jax.Array, rev_vals: jax.Array,
                                     grad_hat: jax.Array,
                                     w: Optional[jax.Array]) -> jax.Array:
    """Eq. 7 injection with an explicit ``grad_hat [b, Dr, f]`` tensor.

    For convolutions whose injected gradient is NOT a pure per-branch
    codeword gather (GAT: the reconstructed codeword concat passes through
    the per-head value map before the edge weighting, so branches mix) --
    the lazy form cannot express it and the reconstruction is a genuine
    residual.  Fixed convolutions must use :func:`inject_context_grad`.
    """
    del rev_vals, grad_hat, w
    return x_b


def _inject_mat_fwd(x_b, rev_vals, grad_hat, w):
    return x_b, (rev_vals, grad_hat, w)


def _inject_mat_bwd(res, g):
    rev_vals, grad_hat, w = res
    phantom = jnp.einsum('bd,bdf->bf', rev_vals.astype(jnp.float32),
                         grad_hat.astype(jnp.float32))
    if w is not None:
        phantom = phantom @ w.astype(jnp.float32).T
    return (g + phantom.astype(g.dtype), jnp.zeros_like(rev_vals),
            jnp.zeros_like(grad_hat),
            None if w is None else jnp.zeros_like(w))


inject_context_grad_materialized.defvjp(_inject_mat_fwd, _inject_mat_bwd)


@jax.custom_vjp
def inject_context_grad_table(x_b: jax.Array, rev_vals: jax.Array,
                              grad_table: jax.Array,
                              w: Optional[jax.Array]) -> jax.Array:
    """Eq. 7 injection against a row-independent gradient table.

    For sketch-form (dense) convolutions the receiving "neighbors" are the
    k clusters themselves, identical for every batch row: the phantom term
    is ``rev_vals [b, m] @ grad_table [m, f]``.  The residual is the
    O(m * f) table -- not its ``[b, m, f]`` broadcast.
    """
    del rev_vals, grad_table, w
    return x_b


def _inject_tab_fwd(x_b, rev_vals, grad_table, w):
    return x_b, (rev_vals, grad_table, w)


def _inject_tab_bwd(res, g):
    rev_vals, grad_table, w = res
    phantom = rev_vals.astype(jnp.float32) @ grad_table.astype(jnp.float32)
    if w is not None:
        phantom = phantom @ w.astype(jnp.float32).T
    return (g + phantom.astype(g.dtype), jnp.zeros_like(rev_vals),
            jnp.zeros_like(grad_table),
            None if w is None else jnp.zeros_like(w))


inject_context_grad_table.defvjp(_inject_tab_fwd, _inject_tab_bwd)


# ---------------------------------------------------------------------------
# codeword reconstruction (gather per-branch codewords, merge to full width)
# ---------------------------------------------------------------------------

def reconstruct(codewords: jax.Array, assignment: jax.Array,
                node_ids: jax.Array) -> jax.Array:
    """Rebuild full-width vectors for arbitrary nodes from product-VQ state.

    codewords:  [n_branches, k, f_blk]  (feature *or* gradient codewords)
    assignment: [n_branches, n]         per-branch codeword ids of all nodes
                (int32/uint8 array or nibble-packed ``PackedAssignment``)
    node_ids:   [...] int               global node ids to reconstruct
    returns     [..., n_branches * f_blk]
    """
    n_branches = codewords.shape[0]
    ids = assignment.gather(node_ids) \
        if isinstance(assignment, PackedAssignment) \
        else assignment[:, node_ids]                    # [nb, ...]
    gathered = jax.vmap(lambda cw, a: cw[a])(codewords, ids)  # [nb, ..., f_blk]
    out = jnp.moveaxis(gathered, 0, -2)                 # [..., nb, f_blk]
    return out.reshape(*out.shape[:-2], n_branches * codewords.shape[-1])


# ---------------------------------------------------------------------------
# forward context messages
# ---------------------------------------------------------------------------

def context_messages_reconstruct(out_vals: jax.Array, out_ids: jax.Array,
                                 feat_codewords: jax.Array,
                                 assignment: jax.Array) -> jax.Array:
    """Out-of-batch forward messages, reconstruction form.

    out_vals: [b, D]   C_{i, j_d} for out-of-batch neighbors (0 = padding)
    out_ids:  [b, D]   their global node ids
    feat_codewords: [n_branches, k, f_blk];  assignment: [n_branches, n]
    returns   [b, f]   =  sum_d out_vals[:, d] * X^_{j_d}

    ONE fused ``kops.context_ell`` dispatch regardless of n_branches
    (DESIGN.md section 10): assignment gather + codeword gather + weighted
    accumulate over D happen inside a single kernel against the resident
    [n_branches * k, f_blk] codeword tables -- no per-branch Python loop,
    no [n_branches, b, D] gathered-assignment intermediate, and the naive
    [b, D, f] reconstruction is never materialized on device.
    """
    cw = jax.lax.stop_gradient(feat_codewords)
    return kops.context_ell(out_ids, out_vals, assignment, cw)


def context_messages_sketch(c_out_sketch: jax.Array,
                            feat_codewords: jax.Array) -> jax.Array:
    """Out-of-batch forward messages, sketch form (dense convolutions).

    c_out_sketch:  [n_branches, b, k]   C~_out = C_out R, per branch
    feat_codewords:[n_branches, k, f_blk]
    returns        [b, n_branches * f_blk]
    """
    cw = jax.lax.stop_gradient(feat_codewords.astype(jnp.float32))
    per_branch = jnp.einsum('nbk,nkf->nbf',
                            c_out_sketch.astype(jnp.float32), cw)
    nb, b, fb = per_branch.shape
    return per_branch.transpose(1, 0, 2).reshape(b, nb * fb)


# ---------------------------------------------------------------------------
# exact intra-batch messages
# ---------------------------------------------------------------------------

def intra_messages(in_pos: jax.Array, in_vals: jax.Array,
                   x_b: jax.Array,
                   stripe_index: Optional[StripeIndex] = None) -> jax.Array:
    """Exact intra-mini-batch messages  C_in X_B.

    in_pos:  [b, D] int32 -- neighbor position inside the batch (-1 padding /
             out-of-batch; those slots must carry in_vals == 0)
    in_vals: [b, D]
    x_b:     [b, f]
    stripe_index: pack-time tile->stripes metadata for the HBM SpMM variant
             (inference-scale batches where b * f exceeds VMEM)
    """
    idx = jnp.maximum(in_pos, 0)
    return kops.spmm_ell(idx, in_vals, x_b, stripe_index)


# ---------------------------------------------------------------------------
# the assembled approximated message passing of one convolution
# ---------------------------------------------------------------------------

class ConvOperands(NamedTuple):
    """Per-mini-batch operands of one convolution's approximated MP.

    Built by ``repro.core.conv`` from the mini-batch pack + current VQ state.
    """
    in_pos: jax.Array      # [b, D]   intra-batch neighbor positions (-1 pad)
    in_vals: jax.Array     # [b, D]   C_in values (0 on padding)
    out_ids: jax.Array     # [b, D]   out-of-batch neighbor global ids
    out_vals: jax.Array    # [b, D]   C_out values (0 on padding)
    rev_ids: jax.Array     # [b, Dr]  reverse-edge (batch -> out) target ids
    rev_vals: jax.Array    # [b, Dr]  C^T_out values (0 on padding)
    stripe_index: Optional[StripeIndex] = None  # intra-term HBM metadata


def approx_message_passing(ops_: ConvOperands, x_b: jax.Array,
                           feat_codewords: jax.Array,
                           grad_codewords: jax.Array,
                           assignment: jax.Array,
                           w: Optional[jax.Array],
                           inject: bool = True) -> jax.Array:
    """Full Eq. 6 forward with the Eq. 7 backward injection attached.

    Returns M = C_in X_B + C~_out X~  of shape [b, f]; its cotangent under
    autodiff is  C_in^T G_B (+ exact learnable-h paths)  and the custom rule
    adds  D_out G~ (W^T).  The injection is lazy (module docstring): the
    forward pass stores edge operands + the codebook, not a reconstructed
    ``[b, Dr, f_grad]`` tensor, and the backward streams Eq. 7 through the
    same fused context kernel the forward uses.
    """
    if inject:
        x_b = inject_context_grad(
            x_b, ops_.rev_vals, ops_.rev_ids,
            jax.lax.stop_gradient(grad_codewords), assignment, w)
    m = intra_messages(ops_.in_pos, ops_.in_vals, x_b, ops_.stripe_index)
    m = m + context_messages_reconstruct(
        ops_.out_vals, ops_.out_ids, feat_codewords, assignment)
    return m
