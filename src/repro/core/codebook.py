"""VQ codebook state and streaming (EMA / online k-means) updates.

Implements Algorithm 2 of the paper (VQ-Update): exponential-moving-average
codeword estimation with implicit whitening, plus the product-VQ split
(Appendix E).  A codebook quantizes the *concatenation* of a node's layer-l
input features and its layer-l pre-activation gradients,

    V = X^(l) || G^(l+1)   (paper Sec. 4: "each pair of codewords are
                            concatenated together during VQ updates")

so one assignment matrix R serves both the forward sketch (feature codewords)
and the backward sketch (gradient codewords).

Everything here is a pure function on pytrees -> jit/pjit friendly.  At pod
scale the codebook is replicated and the (counts, sums) statistics of the EMA
update are all-reduced over the data axis -- identical math to the
single-device online k-means (see DESIGN.md section 3).

One-pass-per-branch invariant: :func:`update` performs exactly ONE distance
computation per product-VQ branch per step.  The fused assign+stats kernel
(``kernels/vq_update.py``, dispatched via ``kops.vq_assign_update``) returns
the assignment together with the per-codeword (counts, sums) and the per-row
quantization error, so neither the EMA step, nor dead-codeword revival, nor
the relative-error monitor recomputes distances or materializes a
``[n_branches, b, k]`` one-hot.  Anything added to the update path must
consume these fused outputs rather than re-deriving them.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import quantization
from repro.kernels import ops as kops


class CodebookState(NamedTuple):
    """State of one layer's product-VQ codebooks.

    All leading axes: ``n_branches`` product-VQ branches, each quantizing
    ``f_feat_blk`` feature dims concatenated with ``f_grad_blk`` gradient dims.

    Codewords are stored in *whitened* space (``codewords_w``); reads go
    through :func:`feature_codewords` / :func:`gradient_codewords` which
    un-whiten with the smoothed mean/var (Alg. 2 line 9).
    """

    codewords_w: jax.Array      # [n_branches, k, f_blk]   whitened codewords
    cluster_size: jax.Array     # [n_branches, k]          EMA cluster sizes (eta)
    cluster_sum: jax.Array      # [n_branches, k, f_blk]   EMA cluster sums (Sigma)
    mean: jax.Array             # [n_branches, f_blk]      smoothed E[V]
    var: jax.Array              # [n_branches, f_blk]      smoothed Var[V]
    step: jax.Array             # []                       update counter

    @property
    def n_branches(self) -> int:
        return self.codewords_w.shape[0]

    @property
    def k(self) -> int:
        return self.codewords_w.shape[1]

    @property
    def f_blk(self) -> int:
        return self.codewords_w.shape[2]


class UpdateStats(NamedTuple):
    """Per-batch byproducts of :func:`update`, emitted by the fused kernel.

    All in *whitened* concat space (the space assignments are made in), so
    they come for free from the single distance pass -- consumers must not
    recompute them.
    """

    assignment: jax.Array   # [n_branches, b] int32  nearest codeword per row
    qerr: jax.Array         # [n_branches, b]        ||v_w - c_assign||^2
    vnorm2: jax.Array       # [n_branches, b]        ||v_w||^2

    def relative_error(self) -> jax.Array:
        """Whitened-space VQ relative error ||V - R V~|| / ||V|| of this
        batch -- the free training-loop convergence monitor (the Theorem-2
        feature-half epsilon is :func:`relative_error` below)."""
        return jnp.sqrt(jnp.sum(self.qerr) /
                        (jnp.sum(self.vnorm2) + 1e-12))


class CodebookConfig(NamedTuple):
    k: int = 256                 # number of codewords per branch
    f_prod: int = 4              # feature dims per product-VQ branch
    gamma: float = 0.99          # EMA decay for codeword stats (Alg. 2)
    beta: float = 0.999          # EMA decay for whitening stats (Alg. 2)
    eps: float = 1e-5
    whiten: bool = True          # implicit whitening (App. E)
    revive_threshold: float = 0.05   # EMA size under which a codeword is
    # considered dead and re-seeded on the worst-quantized batch rows


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def branch_layout(f_feat: int, f_grad: int, f_prod: int) -> tuple[int, int, int]:
    """Return (n_branches, f_feat_blk, f_grad_blk).

    The paper pairs feature block i with gradient block i under a single
    assignment matrix ("paired" mode); this requires the same number of
    blocks on each side, which we arrange by scaling the per-branch block
    width on the larger side.
    """
    import math
    cap = min(max(1, f_feat // f_prod), max(1, f_grad // f_prod))
    g = math.gcd(f_feat, f_grad)
    n_branches = 1
    for d in range(1, g + 1):
        if g % d == 0 and d <= cap:
            n_branches = d
    return n_branches, f_feat // n_branches, f_grad // n_branches


def init_codebook(key: jax.Array, f_feat: int, f_grad: int,
                  cfg: CodebookConfig) -> CodebookState:
    n_branches, fb, gb = branch_layout(f_feat, f_grad, cfg.f_prod)
    f_blk = fb + gb
    cw = 0.02 * jax.random.normal(key, (n_branches, cfg.k, f_blk), jnp.float32)
    return CodebookState(
        codewords_w=cw,
        cluster_size=jnp.ones((n_branches, cfg.k), jnp.float32),
        cluster_sum=cw.copy(),
        mean=jnp.zeros((n_branches, f_blk), jnp.float32),
        var=jnp.ones((n_branches, f_blk), jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# whitening helpers (Alg. 2 lines 2-4, 9)
# ---------------------------------------------------------------------------

def _whiten(v: jax.Array, mean: jax.Array, var: jax.Array, eps: float) -> jax.Array:
    return (v - mean[None, :]) * jax.lax.rsqrt(var[None, :] + eps)


def _unwhiten(v: jax.Array, mean: jax.Array, var: jax.Array, eps: float) -> jax.Array:
    return v * jnp.sqrt(var[None, :] + eps) + mean[None, :]


def _split_branches(x: jax.Array, n_branches: int) -> jax.Array:
    """[b, f] -> [n_branches, b, f // n_branches]."""
    b, f = x.shape
    return x.reshape(b, n_branches, f // n_branches).transpose(1, 0, 2)


def _merge_branches(x: jax.Array) -> jax.Array:
    """[n_branches, m, f_blk] -> [m, n_branches * f_blk]."""
    n, m, fb = x.shape
    return x.transpose(1, 0, 2).reshape(m, n * fb)


# ---------------------------------------------------------------------------
# codeword reads
# ---------------------------------------------------------------------------

def _unwhitened_codewords(state: CodebookState, eps: float) -> jax.Array:
    """[n_branches, k, f_blk] in original (un-whitened) space."""
    return jax.vmap(lambda c, m, v: _unwhiten(c, m, v, eps))(
        state.codewords_w, state.mean, state.var)


def feature_codewords(state: CodebookState, f_feat: int,
                      cfg: CodebookConfig) -> jax.Array:
    """Per-branch feature codewords X~: [n_branches, k, f_feat_blk]."""
    n = state.n_branches
    fb = f_feat // n
    return _unwhitened_codewords(state, cfg.eps)[:, :, :fb]


def gradient_codewords(state: CodebookState, f_feat: int,
                       cfg: CodebookConfig) -> jax.Array:
    """Per-branch gradient codewords G~: [n_branches, k, f_grad_blk]."""
    n = state.n_branches
    fb = f_feat // n
    return _unwhitened_codewords(state, cfg.eps)[:, :, fb:]


def quantized_codewords(state: CodebookState, f_feat: int,
                        cfg: CodebookConfig, *,
                        prev_feat: Optional[quantization.QTensor] = None,
                        prev_grad: Optional[quantization.QTensor] = None,
                        dtype=jnp.int8
                        ) -> tuple[quantization.QTensor, quantization.QTensor]:
    """Quantized kernel operands of the (feature, gradient) codeword tables.

    The quantize-on-update hook of the quantized tiers (DESIGN.md sections
    13/15): each table becomes a QTensor with per-branch/per-channel scales
    ([nb, 1, f_blk], amax over the k codewords only) -- the exact layout
    ``kops.context_ell`` dequantizes in one epilogue row.  ``dtype`` picks
    int8 or float8_e4m3fn storage for a fresh snapshot; passing the
    previous step's QTensors pins the dtype to theirs and enables the
    drift-aware rescale: the quantization grid is reused while the EMA
    step barely moves the table, keeping serving-side quantized bytes
    stable across refreshes.
    """
    fcw = feature_codewords(state, f_feat, cfg)
    gcw = gradient_codewords(state, f_feat, cfg)
    return (quantization.quantize_codewords(fcw, prev=prev_feat, dtype=dtype),
            quantization.quantize_codewords(gcw, prev=prev_grad, dtype=dtype))


# ---------------------------------------------------------------------------
# assignment
# ---------------------------------------------------------------------------

def assign(state: CodebookState, feats: jax.Array, grads: jax.Array,
           cfg: CodebookConfig) -> jax.Array:
    """Nearest-codeword assignment in whitened concat space.

    feats: [b, f_feat], grads: [b, f_grad]  ->  [n_branches, b] int32.
    """
    n = state.n_branches
    v = jnp.concatenate(
        [_split_branches(feats.astype(jnp.float32), n),
         _split_branches(grads.astype(jnp.float32), n)], axis=-1)
    if cfg.whiten:
        v = jax.vmap(lambda x, m, s: _whiten(x, m, s, cfg.eps))(
            v, state.mean, state.var)
    return jax.vmap(kops.vq_assign)(v, state.codewords_w)


def assign_features_only(state: CodebookState, feats: jax.Array, f_feat: int,
                         cfg: CodebookConfig) -> jax.Array:
    """Assignment using only the feature half (inference / inductive setting).

    The paper (Sec. 6, PPI inductive): "during the inference stage, we find
    the codeword assignments (i.e. the nearest codeword) of the test nodes".
    At inference no gradients exist, so distance is measured on feature dims.
    """
    n = state.n_branches
    fb = f_feat // n
    v = _split_branches(feats.astype(jnp.float32), n)
    if cfg.whiten:
        v = jax.vmap(lambda x, m, s: _whiten(x, m, s, cfg.eps))(
            v, state.mean[:, :fb], state.var[:, :fb])
    return jax.vmap(kops.vq_assign)(v, state.codewords_w[:, :, :fb])


# ---------------------------------------------------------------------------
# VQ-Update (Algorithm 2)
# ---------------------------------------------------------------------------

def update(state: CodebookState, feats: jax.Array, grads: jax.Array,
           cfg: CodebookConfig, *,
           axis_name: Optional[str] = None
           ) -> tuple[CodebookState, UpdateStats]:
    """One streaming VQ update with a mini-batch of (features || gradients).

    Returns (new_state, :class:`UpdateStats`) -- the stats carry the
    assignment [n_branches, b] plus the per-row quantization error the
    single fused distance pass emits (module docstring: one-pass-per-branch
    invariant).  Cluster statistics come fused from the kernel; there is no
    one-hot / ``[n, b, k]`` einsum on any path.

    If ``axis_name`` is given the (counts, sums, batch moments) are psum-ed
    over that mesh axis so that data-parallel replicas learn one codebook.
    """
    n = state.n_branches
    v = jnp.concatenate(
        [_split_branches(feats.astype(jnp.float32), n),
         _split_branches(grads.astype(jnp.float32), n)], axis=-1)
    b = v.shape[1]

    # --- batch moments (possibly cross-replica) ---
    if axis_name is None:
        batch_mean = jnp.mean(v, axis=1)                     # [n, f_blk]
        batch_var = jnp.var(v, axis=1)
    else:
        s1 = jax.lax.psum(jnp.sum(v, axis=1), axis_name)
        s2 = jax.lax.psum(jnp.sum(v * v, axis=1), axis_name)
        cnt = jax.lax.psum(jnp.asarray(b, jnp.float32), axis_name)
        batch_mean = s1 / cnt
        batch_var = jnp.maximum(s2 / cnt - batch_mean ** 2, 0.0)

    if cfg.whiten:
        new_mean = state.mean * cfg.beta + batch_mean * (1.0 - cfg.beta)
        new_var = state.var * cfg.beta + batch_var * (1.0 - cfg.beta)
        vw = jax.vmap(lambda x, m, s: _whiten(x, m, s, cfg.eps))(
            v, new_mean, new_var)
    else:
        new_mean, new_var = state.mean, state.var
        vw = v

    # --- fused: nearest codeword + cluster stats + per-row qerr, one
    # distance pass per branch (kernels/vq_update.py / the scatter oracle) ---
    assignment, qerr, counts, sums = jax.vmap(kops.vq_assign_update)(
        vw, state.codewords_w)        # [n, b], [n, b], [n, k], [n, k, f_blk]
    if axis_name is not None:
        counts = jax.lax.psum(counts, axis_name)
        sums = jax.lax.psum(sums, axis_name)

    new_size = state.cluster_size * cfg.gamma + counts * (1.0 - cfg.gamma)
    new_sum = state.cluster_sum * cfg.gamma + sums * (1.0 - cfg.gamma)
    new_cw = new_sum / jnp.maximum(new_size, cfg.eps)[..., None]

    # dead codewords keep their previous position
    alive = (new_size > 1e-3)[..., None]
    new_cw = jnp.where(alive, new_cw, state.codewords_w)

    # --- dead-codeword revival: park starved codewords on the batch rows
    # with the largest quantization error (keeps the codebook fully used;
    # standard online-k-means practice, deterministic and jit-friendly).
    # The ranking consumes the kernel-emitted qerr -- cheap [k]/[b]-shaped
    # post-processing, no recomputed reconstruction distances.  Under data
    # parallelism the candidate rows are all-gathered first: the dead mask
    # is replica-identical (psum'd sizes), so picking from replica-LOCAL
    # rows would silently write different replacement codewords on every
    # device and diverge the "replicated" codebooks ---
    if cfg.revive_threshold > 0:
        vw_rev, qerr_rev = vw, qerr
        if axis_name is not None:
            vw_rev = jax.lax.all_gather(vw, axis_name, axis=1, tiled=True)
            qerr_rev = jax.lax.all_gather(qerr, axis_name, axis=1,
                                          tiled=True)
        n_rev = min(cfg.k, qerr_rev.shape[-1])
        _, worst = jax.lax.top_k(qerr_rev, n_rev)             # [n, n_rev]
        worst_rows = jax.vmap(lambda vv, ww: vv[ww])(vw_rev, worst)
        dead = new_size < cfg.revive_threshold                # [n, k]
        # rank dead codewords so each picks a distinct worst row
        rank = jnp.cumsum(dead.astype(jnp.int32), axis=1) - 1
        rank = jnp.clip(rank, 0, n_rev - 1)
        repl = jax.vmap(lambda wr, rk: wr[rk])(worst_rows, rank)
        new_cw = jnp.where(dead[..., None], repl, new_cw)
        new_size = jnp.where(dead, 1.0, new_size)
        new_sum = jnp.where(dead[..., None], repl, new_sum)

    stats = UpdateStats(assignment=assignment, qerr=qerr,
                        vnorm2=jnp.sum(vw * vw, axis=-1))
    return CodebookState(new_cw, new_size, new_sum, new_mean, new_var,
                         state.step + 1), stats


def kmeanspp_init(key: jax.Array, state: CodebookState, feats: jax.Array,
                  grads: jax.Array, cfg: CodebookConfig) -> CodebookState:
    """Seed codewords from a batch (random rows + jitter), jit-compatible.

    A light-weight stand-in for k-means++ seeding: the streaming EMA updates
    converge from here (paper App. F uses random init as well).
    """
    n = state.n_branches
    v = jnp.concatenate(
        [_split_branches(feats.astype(jnp.float32), n),
         _split_branches(grads.astype(jnp.float32), n)], axis=-1)
    b = v.shape[1]
    mean = jnp.mean(v, axis=1)
    var = jnp.maximum(jnp.var(v, axis=1), 0.0)
    if cfg.whiten:
        vw = jax.vmap(lambda x, m, s: _whiten(x, m, s, cfg.eps))(v, mean, var)
    else:
        vw = v
    kidx, knoise = jax.random.split(key)
    rows = jax.random.randint(kidx, (n, cfg.k), 0, b)
    seeds = jax.vmap(lambda vv, rr: vv[rr])(vw, rows)          # [n, k, f_blk]
    seeds = seeds + 0.01 * jax.random.normal(knoise, seeds.shape, seeds.dtype)
    return CodebookState(
        codewords_w=seeds,
        cluster_size=jnp.ones_like(state.cluster_size),
        cluster_sum=seeds.copy(),
        mean=mean if cfg.whiten else state.mean,
        var=var if cfg.whiten else state.var,
        step=state.step,
    )


def relative_error(state: CodebookState, feats: jax.Array, grads: jax.Array,
                   assignment: jax.Array, f_feat: int,
                   cfg: CodebookConfig) -> jax.Array:
    """VQ relative error  eps = ||X - R X~||_F / ||X||_F  on the feature half.

    This is the epsilon appearing in Theorem 2 / Corollary 3 -- an offline
    oracle (tests, benchmarks): it reconstructs in un-whitened feature space,
    which costs a gather the training loop never pays.  In-training
    monitoring uses :meth:`UpdateStats.relative_error`, the whitened-space
    epsilon the fused update kernel emits for free.
    """
    n = state.n_branches
    xcw = feature_codewords(state, f_feat, cfg)               # [n, k, fb]
    xb = _split_branches(feats.astype(jnp.float32), n)        # [n, b, fb]
    recon = jax.vmap(lambda c, a: c[a])(xcw, assignment)      # [n, b, fb]
    num = jnp.sqrt(jnp.sum((xb - recon) ** 2))
    den = jnp.sqrt(jnp.sum(xb ** 2)) + 1e-12
    return num / den
