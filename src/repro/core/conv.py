"""Generalized graph convolution (paper Sec. 2, Eq. 1-2) operand builders.

A convolution matrix ``C^(s)`` is either *fixed* (GCN / SAGE-Mean / GIN / GDC
-- entries derivable from the adjacency structure and degrees) or *learnable*
(GAT / Graph-Transformer -- ``C_ij = frak_C_ij * h_theta(X_i, X_j)``,
optionally row-normalized).

This module converts a mini-batch "pack" (padded neighbor lists produced by
the graph pipeline) + the current VQ state into the per-convolution
:class:`~repro.core.message_passing.ConvOperands`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import codebook as cbm
from repro.core.codebook import CodebookState, CodebookConfig
from repro.core.message_passing import ConvOperands
from repro.distributed.quantization import PackedAssignment, QTensor
from repro.kernels import ops as kops
from repro.kernels.spmm_ell_hbm import StripeIndex


class MinibatchPack(NamedTuple):
    """Device-side mini-batch of nodes with padded (ELLPACK) neighbor lists.

    Produced by ``repro.graph.batching``; all shapes static per dataset.
    ``nbr_*`` are the in-edges (messages INTO batch nodes, forward pass);
    ``rev_*`` are the out-edges (messages FROM batch nodes -- the "blue"
    backward messages of Fig. 2).  Positions are the index inside the batch
    if the other endpoint is also in the batch, else -1.
    ``stripe_index`` (optional, built by the packer) is the tile->stripes
    scalar-prefetch metadata for the intra-batch term's HBM SpMM variant,
    used when b * f exceeds the VMEM envelope (DESIGN.md section 3).
    ``slot_mask`` (optional, [b]) is 0 on the wrap-padded slots of a tail
    batch -- those rows are real (wrapped) nodes whose messages stay valid,
    but the loss must skip them (DESIGN.md section 9).
    """
    batch_ids: jax.Array   # [b]      global node ids
    nbr_ids: jax.Array     # [b, D]   in-neighbor global ids (0 on padding)
    nbr_mask: jax.Array    # [b, D]   1.0 on real edges
    nbr_pos: jax.Array     # [b, D]   in-batch position or -1
    rev_ids: jax.Array     # [b, Dr]  out-edge target global ids
    rev_mask: jax.Array    # [b, Dr]
    rev_pos: jax.Array     # [b, Dr]
    stripe_index: Optional[StripeIndex] = None
    slot_mask: Optional[jax.Array] = None

    @property
    def b(self) -> int:
        return self.batch_ids.shape[0]


class QuantizedCodewords(NamedTuple):
    """int8 kernel-operand snapshot of a layer's codeword tables.

    Each QTensor pairs [n_branches, k, f_blk] int8 values with
    [n_branches, 1, f_blk] f32 per-branch/per-channel scales -- the layout
    ``kops.context_ell`` consumes natively (DESIGN.md section 13).
    """
    feat: QTensor   # feature codewords X~ (Eq. 6 forward)
    grad: QTensor   # gradient codewords G~ (Eq. 7 backward)


class LayerVQState(NamedTuple):
    """Per-layer streaming VQ state: codebook + global assignment table.

    ``assignment`` is int32, uint8 under the int8/fp8 operand tiers
    (k <= 256), or a nibble-packed ``PackedAssignment`` under the +a4
    tiers (k <= 16) -- the kernels accept every storage form.  ``qcw``,
    when present, is the int8 or fp8 snapshot of the codeword tables the
    layers feed the context kernels instead of dense f32 slices; it is
    refreshed by the codebook update (quantize-on-update, in the snapshot's
    own storage dtype) and preserved untouched by assignment scatters.
    """
    codebook: CodebookState
    # [n_branches, n] codeword id per node: int32 | uint8 | PackedAssignment
    assignment: jax.Array | PackedAssignment
    counts: jax.Array      # [n_branches, k] f32    histogram of `assignment`
    qcw: Optional[QuantizedCodewords] = None


def branch_histogram(ids: jax.Array, k: int,
                     weights: Optional[jax.Array] = None) -> jax.Array:
    """Per-branch codeword histogram as ONE flattened segment-sum.

    ids: [n_branches, m] int codeword ids; weights: optional [n_branches, m]
    (default 1.0 per id) -> [n_branches, k] float32.

    Offsetting branch beta's ids by beta * k turns the per-branch
    histograms into a single 1-D segment-sum over n_branches * k buckets --
    one scatter instead of the n_branches-deep vmap'd ``.at[].add`` chains
    these hot paths (every train step) used to compile to.
    """
    nb, m = ids.shape
    flat = (ids.astype(jnp.int32)
            + (k * jnp.arange(nb, dtype=jnp.int32))[:, None]).reshape(-1)
    w = jnp.ones((nb * m,), jnp.float32) if weights is None \
        else weights.astype(jnp.float32).reshape(-1)
    return jax.ops.segment_sum(
        w, flat, num_segments=nb * k).reshape(nb, k)


def refresh_assignment(state: LayerVQState, batch_ids: jax.Array,
                       new_assign: jax.Array) -> LayerVQState:
    """Scatter the refreshed batch assignments into the global table
    (Alg. 1 line 16, 'synchronize the codeword assignment matrix')."""
    k = state.counts.shape[-1]
    packed = isinstance(state.assignment, PackedAssignment)
    old = state.assignment.gather(batch_ids) if packed \
        else state.assignment[:, batch_ids]                     # [nb, b]
    # -1 on the evicted ids, +1 on the refreshed ones, in one segment-sum
    delta = branch_histogram(
        jnp.concatenate([old, new_assign.astype(old.dtype)], axis=1), k,
        jnp.concatenate([jnp.full(old.shape, -1.0, jnp.float32),
                         jnp.ones(new_assign.shape, jnp.float32)], axis=1))
    if packed:
        # parity-pass nibble scatter; batch_ids are distinct per batch (the
        # EpochPlan pack contract), which scatter_nibbles requires
        assignment = state.assignment.scatter(batch_ids, new_assign)
    else:
        assignment = state.assignment.at[:, batch_ids].set(
            new_assign.astype(state.assignment.dtype))
    return LayerVQState(state.codebook, assignment, state.counts + delta,
                        state.qcw)


def assignment_dtype(cfg: CodebookConfig):
    """Element dtype of the global assignment table under the active
    kernel precision tier: uint8 when a quantized tier is on and k fits a
    byte (the 4x VMEM-envelope win on the fused context kernel's resident
    table), else int32.  The +a4 tiers additionally nibble-pack the uint8
    values two-per-byte -- see ``assignment_packed``."""
    quantized = kops.precision_codeword_dtype() is not None and cfg.k <= 256
    return jnp.uint8 if quantized else jnp.int32


def assignment_packed(cfg: CodebookConfig) -> bool:
    """True when the active tier nibble-packs the assignment table
    (a '+a4' tier and k <= 16; larger k silently stays unpacked, matching
    the uint8 fallback to int32 for k > 256)."""
    return kops.precision_packs_assignment() and cfg.k <= 16


def quantize_layer_state(state: LayerVQState, f_feat: int,
                         cfg: CodebookConfig,
                         dtype=jnp.int8) -> LayerVQState:
    """(Re)build the quantized codeword snapshot from the current codebook,
    reusing the previous snapshot's scales inside the drift band.

    ``dtype`` (int8 or float8_e4m3fn) only matters on the first build;
    with an existing snapshot the requantization keeps its storage dtype
    (data-driven -- this runs inside jitted update steps, which must not
    read the precision knob)."""
    prev = state.qcw
    qf, qg = cbm.quantized_codewords(
        state.codebook, f_feat, cfg,
        prev_feat=None if prev is None else prev.feat,
        prev_grad=None if prev is None else prev.grad,
        dtype=dtype)
    return state._replace(qcw=QuantizedCodewords(qf, qg))


def layer_codewords(vq: LayerVQState, f_feat: int, cfg: CodebookConfig, *,
                    dense: bool = False):
    """The (feature, gradient) codeword operands a layer feeds the context
    kernels: the int8 QTensor snapshot when one is attached, else dense f32
    slices.  ``dense=True`` forces f32 materialization -- GAT and the
    Graph-Transformer mix branches through per-head weight maps, so their
    math needs real tables, not kernel-side dequant epilogues.
    """
    if vq.qcw is not None and not dense:
        return vq.qcw.feat, vq.qcw.grad
    return (cbm.feature_codewords(vq.codebook, f_feat, cfg),
            cbm.gradient_codewords(vq.codebook, f_feat, cfg))


def init_layer_vq_state(key: jax.Array, n_nodes: int, f_feat: int,
                        f_grad: int, cfg: CodebookConfig) -> LayerVQState:
    from repro.core.codebook import init_codebook
    k_cb, k_assign = jax.random.split(key)
    cb = init_codebook(k_cb, f_feat, f_grad, cfg)
    dtype = assignment_dtype(cfg)
    assignment = jax.random.randint(
        k_assign, (cb.n_branches, n_nodes), 0, cfg.k).astype(dtype)
    counts = branch_histogram(assignment, cfg.k)
    if assignment_packed(cfg):
        assignment = PackedAssignment.pack(assignment)
    state = LayerVQState(cb, assignment, counts)
    cw_dtype = kops.precision_codeword_dtype()
    if cw_dtype is not None:
        state = quantize_layer_state(state, f_feat, cfg, dtype=cw_dtype)
    return state


# ---------------------------------------------------------------------------
# fixed convolution edge values (paper Table 1)
# ---------------------------------------------------------------------------

def fixed_edge_values(kind: str, pack: MinibatchPack,
                      degrees: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Edge values of a fixed convolution for a mini-batch.

    kind:
      'gcn'  : C = D~^-1/2 A~ D~^-1/2  (self-loop handled via `self_vals`)
      'mean' : C = D^-1 A              (SAGE-Mean aggregator)
      'adj'  : C = A                   (GIN aggregation / GAT mask)
    degrees: [n] float -- raw degrees (no self loop).

    Returns (in_vals, out_vals, rev_vals, self_vals):
      in_vals/out_vals split the forward in-edge values by in/out-of-batch;
      rev_vals are the C_{j,i} values on out-edges to out-of-batch targets;
      self_vals [b] is the diagonal (self-loop) weight, 0 if none.
    """
    deg_i = degrees[pack.batch_ids]                       # [b]
    deg_in = degrees[pack.nbr_ids]                        # [b, D]
    deg_rev = degrees[pack.rev_ids]                       # [b, Dr]

    if kind == 'gcn':
        dt_i = deg_i + 1.0
        vals = pack.nbr_mask / jnp.sqrt(dt_i[:, None] * (deg_in + 1.0))
        rev = pack.rev_mask / jnp.sqrt((deg_rev + 1.0) * dt_i[:, None])
        self_vals = 1.0 / dt_i
    elif kind == 'mean':
        vals = pack.nbr_mask / jnp.maximum(deg_i, 1.0)[:, None]
        rev = pack.rev_mask / jnp.maximum(deg_rev, 1.0)
        self_vals = jnp.zeros_like(deg_i)
    elif kind == 'adj':
        vals = pack.nbr_mask
        rev = pack.rev_mask
        self_vals = jnp.zeros_like(deg_i)
    else:
        raise ValueError(f"unknown fixed conv kind: {kind}")

    in_vals = jnp.where(pack.nbr_pos >= 0, vals, 0.0)
    out_vals = jnp.where(pack.nbr_pos < 0, vals, 0.0)
    # only out-of-batch reverse targets are injected (in-batch ones are
    # handled exactly by autodiff through the intra term)
    rev_vals = jnp.where(pack.rev_pos < 0, rev, 0.0)
    return in_vals, out_vals, rev_vals, self_vals


def fixed_conv_operands(kind: str, pack: MinibatchPack,
                        degrees: jax.Array) -> tuple[ConvOperands, jax.Array]:
    in_vals, out_vals, rev_vals, self_vals = fixed_edge_values(
        kind, pack, degrees)
    ops_ = ConvOperands(
        in_pos=pack.nbr_pos, in_vals=in_vals,
        out_ids=pack.nbr_ids, out_vals=out_vals,
        rev_ids=pack.rev_ids, rev_vals=rev_vals,
        stripe_index=pack.stripe_index)
    return ops_, self_vals


# ---------------------------------------------------------------------------
# dense/global convolution sketch masses (Graph-Transformer; paper Table 5)
# ---------------------------------------------------------------------------

def out_of_batch_cluster_mass(state: LayerVQState,
                              batch_ids: jax.Array) -> jax.Array:
    """fraC~_out for the all-ones mask of global attention: [n_branches, k].

    For a dense convolution the fixed mask is all-ones, so the sketch
    ``frak_C_out R`` reduces per row to the out-of-batch cluster sizes
    (global histogram minus the batch members' clusters) -- O(k) instead of
    O(n), the paper's key win for global-context GNNs.
    """
    k = state.counts.shape[-1]
    batch_assign = state.assignment.gather(batch_ids) \
        if isinstance(state.assignment, PackedAssignment) \
        else state.assignment[:, batch_ids]               # [nb, b]
    batch_counts = branch_histogram(batch_assign, k)
    return jnp.maximum(state.counts - batch_counts, 0.0)
