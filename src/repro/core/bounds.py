"""Error bounds of Theorem 2 / Corollary 3, as executable checks.

    || X^_B^(l+1) - X_B^(l+1) ||_F
        <= eps^(l) (1 + O(Lip(h))) Lip(sigma) ||C|| ||X|| ||W||     (Thm 2)

    || grad^_X_B - grad_X_B ||_F
        <= eps^(l) (1 + O(Lip(h))) sigma'_max ||C|| ||grad_X^(l+1)|| ||W||
                                                                    (Cor 3)

Used by tests/test_bounds.py (hypothesis sweeps) and by the convergence
benchmark to report the measured eps per layer during training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fro(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))


def vq_relative_error(x: jax.Array, x_recon: jax.Array) -> jax.Array:
    """eps = ||X - R X~||_F / ||X||_F."""
    return fro(x - x_recon) / jnp.maximum(fro(x), 1e-12)


def feature_error_bound(eps: jax.Array, c_fro: jax.Array, x_fro: jax.Array,
                        w_fro: jax.Array, lip_sigma: float = 1.0,
                        lip_h: float = 0.0) -> jax.Array:
    """Theorem 2 right-hand side.  lip_h = 0 for fixed convolutions."""
    return eps * (1.0 + lip_h) * lip_sigma * c_fro * x_fro * w_fro


def gradient_error_bound(eps: jax.Array, c_fro: jax.Array, g_fro: jax.Array,
                         w_fro: jax.Array, sigma_prime_max: float = 1.0,
                         lip_h: float = 0.0) -> jax.Array:
    """Corollary 3 right-hand side."""
    return eps * (1.0 + lip_h) * sigma_prime_max * c_fro * g_fro * w_fro


def lipschitz_leaky_relu(negative_slope: float = 0.2) -> float:
    return max(1.0, negative_slope)


def gat_h_lipschitz(w: jax.Array, a: jax.Array,
                    negative_slope: float = 0.2,
                    score_clip: float = 5.0) -> jax.Array:
    """Upper bound on Lip(h) for the (Lipschitz-regularized) GAT score

        h(x_i, x_j) = exp(clip(LeakyReLU([x_i W || x_j W] . a), +-c))

    Following the paper's App. E Lipschitz regularization (after [47]):
    clipping the pre-exp score to [-c, c] bounds the exp's local Lipschitz
    constant by e^c, and the inner map's by ||W|| ||a||.
    """
    return jnp.exp(score_clip) * lipschitz_leaky_relu(negative_slope) * \
        jnp.linalg.norm(w) * jnp.linalg.norm(a)
