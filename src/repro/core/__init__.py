"""The paper's primary contribution: VQ-GNN.

codebook.py        -- streaming EMA codebooks, product VQ, whitening (Alg. 2)
message_passing.py -- approximated fwd/bwd message passing (Eq. 6/7),
                      custom_vjp backward injection, probe-trick gradients
conv.py            -- generalized graph convolution operands (Table 1/5)
bounds.py          -- Theorem 2 / Corollary 3 as executable checks
"""
from repro.core.codebook import (CodebookConfig, CodebookState, init_codebook,
                                 kmeanspp_init)
from repro.core.conv import (ConvOperands, LayerVQState, MinibatchPack,
                             branch_histogram, fixed_conv_operands,
                             init_layer_vq_state, out_of_batch_cluster_mass,
                             refresh_assignment)
from repro.core.message_passing import (approx_message_passing,
                                        inject_context_grad,
                                        inject_context_grad_materialized,
                                        inject_context_grad_table,
                                        reconstruct)
