"""GNN experiment harness: the paper's training regimes on one API.

  train_full     -- "Full-Graph" oracle rows of Table 4
  train_vq       -- VQ-GNN (Alg. 1), mini-batched, streaming codebooks
  train_sampler  -- NS-SAGE / LABOR / Cluster-GCN / GraphSAINT-RW
                    baselines, on the sampler epoch executor by default
                    (pre-sample an epoch, pack once, one lax.scan --
                    DESIGN.md sec. 12; REPRO_SAMPLER_EXECUTOR=0 falls back
                    to the per-batch host loop)
  train_hybrid   -- VQ/sampling hybrid: sampler-expanded batches on the
                    UNCHANGED VQ epoch executor (exact messages inside the
                    sampled set, VQ context outside)
  train_scenario -- one front for every scale method (the scenario-matrix
                    registry; REPRO_SCALE_METHOD picks the default)
  vq_inference   -- mini-batched codeword inference (the paper's 4x
                    inference speedup claim; supports the inductive setting
                    via feature-half assignment).  Device-resident: one
                    jitted lax.scan per layer over static wrap-padded
                    batches (models.gnn.vq_infer_epoch, DESIGN.md sec. 11);
                    the serving front is launch/serve_gnn.py

Each returns a result dict with metric history, per-epoch loss traces,
wall-times, and the memory/message accounting used by benchmarks
(Table 2/3 analogues).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codebook as cbm
from repro.core.conv import refresh_assignment
from repro.distributed.quantization import dtype_nbits
from repro.kernels import ops as kops
from repro.distributed.data_parallel import ShardedGraphState, \
    vq_train_epoch_dp, vq_train_epoch_sharded
from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                  full_operands, inference_slices,
                                  make_pack, minibatch_stream,
                                  pack_sampler_epoch, pad_bucket,
                                  plan_batch, subgraph_operands)
from repro.graph.batching import PAD_BUCKET_CAP  # noqa: F401  (re-export)
from repro.graph.sampling import (SAMPLER_METHODS, hybrid_epoch_batches,
                                  partition_graph, sample_epoch)
from repro.graph.structure import Graph
from repro.models.gnn import (GNNConfig, _act_for_layer, _layer_out_dims,
                              full_predict, full_train_step, hits_at_k,
                              init_gnn, init_vq_states, node_metric,
                              sampler_train_epoch, vq_infer_epoch,
                              vq_train_epoch, vq_train_step)
from repro.nn.gnn_layers import BACKBONES
from repro.train.optimizer import adam, rmsprop

# canonical implementation moved to repro.graph.batching (the packer is its
# natural home); re-exported here for the existing import sites
_pad_bucket = pad_bucket


def _eval_full(params, g, cfg, x, ops):
    out = full_predict(params, x, ops, cfg)
    labels = jnp.asarray(g.labels)
    return {
        "val": float(node_metric(out[g.val_idx], labels[g.val_idx],
                                 cfg.multilabel)),
        "test": float(node_metric(out[g.test_idx], labels[g.test_idx],
                                  cfg.multilabel)),
    }


def _eval_link(params, g, cfg, x, ops):
    emb = np.asarray(full_predict(params, x, ops, cfg))

    def scores(pairs):
        return (emb[pairs[:, 0]] * emb[pairs[:, 1]]).sum(-1)
    return {
        "val": hits_at_k(scores(g.val_edges), scores(g.val_neg_edges)),
        "test": hits_at_k(scores(g.test_edges), scores(g.test_neg_edges)),
    }


def _evaluate(params, g, cfg, x, ops):
    return (_eval_link if cfg.task == "link" else _eval_full)(
        params, g, cfg, x, ops)


# ---------------------------------------------------------------------------
# memory accounting (paper Table 3: bytes materialized per mini-batch)
# ---------------------------------------------------------------------------

def vq_batch_bytes(b: int, deg: int, f: int, L: int, k: int,
                   f_prod: int = 4, f_grad: Optional[int] = None,
                   precision: Optional[str] = None) -> int:
    """VQ-GNN per-batch device bytes: batch features/acts + packed neighbor
    lists + codebooks + reconstructed context messages.

    The codebook term uses the codebook's ACTUAL ``branch_layout`` (largest
    common divisor of the feature/grad widths capped by both block-size
    budgets) so the Table 3 accounting matches what ``init_codebook``
    allocates: the naive ``f // f_prod`` branch count disagrees whenever
    ``f`` is not divisible by ``f_prod`` or the layout is capped by the
    gradient width (e.g. any transformer-backbone full-width codebook).
    ``f_grad`` defaults to ``f`` (the Z-level gradient codewords of the
    fixed-convolution backbones).

    ``precision`` (a :data:`repro.kernels.ops.PRECISIONS` tier; default
    fp32 accounting) sizes the per-layer codeword tables the kernels
    actually read under that tier -- e.g. int8/fp8 tables at 8 bits plus
    their f32 per-channel scale rows -- via the shared
    :func:`~repro.distributed.quantization.dtype_nbits`, so sub-byte
    operand widths stay exact (bit-accumulated, rounded up once)."""
    f_grad = f if f_grad is None else f_grad
    n_branches, fb, gb = cbm.branch_layout(f, f_grad, f_prod)
    pack = b * deg * 4 * 6                     # ids/mask/pos x2 directions
    acts = L * b * f * 4
    cw_dtype = None if precision is None \
        else kops.precision_codeword_dtype(precision)
    if cw_dtype is None:
        books = L * n_branches * k * (fb + gb) * 4
    else:
        bits = L * n_branches * k * (fb + gb) * dtype_nbits(cw_dtype)
        books = (bits + 7) // 8 \
            + L * n_branches * (fb + gb) * 4   # f32 per-channel scales
    recon = b * deg * f * 4                    # reconstructed neighbors
    return pack + acts + books + recon


def subgraph_batch_bytes(n_sub: int, m_sub: int, f: int, L: int) -> int:
    """Sampler per-batch bytes: subgraph features+acts+edges."""
    return n_sub * f * 4 * L + m_sub * 2 * 8


def messages_per_batch_vq(g: Graph, b: int) -> float:
    """Paper Sec. 4: VQ preserves ALL messages to the batch: b*d of them."""
    return b * float(g.m) / g.n


# ---------------------------------------------------------------------------
# trainers
# ---------------------------------------------------------------------------

def train_full(g: Graph, cfg: GNNConfig, *, epochs: int, lr: float = 1e-2,
               seed: int = 0, eval_every: int = 10) -> dict:
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    opt = adam(lr)
    ost = opt.init(params)
    hist, t0 = [], time.time()
    rng = np.random.default_rng(seed)
    mask_np = np.zeros(g.n, np.float32)
    mask_np[g.train_idx] = 1.0
    mask = jnp.asarray(mask_np)
    for ep in range(epochs):
        if cfg.task == "link":
            e = g.train_edges
            neg = np.stack([rng.integers(0, g.n, len(e)),
                            rng.integers(0, g.n, len(e))], 1)
            params, ost, loss = full_train_step(
                params, ost, x, ops, labels, mask, cfg,
                opt, neg_pairs=jnp.asarray(neg), pos_pairs=jnp.asarray(e))
        else:
            params, ost, loss = full_train_step(
                params, ost, x, ops, labels, mask, cfg, opt)
        if (ep + 1) % eval_every == 0 or ep == epochs - 1:
            m = _evaluate(params, g, cfg, x, ops)
            hist.append({"epoch": ep + 1, "time": time.time() - t0, **m})
    return {"history": hist, "final": hist[-1], "params": params,
            "mem_bytes": g.n * g.f * 4 * cfg.n_layers + g.m * 16}


def train_vq(g: Graph, cfg: GNNConfig, *, epochs: int, batch_size: int,
             lr: float = 3e-3, seed: int = 0, eval_every: int = 10,
             deg_cap: Optional[int] = None, mesh=None,
             shard_graph: bool = False,
             batch_fn: Optional[Callable] = None) -> dict:
    """VQ-GNN training (Alg. 1).

    Node-task training runs on the device-resident epoch executor by
    default: the graph is packed ONCE into an ``EpochPlan`` and each epoch
    is one ``vq_train_epoch`` call (``lax.scan`` over the stacked batches,
    DESIGN.md section 9).  ``REPRO_EPOCH_EXECUTOR=0`` falls back to the
    host-driven per-step loop (debugging; also the link-task path, whose
    per-batch pair mining is host-side).  Both paths consume identical
    wrap-padded batches from the same rng stream, so they match
    numerically on a fixed seed.
    ``mesh`` (optional, a 1-axis "data" ``Mesh``) runs the epoch under
    ``shard_map`` data parallelism (``vq_train_epoch_dp``).
    ``shard_graph`` (requires ``mesh``) additionally row-shards every
    node-indexed table (EpochPlan / features / labels / train mask) over
    the mesh (``vq_train_epoch_sharded``, DESIGN.md section 14), making
    mesh size a graph-capacity knob; value-identical to the replicated
    DP run at the same mesh size.
    ``batch_fn`` (optional, node task) overrides the per-epoch batch
    construction: ``batch_fn(rng) -> (ids [S, b'], slot_mask [S, b'])``
    with distinct ids per row -- the hook the VQ/sampling hybrid uses to
    feed sampler-expanded batches through the unchanged executor
    (``train_hybrid``, DESIGN.md section 12).
    """
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels = jnp.asarray(g.labels)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    vq = init_vq_states(jax.random.PRNGKey(seed + 1), cfg, g.n)
    opt = rmsprop(lr)   # paper App. F: RMSprop for VQ-GNN
    ost = opt.init(params)
    rng = np.random.default_rng(seed)
    train_mask = np.zeros(g.n, np.float32)
    train_mask[g.train_idx] = 1.0

    use_epoch = (cfg.task == "node"
                 and os.environ.get("REPRO_EPOCH_EXECUTOR", "1") != "0")
    if batch_fn is not None and cfg.task != "node":
        raise ValueError("batch_fn= is a node-task batch-construction "
                         "hook (link pair mining is per-batch host work)")
    if batch_fn is not None and mesh is not None:
        # the dp path's per-shard split assumes the fixed epoch_slices
        # batch width; sampler-widened rows would break its divisibility
        # contract silently
        raise ValueError("batch_fn= and mesh= are mutually exclusive")
    if mesh is not None and not use_epoch:
        # never fall back to single-device training silently when the
        # caller explicitly asked for data parallelism
        raise ValueError(
            "mesh= (shard_map data parallelism) requires the epoch "
            "executor: node task and REPRO_EPOCH_EXECUTOR != 0")
    if shard_graph and mesh is None:
        raise ValueError(
            "shard_graph=True row-shards the node tables over a mesh -- "
            "pass mesh= (graph_dp_mesh) as well")
    if mesh is not None:
        # surface epoch_slices' pool clamp here, against the caller's
        # numbers, instead of letting the dp divisibility check report a
        # batch size the caller never passed
        eff_b = min(batch_size, g.n)
        nd = mesh.shape["data"]
        if eff_b % nd != 0:
            raise ValueError(
                f"effective batch size {eff_b} (batch_size={batch_size} "
                f"clamped to the {g.n}-node pool) is not divisible by the "
                f"data mesh size {nd} -- each mesh device trains on "
                f"b/{nd} rows of every batch"
                + (f"; with shard_graph it also owns a contiguous "
                   f"1/{nd} row block of the node tables (padded to a "
                   f"multiple of {nd} rows internally), so only the "
                   f"batch size needs adjusting: pick a multiple of {nd}"
                   if shard_graph else ""))
    plan = build_epoch_plan(g, deg_cap, full_ops=ops) if use_epoch else None
    tm = jnp.asarray(train_mask)
    sstate = None
    if shard_graph:
        # built once per run, like the plan: every node-indexed table is
        # padded + row-placed here and the epoch loop ships only [S, b]
        # id arrays.  ops/x stay host/replicated for _evaluate -- the
        # capacity story is measured on the executor's operands
        # (bench_epoch's graph_state_ratio), eval is offline.
        sstate = ShardedGraphState(mesh, plan, x, ops.degrees,
                                   labels=labels, train_mask=tm)

    hist, t0 = [], time.time()
    vq_errs = None
    for ep in range(epochs):
        if use_epoch:
            ids, smask = (batch_fn(rng) if batch_fn is not None else
                          epoch_slices(rng.permutation(np.arange(g.n)),
                                       batch_size))
            ids_d = jnp.asarray(ids.astype(np.int32))
            smask_d = jnp.asarray(smask)
            if sstate is not None:
                params, vq, ost, _, errs = vq_train_epoch_sharded(
                    sstate, params, vq, ost, ids_d, smask_d, cfg, opt)
            elif mesh is not None:
                params, vq, ost, _, errs = vq_train_epoch_dp(
                    mesh, params, vq, ost, plan, ids_d, smask_d, x,
                    labels, tm, ops.degrees, cfg, opt)
            else:
                params, vq, ost, _, errs = vq_train_epoch(
                    params, vq, ost, plan, ids_d, smask_d, x, labels, tm,
                    ops.degrees, cfg, opt)
            if errs.shape[0]:
                vq_errs = errs[-1]
        elif cfg.task == "node":
            # host-driven per-step loop over the SAME batches the executor
            # would scan (epoch_slices of one permutation draw, or the
            # caller's batch_fn) -- numerically identical to the former
            # minibatch_stream fallback, but batch_fn-aware so hybrid
            # parity can be checked executor-off too
            ids, smask = (batch_fn(rng) if batch_fn is not None else
                          epoch_slices(rng.permutation(np.arange(g.n)),
                                       batch_size))
            for s in range(ids.shape[0]):
                bidx = np.asarray(ids[s])
                pack = make_pack(g, bidx, deg_cap, slot_mask=smask[s])
                lm = train_mask[bidx] * np.asarray(smask[s])
                params, vq, ost, loss, _, vq_errs = vq_train_step(
                    params, vq, ost, pack, x[bidx], labels[bidx],
                    ops.degrees, cfg, opt, loss_mask=jnp.asarray(lm))
        else:
            # link task: per-batch pair mining stays host-side
            for pack in minibatch_stream(g, batch_size, rng,
                                         deg_cap=deg_cap):
                bidx = np.asarray(pack.batch_ids)
                # intra-batch positive pairs + random negatives, mined
                # over the REAL slots only: wrap-padded tail slots are
                # nodes already supervised earlier in the epoch
                # (MinibatchPack.slot_mask contract)
                slots = np.arange(len(bidx))
                if pack.slot_mask is not None:
                    slots = slots[np.asarray(pack.slot_mask) > 0]
                inb = np.full(g.n, -1)
                inb[bidx[slots]] = slots
                e = g.train_edges
                sel = (inb[e[:, 0]] >= 0) & (inb[e[:, 1]] >= 0)
                pos = np.stack([inb[e[sel, 0]], inb[e[sel, 1]]], 1)
                if len(pos) < 2:
                    pos = np.zeros((2, 2), np.int64)
                neg = slots[rng.integers(0, len(slots), pos.shape)]
                params, vq, ost, loss, _, vq_errs = vq_train_step(
                    params, vq, ost, pack, x[bidx], labels[bidx],
                    ops.degrees, cfg, opt, pos_pairs=jnp.asarray(pos),
                    neg_pairs=jnp.asarray(neg))
        if (ep + 1) % eval_every == 0 or ep == epochs - 1:
            m = _evaluate(params, g, cfg, x, ops)
            # whitened-space VQ relative error of the last batch, emitted by
            # the fused update kernel (no extra distance computation); stays
            # unset when the epoch had no batch (empty node pool)
            if vq_errs is not None:
                m["vq_err"] = float(jnp.mean(vq_errs))
            hist.append({"epoch": ep + 1, "time": time.time() - t0, **m})
    deg = deg_cap or g.max_degree()
    # hidden-width layer model: the gradient codewords live at the level
    # the backbone probes (f_out for fixed convs, f_out + heads for GAT),
    # so the codebook term must use the backbone's f_grad -- defaulting it
    # to cfg.hidden re-creates the naive-branch-count accounting bug for
    # every backbone where f_grad != f
    fi0, fo0 = _layer_out_dims(cfg)[0]
    f_grad = BACKBONES[cfg.backbone].f_grad(fi0, fo0, heads=cfg.heads)
    return {"history": hist, "final": hist[-1], "params": params,
            "vq_states": vq,
            "mem_bytes": vq_batch_bytes(
                batch_size, deg, cfg.hidden, cfg.n_layers, cfg.codebook.k,
                f_prod=cfg.layer_codebook_cfg().f_prod, f_grad=f_grad,
                precision=kops.kernel_precision()),
            "messages": messages_per_batch_vq(g, batch_size)}


def train_sampler(g: Graph, cfg: GNNConfig, method: str, *, epochs: int,
                  batch_size: int, lr: float = 1e-3, seed: int = 0,
                  eval_every: int = 10, fanout: int = 5,
                  walk_length: int = 3, n_parts: int = 32,
                  fanouts: Optional[list] = None,
                  parts_per_batch: Optional[int] = None) -> dict:
    """Sampling-baseline training; ``method`` in ``SAMPLER_METHODS``
    (ns-sage / labor / cluster-gcn / graphsaint-rw).

    Every epoch is pre-sampled on host into ONE batch list
    (``sample_epoch``), then by default runs on the device-resident
    sampler epoch executor: ``pack_sampler_epoch`` stacks the induced
    subgraphs into a padded [S, P, ...] plan and
    ``models.gnn.sampler_train_epoch`` scans the exact-subgraph step over
    it -- the same pack-once/``lax.scan`` regime VQ training rides, so the
    paper's Table 2/4 comparison is executor-vs-executor (DESIGN.md
    section 12).  ``REPRO_SAMPLER_EXECUTOR=0`` falls back to the per-batch
    host loop (debugging; also the link-task path, whose pair mining is
    host-side).  Both paths consume the SAME pre-sampled batches for a
    fixed seed, and padding rows are message- and loss-neutral (empty
    neighbor lists, loss weight 0 under the masked-mean loss), so they
    match numerically.

    ``fanouts`` (per-layer list) overrides the uniform ``fanout``;
    ``parts_per_batch`` overrides the Cluster-GCN default
    ``max(1, n_parts // 8)``.
    """
    if method not in SAMPLER_METHODS:
        raise ValueError(f"unknown sampler {method!r}; expected one of "
                         f"{SAMPLER_METHODS}")
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    labels_np = g.labels
    labels = jnp.asarray(labels_np)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    opt = adam(lr)
    ost = opt.init(params)
    rng = np.random.default_rng(seed)
    part = partition_graph(g, n_parts, rng) if method == "cluster-gcn" \
        else None
    fanouts = list(fanouts) if fanouts is not None \
        else [fanout] * cfg.n_layers
    ppb = parts_per_batch if parts_per_batch is not None \
        else max(1, n_parts // 8)
    deg_cap = g.max_degree()
    use_exec = (cfg.task == "node"
                and os.environ.get("REPRO_SAMPLER_EXECUTOR", "1") != "0")
    hist, t0 = [], time.time()
    losses_tr: list = []
    max_sub, max_msg = 0, 0
    max_pairs = 4096

    for ep in range(epochs):
        batches = sample_epoch(g, method, batch_size=batch_size, rng=rng,
                               fanouts=fanouts, walk_length=walk_length,
                               partition=part, parts_per_batch=ppb)
        for src, _, nodes, _, _ in batches:
            max_sub = max(max_sub, len(nodes))
            max_msg = max(max_msg, len(src))
        if use_exec:
            splan = pack_sampler_epoch(batches, deg_cap)
            params, ost, losses = sampler_train_epoch(
                params, ost, splan, x, labels, cfg, opt)
            losses_tr.append(np.asarray(losses))
        else:
            ep_losses = []
            for src, dst, nodes, seed_pos, seed_w in batches:
                n_real = len(nodes)
                n_pad = _pad_bucket(n_real)
                sub_ops = subgraph_operands(src, dst, n_pad, deg_cap)
                xs = jnp.zeros((n_pad, g.f), jnp.float32
                               ).at[:n_real].set(x[nodes])
                lpad = np.zeros((n_pad,) + labels_np.shape[1:],
                                labels_np.dtype)
                lpad[:n_real] = labels_np[nodes]
                ls = jnp.asarray(lpad)
                mask = np.zeros(n_pad, np.float32)
                mask[seed_pos] = seed_w
                if cfg.task == "link":
                    inb = np.full(g.n, -1)
                    inb[nodes] = np.arange(n_real)
                    e = g.train_edges
                    sel = (inb[e[:, 0]] >= 0) & (inb[e[:, 1]] >= 0)
                    pos = np.stack([inb[e[sel, 0]], inb[e[sel, 1]]], 1)
                    if len(pos) < 2:
                        continue
                    pos = pos[:max_pairs]
                    pmask = np.zeros(max_pairs, np.float32)
                    pmask[:len(pos)] = 1.0
                    pos = np.concatenate(
                        [pos,
                         np.zeros((max_pairs - len(pos), 2), np.int64)])
                    neg = rng.integers(0, n_real, pos.shape)
                    params, ost, loss = full_train_step(
                        params, ost, xs, sub_ops, ls, jnp.asarray(mask),
                        cfg, opt, neg_pairs=jnp.asarray(neg),
                        pos_pairs=jnp.asarray(pos),
                        pair_mask=jnp.asarray(pmask))
                else:
                    params, ost, loss = full_train_step(
                        params, ost, xs, sub_ops, ls, jnp.asarray(mask),
                        cfg, opt)
                ep_losses.append(float(loss))
            losses_tr.append(np.asarray(ep_losses, np.float32))
        if (ep + 1) % eval_every == 0 or ep == epochs - 1:
            m = _evaluate(params, g, cfg, x, ops)
            hist.append({"epoch": ep + 1, "time": time.time() - t0, **m})
    return {"history": hist, "final": hist[-1], "params": params,
            "losses": losses_tr,
            "mem_bytes": subgraph_batch_bytes(max_sub, max_msg, cfg.hidden,
                                              cfg.n_layers),
            "messages": max_msg * cfg.n_layers}


def train_hybrid(g: Graph, cfg: GNNConfig, *, epochs: int, batch_size: int,
                 lr: float = 3e-3, seed: int = 0, eval_every: int = 10,
                 deg_cap: Optional[int] = None, fanout: int = 5,
                 fanouts: Optional[list] = None,
                 n_ctx: Optional[int] = None) -> dict:
    """VQ/sampling hybrid (Message Invariance, DESIGN.md section 12):
    LABOR-expanded batches on the UNCHANGED VQ executor.

    Each batch is ``batch_size`` loss-bearing seeds plus up to ``n_ctx``
    of their sampled neighbors as loss-masked context slots
    (``hybrid_epoch_batches``).  No model change is involved: ``vq_apply``
    already routes messages from in-batch neighbors through the exact
    intra-batch SpMM (``nbr_pos >= 0``) and only the remaining
    out-of-batch term through the codeword context kernel, so widening the
    batch with sampled neighbors converts exactly those messages from
    VQ-approximated to exact.  ``n_ctx=0`` degenerates to plain VQ
    training bit-for-bit; ``n_ctx >= n - batch_size`` makes every message
    exact (the full-graph regime at batch granularity).
    """
    if cfg.task != "node":
        raise ValueError("train_hybrid is node-task only (the hybrid is a "
                         "batch-construction strategy for Alg. 1)")
    fo = list(fanouts) if fanouts is not None else [fanout] * cfg.n_layers
    return train_vq(
        g, cfg, epochs=epochs, batch_size=batch_size, lr=lr, seed=seed,
        eval_every=eval_every, deg_cap=deg_cap,
        batch_fn=lambda rng: hybrid_epoch_batches(g, batch_size, fo, rng,
                                                  n_ctx=n_ctx))


SCALE_METHODS = ("full", "vq", "ns_sage", "labor", "cluster", "saint",
                 "hybrid")

_SAMPLER_OF = {"ns_sage": "ns-sage", "labor": "labor",
               "cluster": "cluster-gcn", "saint": "graphsaint-rw"}


def train_scenario(g: Graph, cfg: GNNConfig, method: Optional[str] = None,
                   *, epochs: int, batch_size: int, seed: int = 0,
                   eval_every: int = 10, lr: Optional[float] = None,
                   **knobs) -> dict:
    """One front for every scale method of the scenario matrix.

    ``method`` is one of ``SCALE_METHODS`` (full / vq / ns_sage / labor /
    cluster / saint / hybrid); when None it comes from the
    ``REPRO_SCALE_METHOD`` env knob (default "vq").  Per-method tuning
    knobs are read from the environment when not passed explicitly:
    ``REPRO_SAMPLER_FANOUT``, ``REPRO_WALK_LENGTH``, ``REPRO_N_PARTS``,
    ``REPRO_HYBRID_CTX``.  Extra ``knobs`` are forwarded to the
    underlying trainer.
    """
    method = method or os.environ.get("REPRO_SCALE_METHOD", "vq")
    if method not in SCALE_METHODS:
        raise ValueError(f"unknown scale method {method!r}; expected one "
                         f"of {SCALE_METHODS}")

    def env_int(name, default):
        return int(os.environ.get(name, default))

    if method == "full":
        return train_full(g, cfg, epochs=epochs, lr=lr or 1e-2, seed=seed,
                          eval_every=eval_every, **knobs)
    if method == "vq":
        return train_vq(g, cfg, epochs=epochs, batch_size=batch_size,
                        lr=lr or 3e-3, seed=seed, eval_every=eval_every,
                        **knobs)
    if method == "hybrid":
        knobs.setdefault("fanout", env_int("REPRO_SAMPLER_FANOUT", 5))
        knobs.setdefault("n_ctx", env_int("REPRO_HYBRID_CTX", batch_size))
        return train_hybrid(g, cfg, epochs=epochs, batch_size=batch_size,
                            lr=lr or 3e-3, seed=seed,
                            eval_every=eval_every, **knobs)
    knobs.setdefault("fanout", env_int("REPRO_SAMPLER_FANOUT", 5))
    knobs.setdefault("walk_length", env_int("REPRO_WALK_LENGTH", 3))
    knobs.setdefault("n_parts", env_int("REPRO_N_PARTS", 32))
    return train_sampler(g, cfg, _SAMPLER_OF[method], epochs=epochs,
                         batch_size=batch_size, lr=lr or 1e-3, seed=seed,
                         eval_every=eval_every, **knobs)


# ---------------------------------------------------------------------------
# VQ mini-batched inference (paper Sec. 6 inference speedup + inductive)
# ---------------------------------------------------------------------------

def vq_inference(params, vq_states, g: Graph, cfg: GNNConfig,
                 batch_size: int, *, inductive: bool = False) -> np.ndarray:
    """Layer-synchronous mini-batched inference using codeword context.

    Runs on the device-resident inference executor by default
    (``models.gnn.vq_infer_epoch``, DESIGN.md section 11): the graph is
    packed ONCE into an ``EpochPlan`` (aliasing ``full_operands``' in-edge
    tables), the node set is split into static wrap-padded [S, b] batches
    (``inference_slices``), and each layer's sweep over all S batches is
    one jitted ``lax.scan`` scattering outputs into the device-resident
    [n, f] activation table.  XLA compiles O(n_layers) executables --
    independent of S and of ``g.n % batch_size`` (the pre-executor path
    was fully eager, one dispatch per (batch, layer), with a ragged tail
    batch and a host concatenate per layer).

    ``REPRO_INFER_EXECUTOR=0`` falls back to the eager per-batch loop
    (debugging); both paths traverse identical wrap-padded batches and
    write only real slots, so they agree to float tolerance.

    Inductive extra step (paper Sec. 6): unseen nodes get their codeword
    assignment from the *feature half* of the layer's codebook before the
    layer executes -- inside the jitted layer sweep on the executor path.
    """
    ops = full_operands(g)
    x = jnp.asarray(g.features)
    plan = build_epoch_plan(g, full_ops=ops)
    ids, smask = inference_slices(g.n, batch_size)
    perm = jnp.asarray(ids.astype(np.int32))
    sm = jnp.asarray(smask)

    if os.environ.get("REPRO_INFER_EXECUTOR", "1") != "0":
        acts, _ = vq_infer_epoch(params, vq_states, plan, perm, sm, x,
                                 ops.degrees, cfg, inductive=inductive)
        return np.asarray(acts)
    return eager_inference_loop(params, vq_states, plan, ids, smask, x,
                                ops.degrees, cfg, inductive=inductive)


def eager_inference_loop(params, vq_states, plan, ids: np.ndarray,
                         smask: np.ndarray, x, degrees, cfg: GNNConfig, *,
                         inductive: bool = False) -> np.ndarray:
    """The pre-executor inference regime: zero jit, one eager ``vq_apply``
    dispatch per (batch, layer), a host round-trip per layer -- on the
    same wrap-padded batches with the same real-slot-only writes as the
    executor, so the two paths agree to float tolerance.  The
    ``REPRO_INFER_EXECUTOR=0`` debugging fallback AND the baseline the
    CI-gated ``benchmarks/bench_inference.py`` comparison times (one
    implementation, no drift between what ships and what is measured)."""
    cb_cfg = cfg.layer_codebook_cfg()
    states = list(vq_states)
    bk = BACKBONES[cfg.backbone]
    n = plan.n
    acts = x
    for l, (fi, fo) in enumerate(_layer_out_dims(cfg)):
        st = states[l]
        if inductive:
            assign = cbm.assign_features_only(st.codebook, acts, fi, cb_cfg)
            st = refresh_assignment(st, jnp.arange(n), assign)
            states[l] = st
        out = np.zeros((n, fo), np.float32)
        for s in range(ids.shape[0]):
            pack = plan_batch(plan, jnp.asarray(ids[s].astype(np.int32)))
            y = bk.vq_apply(params[l], acts[ids[s]], None, pack, st,
                            degrees, cb_cfg, _act_for_layer(cfg, l),
                            fi, fo, inject=False)
            real = smask[s] > 0
            out[ids[s][real]] = np.asarray(y)[real]
        acts = jnp.asarray(out)
    return np.asarray(acts)
