"""repro subpackage."""
