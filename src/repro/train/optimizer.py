"""Optimizers as pure pytree transforms (no external deps).

Adam + RMSprop (the paper's App. E: the EMA-smoothed gradient statistics of
VQ-GNN interact badly with Adam's cumulative moments -- RMSprop is the
prescribed optimizer for VQ-GNN; Adam is used for the baselines), plus
gradient clipping, weight decay, and LR schedules.

States are pytrees mirroring the params, so they shard with the params under
pjit (ZeRO-1/3 comes from the sharding rules, not from optimizer code --
see repro/distributed/sharding.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree        # first moment (Adam) / unused zeros (RMSprop)
    nu: PyTree        # second moment


class Optimizer(NamedTuple):
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree)


def _zeros_like_f32(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)
    return sched


def constant_lr(base_lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def adam(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         clip_norm: Optional[float] = None,
         moment_dtype=jnp.float32) -> Optimizer:
    """moment_dtype=bfloat16 halves optimizer HBM (the 405B-class configs
    need it to fit a single pod; see EXPERIMENTS.md memory table)."""
    sched = lr if callable(lr) else constant_lr(lr)

    def _zeros(params):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, moment_dtype), params)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros(params),
                        _zeros(params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = sched(step) * jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            delta = lr_t * m2 / (jnp.sqrt(v2) + eps)
            if weight_decay and p.ndim >= 2:
                delta = delta + sched(step) * weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                    m2.astype(moment_dtype), v2.astype(moment_dtype))

        # three passes (XLA CSEs the shared math); tuple-unzip via tree_map
        # is unsafe because NamedTuple params are themselves tuples
        new_p = jax.tree_util.tree_map(
            lambda g, m, v, pp: upd(g, m, v, pp)[0],
            grads, state.mu, state.nu, params)
        new_m = jax.tree_util.tree_map(
            lambda g, m, v, pp: upd(g, m, v, pp)[1],
            grads, state.mu, state.nu, params)
        new_v = jax.tree_util.tree_map(
            lambda g, m, v, pp: upd(g, m, v, pp)[2],
            grads, state.mu, state.nu, params)
        return new_p, OptState(step, new_m, new_v)

    return Optimizer(init, update)


def rmsprop(lr: float | Callable = 3e-3, alpha: float = 0.99,
            eps: float = 1e-8, weight_decay: float = 0.0,
            clip_norm: Optional[float] = None) -> Optimizer:
    """RMSprop(alpha=0.99), the paper's optimizer for VQ-GNN (App. F)."""
    sched = lr if callable(lr) else constant_lr(lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                        _zeros_like_f32(params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = sched(step)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            v2 = alpha * v + (1 - alpha) * g32 * g32
            delta = lr_t * g32 / (jnp.sqrt(v2) + eps)
            if weight_decay and p.ndim >= 2:
                delta = delta + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), v2

        new_p = jax.tree_util.tree_map(
            lambda g, v, pp: upd(g, v, pp)[0], grads, state.nu, params)
        new_v = jax.tree_util.tree_map(
            lambda g, v, pp: upd(g, v, pp)[1], grads, state.nu, params)
        return new_p, OptState(step, state.mu, new_v)

    return Optimizer(init, update)


OPTIMIZERS = {"adam": adam, "rmsprop": rmsprop}
