"""LM training loop: grad accumulation, checkpoint/restart, failure drills.

The loop is deliberately dumb-robust (1000+-node posture):
  * every step's data is regenerated from (seed, step) -- no loader state;
  * checkpoint every N steps (atomic, versioned; async disk write);
  * on start, resume-from-latest is automatic;
  * a step that raises is retried once after state restore (simulated
    preemption handling -- the launcher-level contract; tested in
    tests/test_fault_tolerance.py by killing and restarting mid-run);
  * straggler mitigation at this layer = synchronous collectives with the
    XLA latency-hiding scheduler + deterministic data (a restarted/replaced
    host recomputes its shard bit-exactly).
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenStreamConfig, batch_shard
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.optimizer import Optimizer, adam, warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def make_train_step(cfg: ArchConfig, opt: Optimizer,
                    accum: int = 1, accum_dtype=jnp.float32) -> Callable:
    """Returns jit-able train_step(state, tokens) -> (state, metrics).

    With accum > 1 the global batch is split into microbatches; gradients
    average across them before one optimizer update (compute/comm overlap:
    only the final microbatch's gradient participates in the cross-replica
    reduction under pjit -- XLA sinks the psum out of the accumulation loop).
    """

    def loss_fn(params, tokens, aux_embeds):
        return lm.train_loss(params, tokens, cfg, aux_embeds)

    def step_fn(state: TrainState, tokens: jax.Array,
                aux_embeds: jax.Array | None = None):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                state.params, tokens, aux_embeds)
        else:
            # scan over a [accum, mb, S] leading axis (NEVER dynamic-slice
            # the sharded batch dim -- that forces replication of the
            # microbatch through GSPMD).  The reshape must put the ORIGINAL
            # batch-contiguous dim on the mb axis: reshape(accum, mb, ...)
            # lands the dp sharding on the accum axis and every scanned
            # microbatch gets replicated (+33 GiB/chip of logits on the
            # 405B cell -- Perf iteration 5); reshape(mb, accum).swap keeps
            # each microbatch 1/dp-sharded (strided microbatch composition,
    # mathematically identical gradient average).
            mb = tokens.shape[0] // accum
            tok_r = tokens.reshape(mb, accum, *tokens.shape[1:]
                                   ).swapaxes(0, 1)
            aux_r = None if aux_embeds is None else \
                aux_embeds.reshape(mb, accum, *aux_embeds.shape[1:]
                                   ).swapaxes(0, 1)

            def micro(c, xs):
                tok = xs[0]
                aux = xs[1] if aux_r is not None else None
                l, g = jax.value_and_grad(loss_fn)(state.params, tok, aux)
                acc_l, acc_g = c
                return (acc_l + l, jax.tree_util.tree_map(
                    lambda a, b: (a + b.astype(a.dtype)), acc_g, g)), None
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            xs = (tok_r,) if aux_r is None else (tok_r, aux_r)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zero_g), xs)
            loss = loss / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        new_params, new_opt = opt.update(grads, state.opt, state.params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)))
        return TrainState(new_params, new_opt, state.step + 1), \
            {"loss": loss, "grad_norm": gnorm}

    return step_fn


def train(cfg: ArchConfig, *, steps: int, batch: int, seq_len: int,
          lr: float = 3e-4, accum: int = 1, seed: int = 0,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          log_every: int = 10,
          inject_failure_at: Optional[int] = None) -> dict:
    """Single-host training driver (the pjit pod driver lives in
    repro/launch/train.py and shares make_train_step)."""
    key = jax.random.PRNGKey(seed)
    params = lm.init_lm(key, cfg)
    opt = adam(warmup_cosine(lr, max(10, steps // 20), steps), clip_norm=1.0)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))

    start_step = 0
    if ckpt_dir is not None:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            state, manifest = ckpt.restore(ckpt_dir, state)
            start_step = manifest["step"]

    ds = TokenStreamConfig(vocab=cfg.vocab, seq_len=seq_len + 1,
                           global_batch=batch, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, opt, accum))

    history = []
    t0 = time.time()
    s = start_step
    while s < steps:
        tokens = jnp.asarray(batch_shard(ds, s, 0, 1))
        try:
            if inject_failure_at is not None and s == inject_failure_at:
                inject_failure_at = None
                raise RuntimeError("injected node failure (drill)")
            state, metrics = step_fn(state, tokens)
        except RuntimeError:
            # preemption drill: restore-from-latest and retry this step
            if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
                state, manifest = ckpt.restore(ckpt_dir, state)
                s = manifest["step"]
                continue
            raise
        s += 1
        if s % log_every == 0 or s == steps:
            history.append({"step": s, "loss": float(metrics["loss"]),
                            "time": time.time() - t0})
        if ckpt_dir is not None and s % ckpt_every == 0:
            ckpt.save(ckpt_dir, s,
                      TrainState(state.params, state.opt,
                                 jnp.asarray(s, jnp.int32)),
                      {"data_seed": seed}, async_write=False)
    return {"history": history, "state": state}
