"""Checkpointing: atomic, versioned, restart/elastic-safe.

Checkpoints store *logical* (unsharded) arrays + a manifest (step, config
fingerprint, data cursor).  Restore re-shards against whatever mesh the
resumed job has -- a run can come back on a different device count (elastic
scaling / failed-node shrink) because shardings are reapplied by *name*
from repro.distributed.sharding, never persisted as device layouts.

Layout:  <dir>/step_<N>/  arrays.npz + manifest.json, written to a tmp dir
and atomically renamed; `latest` is resolved by scanning step dirs, so a
crash mid-write never corrupts the restore path (fault tolerance contract:
kill -9 at any moment loses at most the steps since the last checkpoint).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        a = np.asarray(leaf)
        if a.dtype.name == "bfloat16":      # npz cannot store bf16
            a = a.astype(np.float32)
        out[key] = a
    return out


def _unflatten(tree_like: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        a = arrays[key]
        assert a.shape == tuple(leaf.shape), (key, a.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(a).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, state: Any,
         manifest_extra: Optional[dict] = None, *,
         keep: int = 3, async_write: bool = False) -> threading.Thread | None:
    """Write checkpoint `step`.  Set async_write=True to overlap the host
    serialization with the next training steps (device->host copy happens
    synchronously; disk IO is backgrounded)."""
    host_state = jax.tree_util.tree_map(np.asarray, state)  # sync D2H

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(host_state))
        manifest = {"step": step, **(manifest_extra or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        _gc(ckpt_dir, keep)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_like: Any,
            step: Optional[int] = None) -> tuple[Any, dict]:
    """Restore into the structure of `state_like` (shapes must match;
    dtypes/shardings are re-applied by the caller's pjit entry)."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    return _unflatten(state_like, arrays), manifest
