"""Pallas TPU kernel: ELLPACK SpMM (padded-neighbor message passing).

The intra-mini-batch term ``C_in X_B`` and the cluster-bucketing of
out-of-batch neighbors are segment sums over padded neighbor lists.  GPU
implementations use CSR SpMM with atomics; the TPU-native formulation is a
regular ELLPACK layout: every row has exactly D (padded) neighbor slots, so
the access pattern is a rank-1 gather + weighted accumulate with no dynamic
shapes and no atomics (DESIGN.md section 3, hardware adaptation).

Grid is over row tiles; the dense source matrix X is resident (VMEM for the
validation sizes; an HBM/ANY memory-space variant with double-buffered DMA is
the production path for n_src * f beyond VMEM -- see the block comment in
ops.py).  The inner loop runs over the D neighbor slots, each step doing a
[bb]-wide vector gather from X and a fused multiply-accumulate on the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_ell_kernel(idx_ref, val_ref, x_ref, o_ref, *, deg: int):
    bb, f = o_ref.shape

    def body(d, acc):
        ids = idx_ref[:, d]                                # [bb] int32
        vals = val_ref[:, d].astype(jnp.float32)           # [bb]
        rows = x_ref[ids, :].astype(jnp.float32)           # gather [bb, f]
        return acc + vals[:, None] * rows

    acc = jax.lax.fori_loop(0, deg, body, jnp.zeros((bb, f), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def _spmm_ell_q_kernel(idx_ref, val_ref, x_ref, sc_ref, o_ref, *, deg: int):
    """int8 source rows (VMEM-resident in storage dtype): f32 accumulate,
    then ONE per-channel dequant row multiply -- the scale is row
    (codeword) independent, so it commutes with the over-neighbors sum."""
    bb, f = o_ref.shape

    def body(d, acc):
        ids = idx_ref[:, d]
        vals = val_ref[:, d].astype(jnp.float32)
        rows = x_ref[ids, :].astype(jnp.float32)
        return acc + vals[:, None] * rows

    acc = jax.lax.fori_loop(0, deg, body, jnp.zeros((bb, f), jnp.float32))
    o_ref[...] = (acc * sc_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def spmm_ell_pallas(nbr_idx: jax.Array, nbr_val: jax.Array, x: jax.Array, *,
                    x_scale: Optional[jax.Array] = None,
                    bb: int = 128, interpret: bool = True) -> jax.Array:
    """nbr_idx/[b, D] int32, nbr_val/[b, D], x/[n_src, f] -> [b, f] f32.

    Padding slots must carry val == 0 (their index may point anywhere valid).
    ``x_scale`` ([1, f] f32) marks ``x`` as int8 rows with per-channel
    dequant scales, applied as a single epilogue multiply after the f32
    accumulate (DESIGN.md section 13) -- the source matrix stays int8 in
    VMEM, quartering its share of the resident envelope.
    """
    b, deg = nbr_idx.shape
    n_src, f = x.shape
    bb = min(bb, max(8, b))
    bp = (b + bb - 1) // bb * bb

    idx_p = jnp.zeros((bp, deg), jnp.int32).at[:b].set(nbr_idx.astype(jnp.int32))
    val_p = jnp.zeros((bp, deg), jnp.float32).at[:b].set(
        nbr_val.astype(jnp.float32))

    in_specs = [
        pl.BlockSpec((bb, deg), lambda i: (i, 0)),
        pl.BlockSpec((bb, deg), lambda i: (i, 0)),
        pl.BlockSpec((n_src, f), lambda i: (0, 0)),
    ]
    operands = [idx_p, val_p, x]
    if x_scale is None:
        kern = _spmm_ell_kernel
    else:
        kern = _spmm_ell_q_kernel
        in_specs.append(pl.BlockSpec((1, f), lambda i: (0, 0)))
        operands.append(x_scale.astype(jnp.float32).reshape(1, f))

    out = pl.pallas_call(
        functools.partial(kern, deg=deg),
        grid=(bp // bb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, f), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:b]
