"""Pallas TPU kernel: fused VQ assign + cluster statistics (VQ-Update).

The per-layer, per-batch hot loop of Algorithm 2 (streaming EMA codebook
update) needs, for every product-VQ branch: the nearest-codeword assignment
of b whitened rows, the per-codeword member counts, the per-codeword member
sums, and the per-row quantization error (for dead-codeword revival and the
relative-error monitor).  Computing these separately costs a second distance
pass plus a materialized [b, k] one-hot -- the same "gigantic intermediate"
failure mode the HBM SpMM work removed from message passing.

This kernel produces all four in a single (b/bb, k/kb) grid pass:

  * distances reduce to  |c|^2 - 2 x.c^T  (the |x|^2 term is constant per
    row) so the dominant work is an MXU matmul of the [bb, f] x-tile against
    the [kb, f] codeword tile -- identical to vq_assign.py;
  * the running (min, argmin) pair is carried across the sequential k-tiles
    in the revisited per-row output blocks (qerr, idx);
  * at the LAST k-tile of each row tile the argmin is final, so the cluster
    statistics are accumulated right there: a [bb, kp] selection mask
    (computed on the fly from the final indices, never written to HBM)
    reduces to counts via a VPU column sum and to sums via one MXU matmul
    mask^T . x.  The counts/sums outputs use a CONSTANT index map, so Pallas
    keeps them in VMEM as revisited accumulator blocks across the whole grid
    and writes them back exactly once;
  * |x|^2 is added to the carried min at the last k-tile, turning it into
    the true squared quantization error (clamped at 0 against cancellation).

VMEM envelope per step: bb*fp + kb*fp (operand tiles) + bb*kb (distance
tile) + bb*kp (selection mask, last tile only) + kp*fp + kp (stats
accumulators) floats.  Defaults bb=256, kb=512 with the paper-scale k=256,
f_blk=8 (fp=128) keep this well under 2 MiB.  Callers pad: extra k rows get
value 1e15 so they never win the argmin (their counts/sums stay zero); extra
b rows are masked out of the statistics in-kernel and sliced off by the
wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.vq_assign import pad_assign_operands

# Narrow emit dtypes and the largest k each can index: uint8 (the int8/fp8
# tiers' table dtype) and uint4 (the nibble-packed +a4 tiers; SIGNED int4
# tops out at 7 and would wrap ids 8..15, so it is deliberately absent).
# int32 is always valid and carries no limit.
_EMIT_K_LIMITS = {"uint8": 256, "uint4": 16}


def _vq_update_kernel(x_ref, c_ref, idx_ref, qerr_ref, cnt_ref, sum_ref, *,
                      bb: int, kb: int, b: int):
    i = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    x = x_ref[...].astype(jnp.float32)                    # [bb, fp]
    c = c_ref[...].astype(jnp.float32)                    # [kb, fp]
    # MXU: scores[b, k] = x . c^T
    scores = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    cn2 = jnp.sum(c * c, axis=1)                          # [kb]
    dist = cn2[None, :] - 2.0 * scores                    # [bb, kb]

    tile_min = jnp.min(dist, axis=1, keepdims=True)       # [bb, 1]
    tile_arg = (jnp.argmin(dist, axis=1)[:, None] + ki * kb).astype(jnp.int32)

    @pl.when(ki == 0)
    def _init_rows():
        qerr_ref[...] = tile_min
        idx_ref[...] = tile_arg.astype(idx_ref.dtype)

    @pl.when(ki > 0)
    def _combine():
        prev = qerr_ref[...]
        take = tile_min < prev
        qerr_ref[...] = jnp.where(take, tile_min, prev)
        idx_ref[...] = jnp.where(
            take, tile_arg,
            idx_ref[...].astype(jnp.int32)).astype(idx_ref.dtype)

    @pl.when(jnp.logical_and(i == 0, ki == 0))
    def _init_stats():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)

    @pl.when(ki == nk - 1)
    def _accumulate():
        kp = cnt_ref.shape[0]
        final = idx_ref[...].astype(jnp.int32)            # [bb, 1] post-combine
        rows = i * bb + jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)
        valid = rows < b                                  # padded rows: no stats
        cols = jax.lax.broadcasted_iota(jnp.int32, (bb, kp), 1)
        sel = jnp.where(jnp.logical_and(final == cols, valid), 1.0, 0.0)
        cnt_ref[...] += jnp.sum(sel, axis=0)[:, None]
        sum_ref[...] += jax.lax.dot_general(
            sel, x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        xn2 = jnp.sum(x * x, axis=1, keepdims=True)
        qerr_ref[...] = jnp.maximum(qerr_ref[...] + xn2, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("bb", "kb", "interpret", "emit_dtype"))
def vq_assign_update_pallas(
        x: jax.Array, codewords: jax.Array, *,
        bb: int = 256, kb: int = 512, interpret: bool = False,
        emit_dtype=jnp.int32,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused assign + stats.  x: [b, f], codewords: [k, f].

    Returns (assignment [b] ``emit_dtype``, qerr [b] f32, counts [k] f32,
    sums [k, f] f32) where qerr[i] = ||x_i - c_{assignment[i]}||^2 and
    counts/sums are the per-codeword member histogram and member sum --
    exactly the statistics Algorithm 2's EMA update consumes, with no
    one-hot intermediate and no second distance pass.

    ``emit_dtype=jnp.uint8`` (valid for k <= 256) EMITS the assignment in
    the int8 path's storage dtype: with a single k-tile (kp <= 256) the
    kernel's idx output block is uint8 natively -- padded codeword columns
    carry 1e15 distance and never win the argmin, so every emitted index
    is < k.  Multi-k-tile grids carry int32 intermediates in the revisited
    block (tile offsets exceed the narrow range) and narrow in the wrapper.
    ``emit_dtype=jnp.uint4`` (the +a4 tiers, valid for k <= 16) shares the
    native uint8 output block -- Mosaic has no sub-byte output windows --
    and narrows to uint4 in the wrapper; callers nibble-pack from there
    (``distributed.quantization.pack_nibbles``).

    Handles all padding internally via the shared
    :func:`~repro.kernels.vq_assign.pad_assign_operands` (padded codewords
    sit far away -> never selected, zero stats; padded b rows are masked
    out of the stats in-kernel).
    """
    b, f = x.shape
    k = codewords.shape[0]
    emit = jnp.dtype(emit_dtype)
    k_limit = _EMIT_K_LIMITS.get(emit.name)
    if emit != jnp.int32 and k_limit is None:
        raise ValueError(
            f"emit_dtype={emit.name!r} is not a supported assignment "
            f"storage dtype; want jnp.int32 or one of "
            f"{sorted(_EMIT_K_LIMITS)}")
    if emit != jnp.int32 and k > k_limit:
        raise ValueError(
            f"emit_dtype={emit.name!r} supports k <= {k_limit}, got "
            f"k={k}; use emit_dtype=jnp.int32 (always valid)"
            + (" or jnp.uint8 (k <= 256)" if emit == jnp.uint4 else ""))
    xp, cp, bb, kb, bp, kp, fp = pad_assign_operands(x, codewords, bb, kb)
    # sub-byte dtypes ride the uint8 output block; byte-wide emit dtypes go
    # out natively when the grid has a single k-tile
    block_emit = jnp.uint8 if emit == jnp.uint4 else emit
    idx_dtype = block_emit if (emit == jnp.int32 or
                               (kp <= kb and kp <= 256)) else jnp.int32

    grid = (bp // bb, kp // kb)
    idx, qerr, counts, sums = pl.pallas_call(
        functools.partial(_vq_update_kernel, bb=bb, kb=kb, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((kb, fp), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            # constant index maps: revisited VMEM accumulators (module doc)
            pl.BlockSpec((kp, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((kp, fp), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), idx_dtype),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((kp, fp), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp)
    return (idx[:b, 0].astype(emit), qerr[:b, 0],
            counts[:k, 0], sums[:k, :f])
