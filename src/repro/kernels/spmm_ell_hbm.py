"""Pallas TPU kernel: ELLPACK SpMM with an HBM-resident source matrix.

Production variant of ``spmm_ell`` for ``n_src * f`` beyond the VMEM
envelope (DESIGN.md section 3, resident vs HBM): the dense source matrix
``x`` stays in ``memory_space=ANY`` (HBM on a real TPU) and the kernel
DMAs *stripes* of ``stripe`` contiguous source rows into a double-buffered
VMEM scratch, so the gather+FMA over stripe ``j`` overlaps the async copy
of stripe ``j+1``.

Which stripes a row tile needs is data-dependent, so it is scalar-prefetched
(``PrefetchScalarGridSpec``): a per-tile list of touched stripe ids plus a
per-tile count, both known before the kernel body runs.  The index is built
either at batch-pack time on the host (``repro.graph.batching
.make_stripe_index`` -- the cheap path, it rides along with the pack) or
in-jit from the neighbor ids as a fallback.

Per-tile work is ``count[t] * deg`` masked gathers from the [stripe, f]
scratch instead of the resident kernel's ``deg`` gathers from the full
[n_src, f] block; the win is that VMEM holds ``2 * stripe * f`` source
elements instead of ``n_src * f``.  Graphs with index locality (sorted node
ids, clustered batches) touch few stripes per tile and approach the
resident kernel's arithmetic intensity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@jax.tree_util.register_pytree_node_class
class StripeIndex:
    """Per-row-tile neighbor-stripe index for the HBM SpMM kernel.

    ``ids[t, :counts[t]]`` are the (ascending) stripe ids touched by row
    tile ``t``; entries beyond the count are arbitrary valid stripe ids.
    ``bb`` / ``stripe`` / ``n_src`` are static (pytree aux data) so a
    precomputed index pins the kernel's tiling and jit validates the
    (tile count, source-row count) match at trace time.  The *contents*
    are trusted: an index built from different neighbor ids than the call's
    silently drops messages -- build it from the same pack.
    """

    def __init__(self, ids: jax.Array, counts: jax.Array, *,
                 bb: int, stripe: int, n_src: int):
        self.ids = ids          # [num_tiles, max_stripes] int32
        self.counts = counts    # [num_tiles] int32
        self.bb = int(bb)
        self.stripe = int(stripe)
        self.n_src = int(n_src)

    def tree_flatten(self):
        return (self.ids, self.counts), (self.bb, self.stripe, self.n_src)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ids, counts = children
        bb, stripe, n_src = aux
        return cls(ids, counts, bb=bb, stripe=stripe, n_src=n_src)

    def __repr__(self):
        return (f"StripeIndex(tiles={self.ids.shape[0]}, "
                f"max_stripes={self.ids.shape[1]}, bb={self.bb}, "
                f"stripe={self.stripe}, n_src={self.n_src})")


def _rup(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def clamp_tiles(b: int, n_src: int, bb: int, stripe: int) -> tuple[int, int]:
    """Shared tile clamping so host-built indices match the kernel grid."""
    return min(bb, max(8, b)), min(stripe, _rup(n_src, 8))


def stripe_index_jnp(nbr_idx: jax.Array, nbr_val: jax.Array, n_src: int, *,
                     bb: int, stripe: int) -> StripeIndex:
    """In-jit stripe-index construction (fallback when the pack did not
    precompute one).  Slots with ``val == 0`` (padding) touch no stripe.

    The ids width is the static bound min(n_stripes, bb * deg) -- a tile of
    bb rows with deg slots cannot touch more stripes than it has slots.
    For very large graphs prefer the host-built pack-time index
    (``repro.graph.batching.make_stripe_index``): it can be capped to the
    dataset's measured locality, keeping the scalar-prefetch operand small.
    """
    b, deg = nbr_idx.shape
    bb, stripe = clamp_tiles(b, n_src, bb, stripe)
    bp = _rup(b, bb)
    nt = bp // bb
    n_stripes = _rup(n_src, stripe) // stripe

    idx_p = jnp.zeros((bp, deg), jnp.int32).at[:b].set(
        nbr_idx.astype(jnp.int32))
    val_p = jnp.zeros((bp, deg), jnp.float32).at[:b].set(
        nbr_val.astype(jnp.float32))
    sid = (idx_p // stripe).reshape(nt, bb * deg)
    # park padding slots in an overflow column that is sliced away
    sid = jnp.where((val_p != 0.0).reshape(nt, bb * deg), sid, n_stripes)
    touched = jnp.zeros((nt, n_stripes + 1), bool).at[
        jnp.arange(nt)[:, None], sid].set(True)[:, :n_stripes]
    counts = jnp.sum(touched, axis=1).astype(jnp.int32)
    # stable argsort of ~touched: touched stripes first, ascending id
    ids = jnp.argsort(~touched, axis=1, stable=True).astype(jnp.int32)
    ids = ids[:, :min(n_stripes, bb * deg)]
    return StripeIndex(ids, counts, bb=bb, stripe=stripe, n_src=n_src)


def _spmm_ell_hbm_kernel(sid_ref, cnt_ref, idx_ref, val_ref, x_ref, *refs,
                         deg: int, stripe: int):
    # refs is (o_ref, scratch, sems) or, on the int8 path,
    # (sc_ref, o_ref, scratch, sems): the DMA'd stripes keep x's storage
    # dtype (int8 rows move as int8 bytes -- the DMA win), the gather-FMA
    # accumulates the raw int8 values in f32, and the per-channel dequant
    # is a single epilogue multiply -- the scale commutes with the sum
    # over neighbors, mirroring the resident ``_spmm_ell_q_kernel``.
    if len(refs) == 4:
        sc_ref, o_ref, scratch, sems = refs
    else:
        o_ref, scratch, sems = refs
        sc_ref = None
    t = pl.program_id(0)
    bb, f = o_ref.shape
    nst = cnt_ref[t]

    def get_dma(slot, j):
        s = sid_ref[t, j]
        return pltpu.make_async_copy(
            x_ref.at[pl.ds(s * stripe, stripe), :],
            scratch.at[slot],
            sems.at[slot])

    @pl.when(nst > 0)
    def _warmup():
        get_dma(0, 0).start()

    def stripe_body(j, acc):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nst)
        def _prefetch_next():
            get_dma(jax.lax.rem(j + 1, 2), j + 1).start()

        get_dma(slot, j).wait()
        base = sid_ref[t, j] * stripe
        xs = scratch[slot].astype(jnp.float32)               # [stripe, f]

        def slot_body(d, acc2):
            loc = idx_ref[:, d] - base                       # [bb]
            in_stripe = (loc >= 0) & (loc < stripe)
            rows = xs[jnp.where(in_stripe, loc, 0), :]       # [bb, f]
            w = jnp.where(in_stripe, val_ref[:, d].astype(jnp.float32), 0.0)
            return acc2 + w[:, None] * rows

        return jax.lax.fori_loop(0, deg, slot_body, acc)

    acc = jax.lax.fori_loop(0, nst, stripe_body,
                            jnp.zeros((bb, f), jnp.float32))
    if sc_ref is not None:
        acc = acc * sc_ref[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "stripe", "interpret"))
def spmm_ell_hbm_pallas(nbr_idx: jax.Array, nbr_val: jax.Array,
                        x: jax.Array,
                        stripe_index: StripeIndex | None = None, *,
                        x_scale: jax.Array | None = None,
                        bb: int = 128, stripe: int = 512,
                        interpret: bool = True) -> jax.Array:
    """nbr_idx/[b, D] int32, nbr_val/[b, D], x/[n_src, f] -> [b, f] f32.

    Same contract as ``spmm_ell_pallas`` (padding slots carry val == 0),
    but ``x`` lives in ``memory_space=ANY`` and only ``2 * stripe`` of its
    rows are ever resident in VMEM.  ``stripe_index`` (from
    ``repro.graph.batching.make_stripe_index``) skips the in-jit index
    build; it must have been built for the same ``(b, n_src)`` tiling.
    As with the resident kernel, callers keep ``f`` lane-aligned (mult. of
    128) for the compiled TPU path; interpret mode takes any ``f``.

    ``x_scale`` ([1, f] or [f] per-channel dequant scales) makes the
    kernel consume an int8 ``x`` natively: stripes DMA as int8 (4x fewer
    HBM bytes -- the bandwidth this variant is bound by), the accumulate
    stays f32, and the scales apply once in the epilogue.
    """
    b, deg = nbr_idx.shape
    n_src, f = x.shape
    if stripe_index is not None:
        bb, stripe = stripe_index.bb, stripe_index.stripe
    else:
        bb, stripe = clamp_tiles(b, n_src, bb, stripe)
        stripe_index = stripe_index_jnp(nbr_idx, nbr_val, n_src,
                                        bb=bb, stripe=stripe)
    bp = _rup(b, bb)
    nt = bp // bb
    np_ = _rup(n_src, stripe)
    if stripe_index.ids.shape[0] != nt:
        raise ValueError(
            f"stripe_index built for {stripe_index.ids.shape[0]} tiles, "
            f"kernel grid has {nt} (b={b}, bb={bb})")
    if stripe_index.n_src != n_src:
        raise ValueError(
            f"stripe_index built for n_src={stripe_index.n_src}, "
            f"x has {n_src} rows")

    idx_p = jnp.zeros((bp, deg), jnp.int32).at[:b].set(
        nbr_idx.astype(jnp.int32))
    val_p = jnp.zeros((bp, deg), jnp.float32).at[:b].set(
        nbr_val.astype(jnp.float32))
    x_p = x if np_ == n_src else \
        jnp.zeros((np_, f), x.dtype).at[:n_src].set(x)

    in_specs = [
        pl.BlockSpec((bb, deg), lambda i, *_: (i, 0)),
        pl.BlockSpec((bb, deg), lambda i, *_: (i, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    operands = [idx_p, val_p, x_p]
    if x_scale is not None:
        in_specs.append(pl.BlockSpec((1, f), lambda i, *_: (0, 0)))
        operands.append(x_scale.reshape(1, f))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, f), lambda i, *_: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, stripe, f), x.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_spmm_ell_hbm_kernel, deg=deg, stripe=stripe),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bp, f), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(stripe_index.ids, stripe_index.counts, *operands)
    return out[:b]
