"""Pallas TPU kernel: VQ-Attention decode step (codebook + exact window).

The paper's approximated message passing (Eq. 6) applied to a decoder LM's
attention: at decode step t the query attends to
  * k codeword (key, value) pairs summarizing all tokens older than the
    window, weighted by cluster mass (the ``C~_out X~`` term), and
  * w exact recent (key, value) pairs (the ``C_in X_B`` term),
in one fused streaming softmax.  Per-step cost O(k + w) instead of O(t) --
this is what makes the ``long_500k`` cells sub-quadratic for dense archs.

Grid is (batch * kv_heads,); each step handles the g = h_q / h_kv query heads
of one GQA group.  Codebook tiles [kcb, d], window tiles [w, d], both padded
to lane width; cluster mass enters as a log-additive bias (row-normalization
handled exactly, paper App. E).  VMEM envelope: (g + kcb + 2w) * d floats --
tiny (decode is memory-bound; this kernel's purpose is to shrink the KV
stream from t*d to (k + w)*d bytes per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _vq_attn_kernel(q_ref, cbk_ref, cbv_ref, mass_ref, wk_ref, wv_ref,
                    wmask_ref, o_ref, *, sm_scale: float):
    g, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * sm_scale

    cbk = cbk_ref[...].astype(jnp.float32)                 # [kcb, d]
    mass = mass_ref[...][:, 0]                             # [kcb]
    s_cb = jax.lax.dot_general(
        q, cbk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [g, kcb]
    s_cb = s_cb + jnp.log(jnp.maximum(mass, 1e-9))[None, :]
    s_cb = jnp.where(mass[None, :] > 0, s_cb, _NEG_INF)

    wk = wk_ref[...].astype(jnp.float32)                   # [w, d]
    wmask = wmask_ref[...][:, 0]                           # [w]
    s_w = jax.lax.dot_general(
        q, wk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [g, w]
    s_w = jnp.where(wmask[None, :] > 0, s_w, _NEG_INF)

    m = jnp.maximum(jnp.max(s_cb, axis=1), jnp.max(s_w, axis=1))  # [g]
    p_cb = jnp.exp(s_cb - m[:, None])
    p_w = jnp.exp(s_w - m[:, None])
    denom = jnp.sum(p_cb, axis=1) + jnp.sum(p_w, axis=1)
    acc = jax.lax.dot(p_cb, cbv_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32) \
        + jax.lax.dot(p_w, wv_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    o_ref[...] = (acc / jnp.maximum(denom, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def vq_attention_decode_pallas(q: jax.Array, cb_k: jax.Array, cb_v: jax.Array,
                               mass: jax.Array, win_k: jax.Array,
                               win_v: jax.Array, win_mask: jax.Array, *,
                               interpret: bool = True) -> jax.Array:
    """Batched VQ-Attention decode.

    q:        [n, g, d]   n = batch*kv_heads GQA groups, g q-heads per group
    cb_k/v:   [n, k, d]
    mass:     [n, k]
    win_k/v:  [n, w, d]
    win_mask: [n, w]
    -> [n, g, d]
    """
    n, g, d = q.shape
    kcb = cb_k.shape[1]
    w = win_k.shape[1]
    sm_scale = 1.0 / (d ** 0.5)

    out = pl.pallas_call(
        functools.partial(_vq_attn_kernel, sm_scale=sm_scale),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((None, g, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, kcb, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, kcb, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, kcb, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, w, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, w, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, w, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, g, d), q.dtype),
        interpret=interpret,
    )(q, cb_k, cb_v, mass[..., None], win_k, win_v, win_mask[..., None])
    return out
