"""Pallas TPU kernel: fused multi-branch VQ-context ELLPACK SpMM.

The out-of-batch ("context") term of Eq. 6 reconstructs each out-of-batch
neighbor from its product-VQ codewords and accumulates the weighted
messages:

    out[i] = sum_d vals[i, d] * concat_beta X~^beta[R^beta[ids[i, d]]]

The paper's scaling argument (Sec. 3.3) is that this term only ever touches
a [k, f_blk] codeword table per branch -- O(k * f) state, independent of
graph size.  The pre-fusion implementation still paid per-branch costs the
math does not require: a materialized ``[n_branches, b, D]`` gathered-
assignment tensor plus one SpMM kernel launch per branch plus a concat.
This kernel performs the whole computation in ONE ``(b/bb,)`` grid pass:

  * all branches' codeword tables live VMEM-resident as a single flat
    ``[n_branches * k, f_blk]`` matrix (k * f is tiny by construction --
    the point of VQ);
  * the assignment table rides along as ``[n, n_branches]`` (transposed so
    a neighbor id selects one contiguous row holding all its branch ids);
  * the inner loop over the D neighbor slots fuses assignment gather ->
    flat codeword gather -> weighted accumulate, emitting the
    branch-concatenated ``[bb, n_branches * f_blk]`` rows directly -- no
    per-branch intermediate ever exists.

The same kernel is the streaming Eq. 7 backward (DESIGN.md section 10):
called with the reverse-edge operands and the *gradient* codewords it
computes ``sum_d rev_vals[:, d] * G~[c(rev_ids[:, d])]``, and the optional
``w_t`` epilogue fuses the trailing ``@ W^T`` (one resident MXU matmul per
row tile), so ``inject_context_grad`` needs no ``[b, Dr, f_grad]``
residual -- the codebook itself is the residual.

Low-precision operands (DESIGN.md sections 13/15): the codeword tables may
be int8 or float8_e4m3fn with a per-branch/per-channel f32 scale
(``cw_scale [nb, 1, f_blk]``,
``distributed.quantization.quantize_codewords``) and the assignment table
may be uint8 (k <= 256) or nibble-packed (``PackedAssignment``, k <= 16,
two ids per byte) -- all stay in their storage dtype inside VMEM (4x /
8x-vs-int32 envelope win on the assignment table, the dispatch-budget
lever).  Quantized codeword rows gather in storage dtype and widen
in-register (``astype(f32)``) -- on non-fp8 backends that upcast IS the
fallback path, so interpret-mode CPU CI exercises the same kernel.  The
accumulate runs in f32, and the dequant multiply is a single epilogue row
``acc * scale_flat [1, nb * f_blk]``: scales are k-independent, so the
multiply commutes with the over-neighbors sum and with the fused ``w_t``
MXU epilogue ordering (scale first, then ``@ W^T``).  Packed assignments
unpack in-kernel with a shift/mask on the gathered byte -- no unpacked
table ever materializes.

Padding contract (shared with spmm_ell): slots with ``vals == 0`` may
point at any valid node id; rows padded to the ``bb`` tile carry zero vals.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.distributed.quantization import PackedAssignment


def _accumulate(ids_ref, val_ref, assign_ref, cw_ref, *, deg: int, nb: int,
                k: int, bb: int, packed: bool = False) -> jax.Array:
    """Shared fused gather+FMA over the D neighbor slots -> [bb, nb*f_blk]."""
    f_blk = cw_ref.shape[1]
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1) * k  # [1, nb]

    def body(d, acc):
        ids = ids_ref[:, d]                                # [bb] int32
        vals = val_ref[:, d].astype(jnp.float32)           # [bb]
        if packed:
            # nibble-packed table [ceil(n/2), nb]: gather the byte holding
            # the id, then shift/mask out this node's nibble in-register
            byte = assign_ref[ids >> 1, :].astype(jnp.int32)   # [bb, nb]
            aid = ((byte >> ((ids & 1) * 4)[:, None]) & 0xF) + offs
        else:
            # assignment rides in its storage dtype (int32 or uint8); the
            # id arithmetic widens in-register only
            aid = assign_ref[ids, :].astype(jnp.int32) + offs  # [bb, nb]
        rows = cw_ref[aid.reshape(bb * nb), :]             # [bb*nb, f_blk]
        # row-major flatten: row (i*nb + beta) is branch beta of batch row i,
        # so this reshape IS the branch concat -- no moveaxis, no copy
        rows = rows.reshape(bb, nb * f_blk).astype(jnp.float32)
        return acc + vals[:, None] * rows

    return jax.lax.fori_loop(
        0, deg, body, jnp.zeros((bb, nb * f_blk), jnp.float32))


def _context_ell_kernel(ids_ref, val_ref, assign_ref, cw_ref, o_ref, *,
                        deg: int, nb: int, k: int, packed: bool):
    bb = o_ref.shape[0]
    o_ref[...] = _accumulate(ids_ref, val_ref, assign_ref, cw_ref, deg=deg,
                             nb=nb, k=k, bb=bb,
                             packed=packed).astype(o_ref.dtype)


def _context_ell_wt_kernel(ids_ref, val_ref, assign_ref, cw_ref, wt_ref,
                           o_ref, *, deg: int, nb: int, k: int,
                           packed: bool):
    bb = o_ref.shape[0]
    acc = _accumulate(ids_ref, val_ref, assign_ref, cw_ref,
                      deg=deg, nb=nb, k=k, bb=bb, packed=packed)
    # fused epilogue: the Eq. 7 ``@ W^T`` as one resident MXU matmul
    o_ref[...] = jax.lax.dot_general(
        acc, wt_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _context_ell_q_kernel(ids_ref, val_ref, assign_ref, cw_ref, sc_ref,
                          o_ref, *, deg: int, nb: int, k: int,
                          packed: bool):
    """int8/fp8 codewords: f32 accumulate + one dequant-row epilogue."""
    bb = o_ref.shape[0]
    acc = _accumulate(ids_ref, val_ref, assign_ref, cw_ref,
                      deg=deg, nb=nb, k=k, bb=bb, packed=packed)
    o_ref[...] = (acc * sc_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _context_ell_q_wt_kernel(ids_ref, val_ref, assign_ref, cw_ref, sc_ref,
                             wt_ref, o_ref, *, deg: int, nb: int, k: int,
                             packed: bool):
    bb = o_ref.shape[0]
    acc = _accumulate(ids_ref, val_ref, assign_ref, cw_ref,
                      deg=deg, nb=nb, k=k, bb=bb, packed=packed)
    acc = acc * sc_ref[...].astype(jnp.float32)   # dequant BEFORE the W^T mix
    o_ref[...] = jax.lax.dot_general(
        acc, wt_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def context_ell_pallas(out_ids: jax.Array, out_vals: jax.Array,
                       assignment: jax.Array, codewords: jax.Array, *,
                       cw_scale: Optional[jax.Array] = None,
                       w_t: Optional[jax.Array] = None,
                       bb: int = 128, interpret: bool = True) -> jax.Array:
    """Fused multi-branch codeword SpMM (one kernel for any n_branches).

    out_ids:    [b, D] int32  global node ids (padding: val == 0)
    out_vals:   [b, D]        edge values
    assignment: [n_branches, n] int32 or uint8 (k <= 256) codeword ids, or
                a nibble-packed ``PackedAssignment`` (k <= 16); the table
                stays in its storage dtype inside VMEM
    codewords:  [n_branches, k, f_blk]  feature OR gradient codewords
                (f32, or int8/fp8 when ``cw_scale`` is given)
    cw_scale:   optional [n_branches, 1, f_blk] f32 per-branch/per-channel
                dequant scales of quantized codewords (module docstring)
    w_t:        optional [n_branches * f_blk, f_out] fused epilogue matmul

    Returns [b, n_branches * f_blk] (branch-concatenated), or [b, f_out]
    with the ``w_t`` epilogue.
    """
    b, deg = out_ids.shape
    nb, k, f_blk = codewords.shape
    f_cat = nb * f_blk
    if deg == 0:
        f_out = f_cat if w_t is None else w_t.shape[1]
        return jnp.zeros((b, f_out), jnp.float32)

    bb = min(bb, max(8, b))
    bp = (b + bb - 1) // bb * bb
    ids_p = jnp.zeros((bp, deg), jnp.int32).at[:b].set(
        out_ids.astype(jnp.int32))
    val_p = jnp.zeros((bp, deg), jnp.float32).at[:b].set(
        out_vals.astype(jnp.float32))
    packed = isinstance(assignment, PackedAssignment)
    if packed:
        # packed bytes transpose to [ceil(n/2), nb]: one gathered byte row
        # holds a node pair's ids for every branch
        assign_t = assignment.packed.T
    else:
        # uint8 assignment stays uint8 (the 4x VMEM-envelope win);
        # everything else rides as int32
        assign_t = assignment.T if assignment.dtype == jnp.uint8 \
            else assignment.astype(jnp.int32).T        # [n, nb]
    cw_flat = codewords.reshape(nb * k, f_blk)

    n = assign_t.shape[0]
    common = dict(deg=deg, nb=nb, k=k, packed=packed)
    in_specs = [
        pl.BlockSpec((bb, deg), lambda i: (i, 0)),
        pl.BlockSpec((bb, deg), lambda i: (i, 0)),
        pl.BlockSpec((n, nb), lambda i: (0, 0)),
        pl.BlockSpec((nb * k, f_blk), lambda i: (0, 0)),
    ]
    operands = [ids_p, val_p, assign_t, cw_flat]
    if cw_scale is not None:
        # [nb, 1, f_blk] -> the flat [1, nb * f_blk] epilogue row matching
        # the accumulator's branch-major column layout
        in_specs.append(pl.BlockSpec((1, f_cat), lambda i: (0, 0)))
        operands.append(cw_scale.astype(jnp.float32).reshape(1, f_cat))
        kern, kern_wt = _context_ell_q_kernel, _context_ell_q_wt_kernel
    else:
        kern, kern_wt = _context_ell_kernel, _context_ell_wt_kernel
    if w_t is None:
        out = pl.pallas_call(
            functools.partial(kern, **common),
            grid=(bp // bb,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bb, f_cat), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, f_cat), jnp.float32),
            interpret=interpret,
        )(*operands)
    else:
        f_out = w_t.shape[1]
        out = pl.pallas_call(
            functools.partial(kern_wt, **common),
            grid=(bp // bb,),
            in_specs=in_specs + [
                pl.BlockSpec((f_cat, f_out), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((bb, f_out), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, f_out), jnp.float32),
            interpret=interpret,
        )(*operands, w_t.astype(jnp.float32))
    return out[:b]
