"""Pallas TPU kernels for the perf-critical compute of VQ-GNN.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with jit'd dispatching wrappers in ops.py and pure-jnp oracles in
ref.py.  Kernels: vq_assign (fused distance+argmin), vq_update (fused
assign + cluster counts/sums + per-row quantization error -- the one-pass
streaming codebook update, no one-hot intermediate), spmm_ell (ELLPACK
message passing, VMEM-resident source), spmm_ell_hbm (ELLPACK message
passing, HBM-resident source with double-buffered stripe DMA),
flash_attention (training attention), vq_attention (codebook + window
decode attention).
"""
