"""Pallas TPU kernels for the perf-critical compute of VQ-GNN.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), with jit'd dispatching wrappers in ops.py and pure-jnp oracles in
ref.py.  Kernels: vq_assign (fused distance+argmin), vq_update (fused
assign + cluster counts/sums + per-row quantization error -- the one-pass
streaming codebook update, no one-hot intermediate), spmm_ell (ELLPACK
message passing, VMEM-resident source), spmm_ell_hbm (ELLPACK message
passing, HBM-resident source with double-buffered stripe DMA),
context_ell (one-pass multi-branch VQ-context SpMM -- Eq. 6 context
forward and streaming Eq. 7 backward, codebook VMEM-resident),
flash_attention (training attention), vq_attention (codebook + window
decode attention).
"""
