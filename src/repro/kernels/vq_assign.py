"""Pallas TPU kernel: fused pairwise-distance + running argmin (VQ assign).

The hot inner loop of VQ-GNN: every mini-batch, every layer, every product-VQ
branch assigns b vectors to their nearest of k codewords.  On GPU this is a
cdist + argmin (two kernels + atomic-free reduction); the TPU formulation is
a single fused kernel:

  * distance reduces to  |c|^2 - 2 x.c^T  (the |x|^2 term is constant per
    row) so the dominant work is an MXU matmul of the [bb, f] x-tile against
    the [kb, f] codeword tile;
  * the argmin over k is carried across k-tiles as a running (min, argmin)
    pair held in the (revisited) output block -- grid is (b/bb, k/kb) with
    the k axis 'arbitrary' (sequential) so revisiting is legal.

VMEM envelope per step: bb*f + kb*f + bb*kb floats.  Defaults bb=256, kb=512,
f padded to a multiple of 128 (lane width) keep this < 1 MiB for f = 128.
Callers pad: extra k rows get value 1e15 so they never win the argmin; extra
b rows are sliced off by the wrapper in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pad_assign_operands(x: jax.Array, codewords: jax.Array,
                        bb: int, kb: int):
    """Clamp tile sizes and pad operands for the shared assign-grid layout
    (used by vq_assign and the fused vq_update kernel -- one place owns the
    padding invariants): b -> bb multiple, k -> kb multiple, f -> lane-width
    multiple of 128 with zeros (leaves distances unchanged).  Padded
    codeword rows get value 1e15 so they never win the argmin.

    Returns (xp, cp, bb, kb, bp, kp, fp) with bb/kb clamped to the actual
    problem size (floor 8, the f32 sublane width).
    """
    b, f = x.shape
    k = codewords.shape[0]
    bb = min(bb, max(8, b))
    kb = min(kb, max(8, k))

    def rup(v, m):
        return (v + m - 1) // m * m

    bp, kp, fp = rup(b, bb), rup(k, kb), rup(f, 128)
    xp = jnp.zeros((bp, fp), x.dtype).at[:b, :f].set(x)
    cp = jnp.full((kp, fp), 1e15, jnp.float32).at[:k, :f].set(
        codewords.astype(jnp.float32)).at[:k, f:].set(0.0)
    return xp, cp, bb, kb, bp, kp, fp


def _vq_assign_kernel(x_ref, c_ref, val_ref, idx_ref, *, kb: int):
    ki = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                    # [bb, f]
    c = c_ref[...].astype(jnp.float32)                    # [kb, f]
    # MXU: scores[b, k] = x . c^T
    scores = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    cn2 = jnp.sum(c * c, axis=1)                          # [kb]
    dist = cn2[None, :] - 2.0 * scores                    # [bb, kb]

    tile_min = jnp.min(dist, axis=1, keepdims=True)       # [bb, 1]
    tile_arg = (jnp.argmin(dist, axis=1)[:, None] + ki * kb).astype(jnp.int32)

    @pl.when(ki == 0)
    def _init():
        val_ref[...] = tile_min
        idx_ref[...] = tile_arg

    @pl.when(ki > 0)
    def _combine():
        prev = val_ref[...]
        take = tile_min < prev
        val_ref[...] = jnp.where(take, tile_min, prev)
        idx_ref[...] = jnp.where(take, tile_arg, idx_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("bb", "kb", "interpret", "want_min"))
def vq_assign_pallas(x: jax.Array, codewords: jax.Array, *,
                     bb: int = 256, kb: int = 512,
                     interpret: bool = False, want_min: bool = False):
    """x: [b, f], codewords: [k, f] -> assignment [b] int32.

    With ``want_min=True`` also returns the squared distance to the chosen
    codeword, [b] f32 (the carried running min plus the per-row |x|^2 the
    kernel factors out) -- callers that need the quantization error get it
    without a second distance pass.

    ``interpret`` defaults to False so a bare call on TPU compiles; the
    interpret-mode test/CI sweeps pass it explicitly.

    Handles all padding internally (b -> bb multiple, k -> kb multiple,
    f -> multiple of 128 with zeros, which leaves distances unchanged).
    """
    b, _ = x.shape
    xp, cp, bb, kb, bp, kp, fp = pad_assign_operands(x, codewords, bb, kb)

    grid = (bp // bb, kp // kb)
    val, idx = pl.pallas_call(
        functools.partial(_vq_assign_kernel, kb=kb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, fp), lambda i, j: (i, 0)),
            pl.BlockSpec((kb, fp), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, cp)
    if not want_min:
        return idx[:b, 0]
    xn2 = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    return idx[:b, 0], jnp.maximum(val[:b, 0] + xn2, 0.0)
