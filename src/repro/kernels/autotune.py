"""Measure-and-cache autotuner for kernel block sizes and dispatch variants.

The dispatch layer (``ops.py``) picks block sizes (``bb``, ``kb``) and the
resident/HBM + fused/loop variants from fixed defaults and a VMEM-budget
heuristic behind ``REPRO_*_VMEM_BUDGET_MB`` env vars.  Those numbers encode
one machine's tradeoffs; this module replaces them with measurements when
the user opts in (``REPRO_AUTOTUNE=1``):

  * each (kind, shape bucket, dtype, backend) key is timed ONCE -- candidate
    configs race on a clamped synthetic problem (rows <= 512, few reps) so a
    cold cache costs milliseconds, not a benchmark run;
  * winners persist to a JSON cache (``REPRO_AUTOTUNE_CACHE``, default
    ``~/.cache/repro/autotune.json``) keyed on next-power-of-two shape
    buckets so one measurement covers a whole size regime and jit caches
    stay warm across nearby shapes;
  * the env vars stay authoritative: ops.py only consults the autotuner
    when no forced variant and no explicit budget override is in effect
    (precedence: programmatic override > env var > autotuner > heuristic).

Measurements call the kernel entry points directly (``spmm_ell_pallas``,
``context_ell_pallas``, ...) rather than going through ops.py dispatch --
the dispatcher consults this module, so routing timings back through it
would recurse.  On CPU the kernels run in interpret mode, making the
timings a proxy for relative launch/gather overheads rather than real MXU
throughput; production TPU deployments get true measurements for free from
the same code path.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import hostenv

_ROW_CLAMP = 512      # measured problems never exceed this many batch rows
_SRC_CLAMP = 8192     # ... nor this many gather-source rows
_REPS = 2             # best-of reps after one warmup (jit compile) call

# in-memory cache: key -> config dict; None until the file is first read
_cache: Optional[dict[str, Any]] = None


def enabled() -> bool:
    """Autotuning is opt-in: measurements only run under REPRO_AUTOTUNE=1.

    Read through the hostenv snapshot -- the tuners are consulted by the
    ops.py dispatchers inside jit traces (env-read-once contract)."""
    return hostenv.env_knob("REPRO_AUTOTUNE", "0") == "1"


def cache_path() -> str:
    return hostenv.env_knob(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def shape_bucket(v: int) -> int:
    """Next power of two (0 -> 0): the shape-key granularity."""
    v = int(v)
    return 0 if v <= 0 else 1 << (v - 1).bit_length()


def cache_key(kind: str, shape: tuple[int, ...], dtype) -> str:
    buckets = "x".join(str(shape_bucket(s)) for s in shape)
    return f"{kind}|{buckets}|{jnp.dtype(dtype).name}|{jax.default_backend()}"


def _load() -> dict[str, Any]:
    global _cache
    if _cache is None:
        try:
            with open(cache_path()) as fh:
                _cache = dict(json.load(fh))
        except (OSError, ValueError):
            _cache = {}
    return _cache


def lookup(key: str) -> Optional[dict[str, Any]]:
    hit = _load().get(key)
    return dict(hit) if isinstance(hit, dict) else None


def record(key: str, cfg: dict[str, Any]) -> None:
    cache = _load()
    cache[key] = dict(cfg)
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as fh:
            json.dump(cache, fh, indent=1, sort_keys=True)
    except OSError:
        pass  # cache stays in-memory for this process


def clear(*, memory_only: bool = False) -> None:
    """Drop the in-memory cache (tests); optionally keep the file."""
    global _cache
    _cache = None
    if not memory_only:
        try:
            os.remove(cache_path())
        except OSError:
            pass


def _time(fn, *args) -> float:
    out = fn(*args)                       # warmup: compile + first run
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# per-kernel tuners (ops.py consumers)
# ---------------------------------------------------------------------------

def tuned_spmm(n_src: int, f: int, itemsize: int = 4, dtype=None
               ) -> Optional[dict[str, Any]]:
    """{'variant': 'resident'|'hbm', 'bb': int, 'stripe': int} for a
    [n_src, f] source matrix of ``itemsize``-byte elements, or None when
    autotuning is off.  ``stripe`` (the HBM variant's DMA granule) is
    measured alongside bb under the same cache entry; the resident
    variant ignores it, and a caller's precomputed ``StripeIndex`` still
    pins both (tuner config never overrides an explicit tiling).

    ``dtype`` is the storage dtype of the source rows and keys the cache
    entry -- int8 and float8_e4m3fn share itemsize 1 but are distinct
    operand regimes, so they must not share a winner (ISSUE 9).  When
    omitted it falls back to the itemsize-derived legacy key."""
    if not enabled():
        return None
    if dtype is None:
        dtype = jnp.int8 if itemsize == 1 else jnp.float32
    key = cache_key("spmm", (n_src, f, itemsize), dtype)
    hit = lookup(key)
    if hit is not None:
        return hit

    from repro.kernels.spmm_ell import spmm_ell_pallas
    from repro.kernels.spmm_ell_hbm import spmm_ell_hbm_pallas
    b, deg = min(_ROW_CLAMP, 256), 16
    ns = min(int(n_src), _SRC_CLAMP)
    fm = min(int(f), 128)
    key_rng = jax.random.PRNGKey(0)
    ki, kv, kx = jax.random.split(key_rng, 3)
    idx = jax.random.randint(ki, (b, deg), 0, ns, jnp.int32)
    val = jax.random.uniform(kv, (b, deg), jnp.float32)
    x = jax.random.normal(kx, (ns, fm), jnp.float32)
    interp = _interpret()

    timings: dict[tuple[str, int, int], float] = {}
    for bb in (64, 128, 256):
        timings[("resident", bb, 512)] = _time(
            lambda i, v, s, _bb=bb: spmm_ell_pallas(
                i, v, s, bb=_bb, interpret=interp), idx, val, x)
    for stripe in (256, 512, 1024):
        timings[("hbm", 128, stripe)] = _time(
            lambda i, v, s, _st=stripe: spmm_ell_hbm_pallas(
                i, v, s, None, stripe=_st, interpret=interp), idx, val, x)
    (variant, bb, stripe), _ = min(timings.items(), key=lambda kv_: kv_[1])
    cfg = {"variant": variant, "bb": int(bb), "stripe": int(stripe)}
    record(key, cfg)
    return cfg


def tuned_context(n_nodes: int, n_branches: int, itemsize: float = 4,
                  dtype=None) -> Optional[dict[str, Any]]:
    """{'variant': 'fused'|'loop', 'bb': int} for an
    [n_branches, n_nodes] assignment table, or None when autotuning is off.

    ``dtype`` keys the cache entry by the table's storage dtype; pass
    ``jnp.uint4`` for nibble-packed tables (``PackedAssignment``, itemsize
    0.5) -- the measurement then races the packed fused kernel against the
    loop fallback on the unpacked uint8 table, matching what dispatch
    would actually run in each regime."""
    if not enabled():
        return None
    if dtype is None:
        dtype = (jnp.uint4 if itemsize == 0.5
                 else jnp.uint8 if itemsize == 1 else jnp.int32)
    dtype = jnp.dtype(dtype)
    packed = dtype == jnp.dtype(jnp.uint4)
    key = cache_key("context", (n_nodes, n_branches), dtype)
    hit = lookup(key)
    if hit is not None:
        return hit

    from repro.distributed.quantization import PackedAssignment
    from repro.kernels.context_ell import context_ell_pallas
    from repro.kernels.spmm_ell import spmm_ell_pallas
    b, deg, f_blk = min(_ROW_CLAMP, 256), 16, 8
    k = 16 if packed else 64
    n = min(int(n_nodes), _SRC_CLAMP)
    nb = int(n_branches)
    rng = jax.random.PRNGKey(0)
    ki, kv, ka, kc = jax.random.split(rng, 4)
    ids = jax.random.randint(ki, (b, deg), 0, n, jnp.int32)
    val = jax.random.uniform(kv, (b, deg), jnp.float32)
    assign = jax.random.randint(ka, (nb, n), 0, k, jnp.int32)
    if packed:
        fused_a: Any = PackedAssignment.pack(assign)
        loop_a = assign.astype(jnp.uint8)
    else:
        fused_a = loop_a = assign.astype(dtype)
    cw = jax.random.normal(kc, (nb, k, f_blk), jnp.float32)
    interp = _interpret()

    def loop(i, v, a, c):
        # the per-branch fallback, built on the kernel directly (module doc)
        bi = a.astype(jnp.int32)[:, i]
        return jnp.concatenate(
            [spmm_ell_pallas(bi[j], v, c[j], interpret=interp)
             for j in range(c.shape[0])], axis=-1)

    timings: dict[tuple[str, int], float] = {}
    for bb in (64, 128, 256):
        timings[("fused", bb)] = _time(
            lambda i, v, a, c, _bb=bb: context_ell_pallas(
                i, v, a, c, bb=_bb, interpret=interp), ids, val, fused_a, cw)
    timings[("loop", 128)] = _time(loop, ids, val, loop_a, cw)
    (variant, bb), _ = min(timings.items(), key=lambda kv_: kv_[1])
    cfg = {"variant": variant, "bb": int(bb)}
    record(key, cfg)
    return cfg


def tuned_vq_update(b: int, k: int, f: int) -> Optional[dict[str, Any]]:
    """{'bb': int, 'kb': int} block sizes for the fused assign+stats kernel,
    or None when autotuning is off."""
    if not enabled():
        return None
    key = cache_key("vq_update", (b, k, f), jnp.float32)
    hit = lookup(key)
    if hit is not None:
        return hit

    from repro.kernels.vq_update import vq_assign_update_pallas
    bm = min(int(b), _ROW_CLAMP)
    km, fm = min(int(k), 512), min(int(f), 128)
    rng = jax.random.PRNGKey(0)
    kx, kc = jax.random.split(rng)
    x = jax.random.normal(kx, (bm, fm), jnp.float32)
    cw = jax.random.normal(kc, (km, fm), jnp.float32)
    interp = _interpret()

    timings: dict[tuple[int, int], float] = {}
    for bb in (128, 256):
        for kb in (256, 512):
            timings[(bb, kb)] = _time(
                lambda xx, cc, _bb=bb, _kb=kb: vq_assign_update_pallas(
                    xx, cc, bb=_bb, kb=_kb, interpret=interp), x, cw)
    (bb, kb), _ = min(timings.items(), key=lambda kv_: kv_[1])
    cfg = {"bb": int(bb), "kb": int(kb)}
    record(key, cfg)
    return cfg
