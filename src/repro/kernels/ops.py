"""Dispatching wrappers around the Pallas kernels.

On a TPU backend the Pallas kernels run compiled; on the CPU host the system
executes the pure-jnp oracles from ref.py (numerically identical -- the
kernels are validated against them in interpret mode by tests/test_kernels_*).
Set REPRO_FORCE_PALLAS=1 to route every call through the interpret-mode
kernels instead (used by the kernel test sweeps and CI).

Production notes (TPU):
  * ``spmm_ell``: for n_src * f beyond VMEM the source matrix lives in
    memory_space=ANY and rows are DMA'd in double-buffered stripes keyed by a
    scalar-prefetched tile->rows index (PrefetchScalarGridSpec); the resident
    variant here is the validated core loop.
  * ``flash_attention``: 32k+ sequences use a (bh, nq, nk) grid with carried
    scratch instead of the resident-KV loop.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


def vq_assign(x: jax.Array, codewords: jax.Array) -> jax.Array:
    if _use_pallas():
        return vq_assign_pallas(
            x, codewords, interpret=jax.default_backend() != "tpu")
    return ref.vq_assign(x, codewords)


def spmm_ell(nbr_idx: jax.Array, nbr_val: jax.Array, x: jax.Array) -> jax.Array:
    if _use_pallas():
        return spmm_ell_pallas(
            nbr_idx, nbr_val, x, interpret=jax.default_backend() != "tpu")
    return ref.spmm_ell(nbr_idx, nbr_val, x)


def flash_attention(q, k, v, *, causal: bool = True):
    if _use_pallas() and q.shape[2] % 128 == 0 and q.shape[-1] % 8 == 0:
        return flash_attention_pallas(
            q, k, v, causal=causal, interpret=jax.default_backend() != "tpu")
    return ref.flash_attention(q, k, v, causal=causal)


def vq_attention_decode(q, cb_k, cb_v, mass, win_k, win_v, win_mask):
    if _use_pallas():
        return vq_attention_decode_pallas(
            q, cb_k, cb_v, mass, win_k, win_v, win_mask,
            interpret=jax.default_backend() != "tpu")
    return jax.vmap(
        lambda qq, ck, cv, m, wk, wv, wm: ref.vq_attention_decode(
            qq, ck, cv, m, wk, wv, wm)
    )(q, cb_k, cb_v, mass, win_k, win_v, win_mask)
