"""Dispatching wrappers around the Pallas kernels.

On a TPU backend the Pallas kernels run compiled; on the CPU host the system
executes the pure-jnp oracles from ref.py (numerically identical -- the
kernels are validated against them in interpret mode by tests/test_kernels.py,
tests/test_context_ell.py, tests/test_spmm_hbm.py, tests/test_vq_update.py
and the precision sweeps in tests/test_int8.py / tests/test_fp8_int4.py).
Set REPRO_FORCE_PALLAS=1 to route every call through the interpret-mode
kernels instead (used by the kernel test sweeps and CI).

Production notes (TPU):
  * ``spmm_ell`` has two variants (DESIGN.md section 3, resident vs HBM):
    the resident kernel holds the full source matrix in VMEM; for
    n_src * f beyond the VMEM envelope the HBM variant keeps it in
    memory_space=ANY and DMAs double-buffered row stripes keyed by a
    scalar-prefetched tile->stripes index (PrefetchScalarGridSpec).  The
    size-based dispatch below picks the variant; override with
    REPRO_SPMM_VARIANT / REPRO_SPMM_VMEM_BUDGET_MB or
    ``configure_spmm_dispatch``.
  * ``context_ell`` (DESIGN.md section 10) fuses the multi-branch
    VQ-context term -- Eq. 6 forward and the streaming Eq. 7 backward --
    into ONE kernel dispatch regardless of n_branches; dispatch falls back
    to the per-branch loop when the [n_branches, n] assignment table
    exceeds the VMEM envelope (REPRO_CONTEXT_VARIANT /
    REPRO_CONTEXT_VMEM_BUDGET_MB or ``configure_context_dispatch``).
  * operand precision tiers (DESIGN.md sections 13/15): codewords may be
    int8 or float8_e4m3fn ``QTensor`` snapshots and assignment tables
    uint8 (k <= 256) or nibble-packed ``PackedAssignment`` (k <= 16);
    every wrapper dispatches on the operand's type/dtype, never on the
    environment, so the tier choice happens once at state construction.
  * ``flash_attention``: 32k+ sequences use a (bh, nq, nk) grid with carried
    scratch instead of the resident-KV loop (the HBM SpMM kernel's
    double-buffering idiom is the template; still TODO).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import hostenv
from repro.kernels import autotune, ref
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_update import vq_assign_update_pallas
from repro.kernels.context_ell import context_ell_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.spmm_ell_hbm import StripeIndex, spmm_ell_hbm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas
from repro.distributed.quantization import PackedAssignment, QTensor


def _use_pallas() -> bool:
    # env knobs resolve through the hostenv snapshot: this runs inside jit
    # traces, where a live os.environ read would desynchronize from jax's
    # executable cache (the env-read-once contract, DESIGN.md section 16)
    if hostenv.env_knob("REPRO_FORCE_PALLAS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# kernel operand precision tiers (fp32 / int8 / fp8 / +a4 packing)
# ---------------------------------------------------------------------------

# The kernels themselves dispatch on OPERAND TYPE (QTensor codewords, uint8
# or PackedAssignment tables) so jitted callers never read the environment
# inside a trace; this knob only steers the host-side state-construction
# sites (core/conv.py init, models/gnn.py serving, launch/serve_gnn.py) that
# decide which storage dtype to build.  The tier ladder (DESIGN.md section
# 15): 'fp32' (dense), 'int8' (int8 codewords + uint8 assignments, k <= 256),
# 'fp8' (float8_e4m3fn codewords, same uint8 assignments), and the '+a4'
# suffix tiers that additionally nibble-pack the assignment table for
# k <= 16 product branches (two ids per byte, 8x vs int32).
PRECISIONS = ("fp32", "int8", "fp8", "int8+a4", "fp8+a4")
_PRECISIONS = PRECISIONS  # backwards-compat alias
_precision_override: list[str] = []


def _check_precision(p: str, source: str) -> str:
    if p not in PRECISIONS:
        raise ValueError(
            f"{source}={p!r}: unknown kernel precision tier; valid tiers "
            f"are {', '.join(PRECISIONS)}")
    return p


def configure_kernel_precision(precision: Optional[str] = None, *,
                               reset: bool = False) -> None:
    """Programmatic override of REPRO_KERNEL_PRECISION.

    Valid tiers are ``PRECISIONS``; anything else raises (listing them) so
    an unrecognized string can never silently behave like fp32.
    """
    if reset:
        _precision_override.clear()
    if precision is not None:
        _check_precision(precision, "kernel precision")
        _precision_override[:] = [precision]


def kernel_precision() -> str:
    """Active operand-storage precision tier ('fp32' default)."""
    if _precision_override:
        return _precision_override[0]
    return _check_precision(
        hostenv.env_knob("REPRO_KERNEL_PRECISION", "fp32"),
        "REPRO_KERNEL_PRECISION")


def precision_codeword_dtype(precision: Optional[str] = None):
    """Codeword storage dtype of a tier: None (dense f32), int8, or fp8."""
    p = _check_precision(precision if precision is not None
                         else kernel_precision(), "kernel precision")
    if p == "fp32":
        return None
    return jnp.float8_e4m3fn if p.startswith("fp8") else jnp.int8


def precision_packs_assignment(precision: Optional[str] = None) -> bool:
    """True for the '+a4' tiers that nibble-pack assignment tables."""
    p = _check_precision(precision if precision is not None
                         else kernel_precision(), "kernel precision")
    return p.endswith("+a4")


def vq_assign(x: jax.Array, codewords: jax.Array) -> jax.Array:
    if _use_pallas():
        return vq_assign_pallas(
            x, codewords, interpret=jax.default_backend() != "tpu")
    return ref.vq_assign(x, codewords)


def vq_assign_update(x: jax.Array, codewords: jax.Array, *,
                     emit_dtype=jnp.int32
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused assign + cluster stats + per-row quantization error.

    The one-pass primitive of the streaming codebook update (Alg. 2):
    returns (assignment [b], qerr [b], counts [k], sums [k, f]) from a
    single distance computation.  TPU: kernels/vq_update.py (revisited
    VMEM accumulator blocks, no one-hot); CPU: scatter-add oracle.
    ``emit_dtype=jnp.uint8`` (k <= 256) emits the assignment in the
    int8/fp8 tiers' storage dtype straight from the kernel;
    ``emit_dtype=jnp.uint4`` (k <= 16) narrows for the +a4 tiers' nibble
    packing (the kernel block stays uint8 -- no sub-byte output windows).
    """
    if _use_pallas():
        bb, kb = 256, 512
        tuned = autotune.tuned_vq_update(x.shape[0], codewords.shape[0],
                                         x.shape[1])
        if tuned is not None:
            bb, kb = tuned["bb"], tuned["kb"]
        return vq_assign_update_pallas(
            x, codewords, bb=bb, kb=kb, emit_dtype=emit_dtype,
            interpret=jax.default_backend() != "tpu")
    idx, qerr, counts, sums = ref.vq_assign_update(x, codewords)
    return idx.astype(emit_dtype), qerr, counts, sums


# ---------------------------------------------------------------------------
# spmm_ell resident-vs-HBM dispatch
# ---------------------------------------------------------------------------

# Per-core VMEM is ~16 MiB; the resident kernel also holds idx/val/out tiles
# and the compiler wants double-buffering headroom for the streamed blocks,
# so by default the source matrix gets half.
_DEFAULT_VMEM_BUDGET_MB = 8.0

# Programmatic overrides (take precedence over the environment) -- the
# config-file hook for deployments that cannot set env vars per-process.
_dispatch_overrides: dict[str, object] = {}


def _vmem_budget_mb(overrides: dict, env_name: str) -> float:
    """Resolve a dispatch VMEM budget: programmatic override > env > default.

    The one shared parse/validate path for the SpMM dispatch, the context
    dispatch, and the autotuner's heuristic fallback (previously copy-pasted
    per consumer).
    """
    raw = overrides.get("vmem_budget_mb",
                        hostenv.env_knob(env_name, _DEFAULT_VMEM_BUDGET_MB))
    try:
        budget = float(raw)  # type: ignore[arg-type]
    except (TypeError, ValueError):
        raise ValueError(
            f"{env_name}={raw!r}: want a positive float (MiB)") from None
    if budget <= 0.0:
        raise ValueError(f"{env_name}={raw!r}: want a positive float (MiB)")
    return budget


def _budget_forced(overrides: dict, env_name: str) -> bool:
    """True when the budget was explicitly configured -- the autotuner then
    stands down (env vars stay authoritative, DESIGN.md section 13)."""
    return "vmem_budget_mb" in overrides or hostenv.env_knob_set(env_name)


def configure_spmm_dispatch(variant: Optional[str] = None,
                            vmem_budget_mb: Optional[float] = None, *,
                            reset: bool = False) -> None:
    """Override spmm_ell dispatch: variant in {'auto', 'resident', 'hbm'}.

    Passing None leaves a setting untouched; 'auto' clears a forced variant.
    ``reset=True`` drops every programmatic override first (back to the
    environment/defaults) -- tests and benchmarks use it so one case's
    overrides never leak into the next.
    """
    if reset:
        _dispatch_overrides.clear()
    if variant is not None:
        if variant not in ("auto", "resident", "hbm"):
            raise ValueError(f"unknown spmm variant: {variant!r}")
        _dispatch_overrides["variant"] = variant
    if vmem_budget_mb is not None:
        _dispatch_overrides["vmem_budget_mb"] = float(vmem_budget_mb)


def spmm_ell_variant(n_src: int, f: int, itemsize: int = 4) -> str:
    """'resident' or 'hbm' for a [n_src, f] source matrix of `itemsize`.

    Precedence: forced variant (programmatic/env) > explicitly configured
    VMEM budget > autotuner measurement (opt-in) > size heuristic against
    the default budget.
    """
    forced = _dispatch_overrides.get(
        "variant", hostenv.env_knob("REPRO_SPMM_VARIANT", "auto"))
    if forced not in ("auto", "resident", "hbm"):
        raise ValueError(
            f"REPRO_SPMM_VARIANT={forced!r}: want auto, resident or hbm")
    if forced in ("resident", "hbm"):
        return str(forced)
    if not _budget_forced(_dispatch_overrides, "REPRO_SPMM_VMEM_BUDGET_MB"):
        tuned = autotune.tuned_spmm(n_src, f, itemsize)
        if tuned is not None:
            return str(tuned["variant"])
    budget_mb = _vmem_budget_mb(_dispatch_overrides,
                                "REPRO_SPMM_VMEM_BUDGET_MB")
    return "hbm" if n_src * f * itemsize > budget_mb * 2 ** 20 \
        else "resident"


def spmm_ell(nbr_idx: jax.Array, nbr_val: jax.Array, x: jax.Array,
             stripe_index: Optional[StripeIndex] = None, *,
             x_scale: Optional[jax.Array] = None) -> jax.Array:
    """ELLPACK SpMM with size-based resident/HBM variant dispatch.

    ``stripe_index`` (built at batch-pack time by
    ``repro.graph.batching.make_stripe_index``) is only consumed by the HBM
    variant; the resident kernel and the CPU oracle ignore it.

    ``x`` may be a ``QTensor`` of int8 or float8_e4m3fn rows (or pass
    ``x_scale`` [1, f] explicitly with a quantized ``x``): both kernel
    variants and the CPU oracle consume the storage dtype natively -- f32
    accumulate and one dequant epilogue inside the kernel, so the HBM
    variant's stripes DMA as 1-byte elements too (DESIGN.md sections
    13/15).  On backends without native fp8 arithmetic the in-kernel
    ``astype(f32)`` upcast is the fallback path -- same kernel, interpret
    mode included.

    A precomputed ``stripe_index`` pins the HBM tiling (its static
    bb/stripe override the tuner's); otherwise the autotuner's measured
    ``bb``/``stripe`` flow into whichever variant dispatch picks.
    """
    if isinstance(x, QTensor):
        x, x_scale = x.q, x.scale
    if _use_pallas():
        interpret = jax.default_backend() != "tpu"
        n_src, f = x.shape
        bb, stripe = 128, 512
        # key the tuner on the storage dtype, not just itemsize: int8 and
        # fp8 sources share itemsize 1 but are distinct operand regimes
        tuned = autotune.tuned_spmm(n_src, f, x.dtype.itemsize,
                                    dtype=x.dtype)
        if tuned is not None:
            bb = int(tuned.get("bb", bb))
            stripe = int(tuned.get("stripe", stripe))
        if spmm_ell_variant(n_src, f, x.dtype.itemsize) == "hbm":
            return spmm_ell_hbm_pallas(
                nbr_idx, nbr_val, x, stripe_index, x_scale=x_scale,
                bb=bb, stripe=stripe, interpret=interpret)
        return spmm_ell_pallas(nbr_idx, nbr_val, x, x_scale=x_scale,
                               bb=bb, interpret=interpret)
    return ref.spmm_ell(nbr_idx, nbr_val, x, x_scale)


# ---------------------------------------------------------------------------
# fused VQ-context (multi-branch codeword SpMM) dispatch
# ---------------------------------------------------------------------------

# Programmatic overrides for the context dispatch, mirroring the SpMM ones.
_context_overrides: dict[str, object] = {}


def configure_context_dispatch(variant: Optional[str] = None,
                               vmem_budget_mb: Optional[float] = None, *,
                               reset: bool = False) -> None:
    """Override context_ell dispatch: variant in {'auto', 'fused', 'loop'}.

    'fused' forces the one-pass multi-branch kernel (assignment table
    VMEM-resident); 'loop' forces the per-branch SpMM fallback (assignment
    gathered outside the kernel -- the pre-fusion path, kept for assignment
    tables beyond the VMEM envelope and for benchmarking).  ``reset=True``
    clears all programmatic overrides first.
    """
    if reset:
        _context_overrides.clear()
    if variant is not None:
        if variant not in ("auto", "fused", "loop"):
            raise ValueError(f"unknown context variant: {variant!r}")
        _context_overrides["variant"] = variant
    if vmem_budget_mb is not None:
        _context_overrides["vmem_budget_mb"] = float(vmem_budget_mb)


def context_ell_variant(n_nodes: int, n_branches: int,
                        itemsize: float = 4, dtype=None) -> str:
    """'fused' or 'loop' for an [n_branches, n_nodes] assignment table.

    The fused kernel keeps the whole assignment table VMEM-resident; past
    the VMEM envelope the per-branch loop (whose gathers run outside the
    kernel against the tiny [k, f_blk] tables) takes over.  ``itemsize``
    is bytes per assignment entry and may be fractional: nibble-packed
    tables (``PackedAssignment``) occupy 0.5 bytes/entry, which is exactly
    how the +a4 tiers double the fused-dispatch crossover again.  ``dtype``
    keys the autotuner entry (defaults to an itemsize-derived dtype).
    """
    forced = _context_overrides.get(
        "variant", hostenv.env_knob("REPRO_CONTEXT_VARIANT", "auto"))
    if forced not in ("auto", "fused", "loop"):
        raise ValueError(
            f"REPRO_CONTEXT_VARIANT={forced!r}: want auto, fused or loop")
    if forced in ("fused", "loop"):
        return str(forced)
    if not _budget_forced(_context_overrides, "REPRO_CONTEXT_VMEM_BUDGET_MB"):
        tuned = autotune.tuned_context(n_nodes, n_branches, itemsize, dtype)
        if tuned is not None:
            return str(tuned["variant"])
    budget_mb = _vmem_budget_mb(_context_overrides,
                                "REPRO_CONTEXT_VMEM_BUDGET_MB")
    return "loop" if n_nodes * n_branches * itemsize \
        > budget_mb * 2 ** 20 else "fused"


def _context_ell_loop(out_ids, out_vals, assignment, codewords, w_t,
                      cw_scale=None):
    """Per-branch fallback: assignment gather + one SpMM per branch.

    Used when the [n_branches, n] assignment table exceeds the fused
    kernel's VMEM envelope -- each branch's gather source is its tiny
    [k, f_blk] codeword table, so the per-branch SpMM always dispatches
    to the resident variant regardless of graph size.  int8/fp8 codewords
    ride into each branch's SpMM with their [1, f_blk] scale row
    (per-branch dequant before the concat == the fused kernel's flat
    epilogue).  Nibble-packed tables unpack here (outside the kernels):
    in the loop regime the table is HBM-resident anyway, so packing only
    buys storage, not the dispatch crossover.
    """
    if isinstance(assignment, PackedAssignment):
        assignment = assignment.unpack()
    branch_ids = assignment.astype(jnp.int32)[:, out_ids]  # [nb, b, D]
    per_branch = [
        spmm_ell(branch_ids[i], out_vals, codewords[i],
                 x_scale=None if cw_scale is None else cw_scale[i])
        for i in range(codewords.shape[0])]
    out = jnp.concatenate(per_branch, axis=-1)
    if w_t is not None:
        out = out.astype(jnp.float32) @ w_t.astype(jnp.float32)
    return out


# The CPU execution path is the oracle jitted as ONE fused XLA computation
# (the dispatch-level analogue of the single kernel launch: the pre-fusion
# code issued one gather + SpMM + concat dispatch chain per branch).
_context_ell_ref = jax.jit(ref.context_ell)


def context_ell(out_ids: jax.Array, out_vals: jax.Array,
                assignment: jax.Array, codewords,
                w_t: Optional[jax.Array] = None) -> jax.Array:
    """Fused multi-branch VQ-context SpMM with size-based variant dispatch.

    One dispatch regardless of n_branches: the Eq. 6 context forward
    (feature codewords) and, with reverse-edge operands + gradient
    codewords (+ optional fused ``w_t`` epilogue), the streaming Eq. 7
    backward of ``inject_context_grad`` (DESIGN.md section 10).

    The quantized tiers are data-driven (no env read under jit): pass
    ``codewords`` as a ``QTensor`` ([nb, k, f_blk] int8 or float8_e4m3fn +
    [nb, 1, f_blk] f32 scales) and an ``assignment`` that is uint8
    (k <= 256) or a nibble-packed ``PackedAssignment`` (k <= 16) -- the
    operands stay in storage dtype through every variant, with one f32
    dequant epilogue; packed tables count 0.5 bytes/entry against the
    dispatch VMEM budget (the crossover-doubling lever).
    """
    cw_scale = None
    if isinstance(codewords, QTensor):
        codewords, cw_scale = codewords.q, codewords.scale
    if _use_pallas():
        interpret = jax.default_backend() != "tpu"
        if isinstance(assignment, PackedAssignment):
            nb, n = assignment.shape
            itemsize: float = 0.5
            a_dtype = jnp.uint4
        else:
            nb, n = assignment.shape
            itemsize = assignment.dtype.itemsize
            a_dtype = assignment.dtype
        bb = 128
        tuned = autotune.tuned_context(n, nb, itemsize, dtype=a_dtype)
        if tuned is not None:
            bb = int(tuned.get("bb", bb))
        if context_ell_variant(n, nb, itemsize, dtype=a_dtype) == "fused":
            return context_ell_pallas(out_ids, out_vals, assignment,
                                      codewords, cw_scale=cw_scale, w_t=w_t,
                                      bb=bb, interpret=interpret)
        return _context_ell_loop(out_ids, out_vals, assignment, codewords,
                                 w_t, cw_scale)
    return _context_ell_ref(out_ids, out_vals, assignment, codewords, w_t,
                            cw_scale)


def flash_attention(q, k, v, *, causal: bool = True):
    if _use_pallas() and q.shape[2] % 128 == 0 and q.shape[-1] % 8 == 0:
        return flash_attention_pallas(
            q, k, v, causal=causal, interpret=jax.default_backend() != "tpu")
    return ref.flash_attention(q, k, v, causal=causal)


def vq_attention_decode(q, cb_k, cb_v, mass, win_k, win_v, win_mask):
    if _use_pallas():
        return vq_attention_decode_pallas(
            q, cb_k, cb_v, mass, win_k, win_v, win_mask,
            interpret=jax.default_backend() != "tpu")
    return jax.vmap(
        lambda qq, ck, cv, m, wk, wv, wm: ref.vq_attention_decode(
            qq, ck, cv, m, wk, wv, wm)
    )(q, cb_k, cb_v, mass, win_k, win_v, win_mask)
