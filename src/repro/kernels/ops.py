"""Dispatching wrappers around the Pallas kernels.

On a TPU backend the Pallas kernels run compiled; on the CPU host the system
executes the pure-jnp oracles from ref.py (numerically identical -- the
kernels are validated against them in interpret mode by tests/test_kernels_*).
Set REPRO_FORCE_PALLAS=1 to route every call through the interpret-mode
kernels instead (used by the kernel test sweeps and CI).

Production notes (TPU):
  * ``spmm_ell`` has two variants (DESIGN.md section 3, resident vs HBM):
    the resident kernel holds the full source matrix in VMEM; for
    n_src * f beyond the VMEM envelope the HBM variant keeps it in
    memory_space=ANY and DMAs double-buffered row stripes keyed by a
    scalar-prefetched tile->stripes index (PrefetchScalarGridSpec).  The
    size-based dispatch below picks the variant; override with
    REPRO_SPMM_VARIANT / REPRO_SPMM_VMEM_BUDGET_MB or
    ``configure_spmm_dispatch``.
  * ``flash_attention``: 32k+ sequences use a (bh, nq, nk) grid with carried
    scratch instead of the resident-KV loop (the HBM SpMM kernel's
    double-buffering idiom is the template; still TODO).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.vq_assign import vq_assign_pallas
from repro.kernels.vq_update import vq_assign_update_pallas
from repro.kernels.spmm_ell import spmm_ell_pallas
from repro.kernels.spmm_ell_hbm import StripeIndex, spmm_ell_hbm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.vq_attention import vq_attention_decode_pallas


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS", "0") == "1":
        return True
    return jax.default_backend() == "tpu"


def vq_assign(x: jax.Array, codewords: jax.Array) -> jax.Array:
    if _use_pallas():
        return vq_assign_pallas(
            x, codewords, interpret=jax.default_backend() != "tpu")
    return ref.vq_assign(x, codewords)


def vq_assign_update(x: jax.Array, codewords: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused assign + cluster stats + per-row quantization error.

    The one-pass primitive of the streaming codebook update (Alg. 2):
    returns (assignment [b], qerr [b], counts [k], sums [k, f]) from a
    single distance computation.  TPU: kernels/vq_update.py (revisited
    VMEM accumulator blocks, no one-hot); CPU: scatter-add oracle.
    """
    if _use_pallas():
        return vq_assign_update_pallas(
            x, codewords, interpret=jax.default_backend() != "tpu")
    return ref.vq_assign_update(x, codewords)


# ---------------------------------------------------------------------------
# spmm_ell resident-vs-HBM dispatch
# ---------------------------------------------------------------------------

# Per-core VMEM is ~16 MiB; the resident kernel also holds idx/val/out tiles
# and the compiler wants double-buffering headroom for the streamed blocks,
# so by default the source matrix gets half.
_DEFAULT_VMEM_BUDGET_MB = 8.0

# Programmatic overrides (take precedence over the environment) -- the
# config-file hook for deployments that cannot set env vars per-process.
_dispatch_overrides: dict[str, object] = {}


def configure_spmm_dispatch(variant: Optional[str] = None,
                            vmem_budget_mb: Optional[float] = None) -> None:
    """Override spmm_ell dispatch: variant in {'auto', 'resident', 'hbm'}.

    Passing None leaves a setting untouched; 'auto' clears a forced variant.
    """
    if variant is not None:
        if variant not in ("auto", "resident", "hbm"):
            raise ValueError(f"unknown spmm variant: {variant!r}")
        _dispatch_overrides["variant"] = variant
    if vmem_budget_mb is not None:
        _dispatch_overrides["vmem_budget_mb"] = float(vmem_budget_mb)


def spmm_ell_variant(n_src: int, f: int, itemsize: int = 4) -> str:
    """'resident' or 'hbm' for a [n_src, f] source matrix of `itemsize`."""
    forced = _dispatch_overrides.get(
        "variant", os.environ.get("REPRO_SPMM_VARIANT", "auto"))
    if forced not in ("auto", "resident", "hbm"):
        raise ValueError(
            f"REPRO_SPMM_VARIANT={forced!r}: want auto, resident or hbm")
    if forced in ("resident", "hbm"):
        return str(forced)
    budget_mb = _dispatch_overrides.get(
        "vmem_budget_mb",
        float(os.environ.get("REPRO_SPMM_VMEM_BUDGET_MB",
                             str(_DEFAULT_VMEM_BUDGET_MB))))
    return "hbm" if n_src * f * itemsize > float(budget_mb) * 2 ** 20 \
        else "resident"


def spmm_ell(nbr_idx: jax.Array, nbr_val: jax.Array, x: jax.Array,
             stripe_index: Optional[StripeIndex] = None) -> jax.Array:
    """ELLPACK SpMM with size-based resident/HBM variant dispatch.

    ``stripe_index`` (built at batch-pack time by
    ``repro.graph.batching.make_stripe_index``) is only consumed by the HBM
    variant; the resident kernel and the CPU oracle ignore it.
    """
    if _use_pallas():
        interpret = jax.default_backend() != "tpu"
        n_src, f = x.shape
        if spmm_ell_variant(n_src, f, x.dtype.itemsize) == "hbm":
            return spmm_ell_hbm_pallas(
                nbr_idx, nbr_val, x, stripe_index, interpret=interpret)
        return spmm_ell_pallas(nbr_idx, nbr_val, x, interpret=interpret)
    return ref.spmm_ell(nbr_idx, nbr_val, x)


def flash_attention(q, k, v, *, causal: bool = True):
    if _use_pallas() and q.shape[2] % 128 == 0 and q.shape[-1] % 8 == 0:
        return flash_attention_pallas(
            q, k, v, causal=causal, interpret=jax.default_backend() != "tpu")
    return ref.flash_attention(q, k, v, causal=causal)


def vq_attention_decode(q, cb_k, cb_v, mass, win_k, win_v, win_mask):
    if _use_pallas():
        return vq_attention_decode_pallas(
            q, cb_k, cb_v, mass, win_k, win_v, win_mask,
            interpret=jax.default_backend() != "tpu")
    return jax.vmap(
        lambda qq, ck, cv, m, wk, wv, wm: ref.vq_attention_decode(
            qq, ck, cv, m, wk, wv, wm)
    )(q, cb_k, cb_v, mass, win_k, win_v, win_mask)
