"""Pallas TPU kernel: block flash attention (training forward).

Used by the LM backbones for the exact-window / intra-block attention term
(the ``C_in`` part of the paper's Eq. 6 on the token graph).  Streaming
softmax with running (max, denom, acc) carried over KV tiles.

Layout decisions for the MXU:
  * q tile [bq, d] with d padded to 128 (lane width), bq = 256 default --
    the two matmuls per step are [bq, d] x [d, bk] and [bq, bk] x [bk, d];
  * KV is scanned in bk = 512 tiles via dynamic slices of the full-sequence
    block; VMEM envelope = (bq + 2 skv) * d floats, which fits the train_4k
    shape (4k * 128 * 4B * 2 = 4 MiB).  For 32k+ sequences the production
    config re-tiles with a 3-axis grid (documented in ops.py); correctness
    here is validated against ref.flash_attention in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *,
                  causal: bool, sm_scale: float, bk: int, seq_kv: int):
    qi = pl.program_id(1)
    bq, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * sm_scale

    nk = seq_kv // bk

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # [bq, bk]
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, _NEG_INF)
        new_m = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - new_m[:, None])
        alpha = jnp.exp(m - new_m)
        new_l = l * alpha + jnp.sum(p, axis=1)
        new_acc = acc * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return new_m, new_l, new_acc

    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    if causal:
        # skip fully-masked kv tiles: row block i only needs kv tiles <= i
        upto = jnp.minimum((qi + 1) * bq + bk - 1, seq_kv) // bk
    else:
        upto = nk
    m, l, acc = jax.lax.fori_loop(0, upto, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 256, bk: int = 512,
                           interpret: bool = True) -> jax.Array:
    """q: [b, h, sq, d], k/v: [b, h, skv, d] -> [b, h, sq, d].

    sq must equal skv when causal (standard training layout).
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    sm_scale = 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, sm_scale=sm_scale,
                          bk=bk, seq_kv=skv),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
            pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((None, skv, d), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
