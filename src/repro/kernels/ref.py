"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the numerical ground truth the corresponding kernel is
validated against (tests sweep shapes/dtypes and assert_allclose).  They are
also the CPU execution path of ``ops.py`` -- on the CPU host the system runs
these, on TPU the Pallas kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.quantization import PackedAssignment


def vq_assign(x: jax.Array, codewords: jax.Array) -> jax.Array:
    """Nearest codeword by squared L2.  x: [b, f], codewords: [k, f] -> [b]."""
    x32 = x.astype(jnp.float32)
    c32 = codewords.astype(jnp.float32)
    # |x - c|^2 = |x|^2 - 2 x.c + |c|^2 ; |x|^2 is constant per row.
    scores = x32 @ c32.T                                  # [b, k]
    dist = jnp.sum(c32 * c32, axis=1)[None, :] - 2.0 * scores
    return jnp.argmin(dist, axis=1).astype(jnp.int32)


def vq_assign_update(x: jax.Array, codewords: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused assign + cluster stats oracle (kernels/vq_update.py).

    x: [b, f], codewords: [k, f] -> (assignment [b] int32,
    qerr [b] = ||x - c_assign||^2, counts [k], sums [k, f]).  The stats are
    scatter-adds keyed by the assignment -- no [b, k] one-hot intermediate,
    which also makes this the fast CPU execution path of ops.py.
    """
    x32 = x.astype(jnp.float32)
    c32 = codewords.astype(jnp.float32)
    scores = x32 @ c32.T                                  # [b, k]
    dist = jnp.sum(c32 * c32, axis=1)[None, :] - 2.0 * scores
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    mind = jnp.take_along_axis(dist, idx[:, None], 1)[:, 0]
    qerr = jnp.maximum(mind + jnp.sum(x32 * x32, axis=1), 0.0)
    k = c32.shape[0]
    counts = jnp.zeros((k,), jnp.float32).at[idx].add(1.0)
    sums = jnp.zeros((k, x32.shape[1]), jnp.float32).at[idx].add(x32)
    return idx, qerr, counts, sums


def spmm_ell(nbr_idx: jax.Array, nbr_val: jax.Array, x: jax.Array,
             x_scale: jax.Array | None = None) -> jax.Array:
    """Padded-neighbor (ELLPACK) sparse @ dense.

    nbr_idx: [b, D] int32 (padding entries may point anywhere, their val is 0)
    nbr_val: [b, D] float
    x:       [n_src, f] (int8 or float8_e4m3fn rows when ``x_scale`` is
             given; the gather stays in storage dtype, the einsum upcasts)
    x_scale: optional [1, f] f32 per-channel dequant scales; applied as one
             epilogue multiply after the accumulate (row-independent scales
             commute with the over-neighbors sum -- the kernels' contract)
    returns  [b, f] with out[i] = sum_d val[i,d] * x[idx[i,d]]
    """
    gathered = x[nbr_idx]                                  # [b, D, f]
    out = jnp.einsum('bd,bdf->bf', nbr_val.astype(jnp.float32),
                     gathered.astype(jnp.float32))
    if x_scale is not None:
        out = out * x_scale.astype(jnp.float32).reshape(1, -1)
    return out


def context_ell(out_ids: jax.Array, out_vals: jax.Array,
                assignment: jax.Array, codewords: jax.Array,
                w_t: jax.Array | None = None,
                cw_scale: jax.Array | None = None) -> jax.Array:
    """Multi-branch VQ-context SpMM oracle (kernels/context_ell.py).

    out_ids/out_vals: [b, D] (padding entries carry val == 0)
    assignment: [n_branches, n] int32 (or uint8 storage, k <= 256; or a
                nibble-packed ``PackedAssignment``, k <= 16 -- the oracle
                unpacks it up front, the kernel shift/masks in-register)
    codewords: [n_branches, k, f_blk] (int8/fp8 when ``cw_scale`` is given)
    cw_scale: optional [n_branches, 1, f_blk] f32 per-branch/per-channel
              dequant scales, applied as one epilogue row multiply (the
              scales are k-independent -- same contract as the kernel)
    w_t: optional [n_branches * f_blk, f_out] fused epilogue matmul

    out[i] = sum_d val[i, d] * concat_beta cw[beta, assignment[beta, ids[i, d]]]
    (optionally @ w_t) -- the Eq. 6 context term and, with reverse-edge
    operands + gradient codewords, the streaming Eq. 7 backward.
    """
    nb, k, f_blk = codewords.shape
    b = out_ids.shape[0]
    if out_ids.shape[1] == 0:
        f_out = nb * f_blk if w_t is None else w_t.shape[1]
        return jnp.zeros((b, f_out), jnp.float32)
    if isinstance(assignment, PackedAssignment):
        assignment = assignment.unpack()
    branch_ids = assignment.astype(jnp.int32)[:, out_ids]  # [nb, b, D]
    vals = out_vals.astype(jnp.float32)
    # per-branch gather + contraction inside ONE computation (the branch
    # loop is a trace-time unroll, and this shape compiles to faster XLA
    # CPU code than a single [nb, b, D, f_blk] flat-gather einsum)
    out = jnp.concatenate(
        [jnp.einsum('bd,bdf->bf', vals,
                    codewords[i].astype(jnp.float32)[branch_ids[i]])
         for i in range(nb)], axis=-1)
    if cw_scale is not None:
        # dequant AFTER the accumulate, BEFORE the W^T mix (kernel ordering)
        out = out * cw_scale.astype(jnp.float32).reshape(1, nb * f_blk)
    if w_t is not None:
        out = out @ w_t.astype(jnp.float32)
    return out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    sm_scale: float | None = None) -> jax.Array:
    """Plain softmax attention.  q: [b, h, sq, d], k/v: [b, h, skv, d]."""
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum('bhqd,bhkd->bhqk', q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        sq, skv = q.shape[2], k.shape[2]
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum('bhqk,bhkd->bhqd', p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def vq_attention_decode(q: jax.Array, cb_k: jax.Array, cb_v: jax.Array,
                        mass: jax.Array, win_k: jax.Array, win_v: jax.Array,
                        win_mask: jax.Array, *,
                        sm_scale: float | None = None) -> jax.Array:
    """One decode step of VQ-Attention (paper Eq. 6 on the token graph).

    The out-of-window context is represented by ``k`` codewords with cluster
    masses m_v; a cluster of m identical keys contributes m * exp(q.k~) =
    exp(q.k~ + log m) to the softmax denominator -- exactly the paper's
    row-normalization trick (App. E: pad a ones column, normalize after).

    q:        [g, d]      (q heads sharing this KV group)
    cb_k/v:   [k, d]      codeword keys / values
    mass:     [k]         cluster sizes (float)
    win_k/v:  [w, d]      exact recent window
    win_mask: [w]         1.0 for valid window slots
    returns   [g, d]
    """
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    q32 = q.astype(jnp.float32) * sm_scale
    s_cb = q32 @ cb_k.astype(jnp.float32).T \
        + jnp.log(jnp.maximum(mass, 1e-9))[None, :]        # [g, k]
    s_cb = jnp.where(mass[None, :] > 0, s_cb, -jnp.inf)
    s_w = q32 @ win_k.astype(jnp.float32).T                # [g, w]
    s_w = jnp.where(win_mask[None, :] > 0, s_w, -jnp.inf)
    s = jnp.concatenate([s_cb, s_w], axis=1)
    p = jax.nn.softmax(s, axis=-1)
    out = p[:, :cb_k.shape[0]] @ cb_v.astype(jnp.float32) \
        + p[:, cb_k.shape[0]:] @ win_v.astype(jnp.float32)
    return out.astype(q.dtype)
