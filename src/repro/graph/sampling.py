"""The paper's sampling baselines (Sec. 5 / Table 2): NS-SAGE, Cluster-GCN,
GraphSAINT-RW.

Each sampler yields (src, dst, nodes) induced-subgraph triples; the baseline
trainer runs exact message passing on the sampled subgraph (which is exactly
what makes them drop messages -- the effect Table 4 measures).  Inference for
all samplers is full-neighborhood (their O(d^L) inference cost, Sec. 5).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.graph.structure import Graph, induced_subgraph


def ns_sage_batches(g: Graph, batch_size: int, fanouts: list[int],
                    rng: np.random.Generator,
                    idx_pool: np.ndarray) -> Iterator[tuple]:
    """NS-SAGE [2]: per-layer fixed-fanout neighbor sampling.

    Returns the union of sampled L-hop neighborhoods as an induced subgraph
    plus the seed positions (loss is only on seeds).  Faithful to the
    O(b r^L) node blow-up of Table 2.
    """
    perm = rng.permutation(idx_pool)
    for s in range(0, len(perm) - batch_size + 1, batch_size):
        seeds = perm[s:s + batch_size]
        frontier = seeds
        nodes = set(seeds.tolist())
        for r in fanouts:
            nxt = []
            for i in frontier:
                ns = g.in_csr.neighbors(i)
                if len(ns) > r:
                    ns = rng.choice(ns, r, replace=False)
                nxt.extend(ns.tolist())
            frontier = np.array(list(set(nxt) - nodes), np.int64)
            nodes.update(nxt)
        sub_nodes = np.array(sorted(nodes), np.int64)
        src, dst, sub_nodes = induced_subgraph(g, sub_nodes)
        seed_pos = np.searchsorted(sub_nodes, seeds)
        yield src, dst, sub_nodes, seed_pos


def partition_graph(g: Graph, n_parts: int,
                    rng: np.random.Generator) -> np.ndarray:
    """Locality-aware partition for Cluster-GCN (METIS stand-in).

    Multi-source BFS from random seeds: each partition grows around a seed,
    which on SBM-style graphs recovers community structure similarly to
    METIS (the property Cluster-GCN depends on).  O(m).
    """
    part = np.full(g.n, -1, np.int64)
    seeds = rng.choice(g.n, n_parts, replace=False)
    from collections import deque
    queues = [deque([s]) for s in seeds]
    part[seeds] = np.arange(n_parts)
    active = True
    while active:
        active = False
        for p in range(n_parts):
            q = queues[p]
            steps = 0
            while q and steps < 64:
                i = q.popleft()
                for j in g.in_csr.neighbors(i):
                    if part[j] < 0:
                        part[j] = p
                        q.append(int(j))
                        steps += 1
                active = active or steps > 0
    unassigned = np.where(part < 0)[0]
    if len(unassigned):
        part[unassigned] = rng.integers(0, n_parts, len(unassigned))
    return part


def cluster_gcn_batches(g: Graph, partition: np.ndarray, parts_per_batch: int,
                        rng: np.random.Generator) -> Iterator[tuple]:
    """Cluster-GCN [9]: sample partitions, train on their union subgraph
    (with between-cluster edges inside the union added back)."""
    n_parts = partition.max() + 1
    order = rng.permutation(n_parts)
    for s in range(0, n_parts - parts_per_batch + 1, parts_per_batch):
        chosen = order[s:s + parts_per_batch]
        nodes = np.where(np.isin(partition, chosen))[0]
        src, dst, nodes = induced_subgraph(g, nodes)
        yield src, dst, nodes, np.arange(len(nodes))


def graphsaint_rw_batches(g: Graph, roots: int, walk_length: int,
                          rng: np.random.Generator,
                          idx_pool: np.ndarray) -> Iterator[tuple]:
    """GraphSAINT-RW [10]: random-walk induced subgraphs."""
    perm = rng.permutation(idx_pool)
    for s in range(0, len(perm) - roots + 1, roots):
        cur = perm[s:s + roots].copy()
        nodes = set(cur.tolist())
        for _ in range(walk_length):
            for t in range(len(cur)):
                ns = g.in_csr.neighbors(cur[t])
                if len(ns):
                    cur[t] = ns[rng.integers(0, len(ns))]
                    nodes.add(int(cur[t]))
        sub_nodes = np.array(sorted(nodes), np.int64)
        src, dst, sub_nodes = induced_subgraph(g, sub_nodes)
        yield src, dst, sub_nodes, np.arange(len(sub_nodes))
