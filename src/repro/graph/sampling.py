"""The paper's sampling baselines (Sec. 5 / Table 2) + LABOR + the hybrid.

Samplers: NS-SAGE, Cluster-GCN, GraphSAINT-RW, and LABOR (Layer-Neighbor
Sampling, PAPERS.md) -- the strongest modern sampling baseline: one shared
uniform variate per node per layer correlates the per-seed picks, so the
sampled union (and the per-batch subgraph) is much smaller than independent
NS at the same fanout.

Every sampler yields the SAME 5-tuple contract

    (src, dst, nodes, seed_pos, seed_weight)

where ``src/dst`` are local edge endpoints of the induced subgraph,
``nodes`` the sorted global node ids, ``seed_pos`` the in-subgraph
positions of this batch's seeds, and ``seed_weight`` a float per seed --
0.0 on the wrap-padded tail seeds (the ``epoch_slices`` contract: every
pool id is a loss-bearing seed EXACTLY once per epoch; the legacy
``range(0, len - b + 1, b)`` loops silently dropped up to b-1 seeds).

The baseline trainer runs exact message passing on the sampled subgraph
(which is exactly what makes samplers drop messages -- the effect Table 4
measures), either per batch on the host loop or stacked onto the sampler
epoch executor (graph/batching.pack_sampler_epoch + lax.scan, DESIGN.md
section 12).  Inference for all samplers is full-neighborhood (their
O(d^L) inference cost, Sec. 5).

``hybrid_epoch_batches`` is not a baseline but the VQ/sampling hybrid the
Message Invariance paper (PAPERS.md) points at: batches are a seed
partition EXPANDED with LABOR-sampled multi-hop neighbors, fed to the
plain VQ epoch executor -- messages inside the sampled set go through the
exact intra-batch SpMM, everything outside through the VQ context kernel.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.graph.batching import epoch_slices
from repro.graph.structure import Graph, induced_subgraph

SamplerBatch = tuple


def _labor_select(csr, frontier: np.ndarray, fanout: int,
                  rvals: np.ndarray) -> list[np.ndarray]:
    """LABOR's per-seed neighbor pick against layer-shared uniforms.

    ``rvals`` holds ONE uniform variate per graph node, drawn once per
    layer and shared by every seed of the batch: seed i keeps its (at
    most) ``fanout`` in-neighbors with the smallest r_t.  Because the
    ranking variate is attached to the *neighbor*, seeds sharing neighbors
    make correlated picks -- the union of sampled nodes shrinks toward the
    per-seed maximum instead of growing additively (the paper's variance
    reduction).  Deterministic contract: the sampled in-degree of every
    seed is <= fanout, and identical ``rvals`` give identical picks.
    """
    out = []
    for i in frontier:
        ns = csr.neighbors(i)
        if len(ns) > fanout:
            ns = ns[np.argsort(rvals[ns], kind="stable")[:fanout]]
        out.append(ns)
    return out


def _ns_select(csr, frontier: np.ndarray, fanout: int,
               rng: np.random.Generator) -> list[np.ndarray]:
    """NS-SAGE's per-seed pick: independent uniform fanout subsets."""
    out = []
    for i in frontier:
        ns = csr.neighbors(i)
        if len(ns) > fanout:
            ns = rng.choice(ns, fanout, replace=False)
        out.append(ns)
    return out


def _expand_batch(g: Graph, seeds: np.ndarray, fanouts: list[int],
                  rng: np.random.Generator, *, labor: bool
                  ) -> tuple[set, list[list[np.ndarray]]]:
    """Union of sampled L-hop neighborhoods around ``seeds``.

    Returns (node set, per-layer list of per-frontier-node picks) -- the
    layer structure is kept so tests can assert the per-layer fanout/
    in-degree contracts directly instead of re-deriving them from the
    union."""
    frontier = np.asarray(seeds, np.int64)
    nodes = set(frontier.tolist())
    layers = []
    for r in fanouts:
        if labor:
            picks = _labor_select(g.in_csr, frontier, r, rng.random(g.n))
        else:
            picks = _ns_select(g.in_csr, frontier, r, rng)
        layers.append(picks)
        nxt = set()
        for ns in picks:
            nxt.update(int(t) for t in ns)
        frontier = np.array(sorted(nxt - nodes), np.int64)
        nodes.update(nxt)
    return nodes, layers


def _neighborhood_batches(g: Graph, batch_size: int, fanouts: list[int],
                          rng: np.random.Generator, idx_pool: np.ndarray,
                          *, labor: bool) -> Iterator[SamplerBatch]:
    """Shared NS-SAGE / LABOR driver: wrap-padded seed batches, L rounds of
    neighbor expansion, induced subgraph, loss only on real seeds."""
    ids, smask = epoch_slices(rng.permutation(idx_pool), batch_size)
    for s in range(ids.shape[0]):
        seeds = ids[s]
        nodes, _ = _expand_batch(g, seeds, fanouts, rng, labor=labor)
        sub = np.array(sorted(nodes), np.int64)
        src, dst, sub = induced_subgraph(g, sub)
        seed_pos = np.searchsorted(sub, seeds)
        yield src, dst, sub, seed_pos, smask[s].astype(np.float32)


def ns_sage_batches(g: Graph, batch_size: int, fanouts: list[int],
                    rng: np.random.Generator,
                    idx_pool: np.ndarray) -> Iterator[SamplerBatch]:
    """NS-SAGE [2]: per-layer fixed-fanout independent neighbor sampling.

    Returns the union of sampled L-hop neighborhoods as an induced subgraph
    plus the seed positions (loss is only on seeds).  Faithful to the
    O(b r^L) node blow-up of Table 2.  The seed stream is wrap-padded
    (``epoch_slices``): every pool id is a weight-1 seed exactly once per
    epoch, tail padding repeats early seeds at weight 0.
    """
    return _neighborhood_batches(g, batch_size, fanouts, rng, idx_pool,
                                 labor=False)


def labor_batches(g: Graph, batch_size: int, fanouts: list[int],
                  rng: np.random.Generator,
                  idx_pool: np.ndarray) -> Iterator[SamplerBatch]:
    """LABOR (Layer-Neighbor Sampling, PAPERS.md): NS-SAGE's loss/fanout
    contract, but each layer ranks candidate neighbors by one SHARED
    uniform variate per node (``_labor_select``), so overlapping
    neighborhoods sample the SAME nodes and the union stays near the
    per-seed maximum -- the same accuracy at a fraction of the subgraph
    size (the defusing-the-neighborhood-explosion claim)."""
    return _neighborhood_batches(g, batch_size, fanouts, rng, idx_pool,
                                 labor=True)


def partition_graph(g: Graph, n_parts: int,
                    rng: np.random.Generator) -> np.ndarray:
    """Locality-aware partition for Cluster-GCN (METIS stand-in).

    Multi-source BFS from random seeds: each partition grows around a seed,
    which on SBM-style graphs recovers community structure similarly to
    METIS (the property Cluster-GCN depends on).  O(m).
    """
    part = np.full(g.n, -1, np.int64)
    seeds = rng.choice(g.n, n_parts, replace=False)
    from collections import deque
    queues = [deque([s]) for s in seeds]
    part[seeds] = np.arange(n_parts)
    active = True
    while active:
        active = False
        for p in range(n_parts):
            q = queues[p]
            steps = 0
            while q and steps < 64:
                i = q.popleft()
                for j in g.in_csr.neighbors(i):
                    if part[j] < 0:
                        part[j] = p
                        q.append(int(j))
                        steps += 1
                active = active or steps > 0
    unassigned = np.where(part < 0)[0]
    if len(unassigned):
        part[unassigned] = rng.integers(0, n_parts, len(unassigned))
    return part


def cluster_gcn_batches(g: Graph, partition: np.ndarray,
                        parts_per_batch: int,
                        rng: np.random.Generator) -> Iterator[SamplerBatch]:
    """Cluster-GCN [9]: sample partitions, train on their union subgraph
    (with between-cluster edges inside the union added back).  The tail
    batch keeps the remaining partitions instead of dropping them -- every
    partition (hence every node) trains exactly once per epoch."""
    n_parts = int(partition.max()) + 1
    order = rng.permutation(n_parts)
    for s in range(0, n_parts, parts_per_batch):
        chosen = order[s:s + parts_per_batch]
        nodes = np.where(np.isin(partition, chosen))[0]
        src, dst, nodes = induced_subgraph(g, nodes)
        yield (src, dst, nodes, np.arange(len(nodes)),
               np.ones(len(nodes), np.float32))


def graphsaint_rw_batches(g: Graph, roots: int, walk_length: int,
                          rng: np.random.Generator,
                          idx_pool: np.ndarray) -> Iterator[SamplerBatch]:
    """GraphSAINT-RW [10]: random-walk induced subgraphs.  The root stream
    is wrap-padded (``epoch_slices``) so every pool id roots a walk at
    least once per epoch; the loss covers every subgraph node (the
    GraphSAINT full-subgraph loss)."""
    ids, _ = epoch_slices(rng.permutation(idx_pool), roots)
    for s in range(ids.shape[0]):
        cur = ids[s].copy()
        nodes = set(cur.tolist())
        for _ in range(walk_length):
            for t in range(len(cur)):
                ns = g.in_csr.neighbors(cur[t])
                if len(ns):
                    cur[t] = ns[rng.integers(0, len(ns))]
                    nodes.add(int(cur[t]))
        sub_nodes = np.array(sorted(nodes), np.int64)
        src, dst, sub_nodes = induced_subgraph(g, sub_nodes)
        yield (src, dst, sub_nodes, np.arange(len(sub_nodes)),
               np.ones(len(sub_nodes), np.float32))


SAMPLER_METHODS = ("ns-sage", "labor", "cluster-gcn", "graphsaint-rw")


def sample_epoch(g: Graph, method: str, *, batch_size: int,
                 rng: np.random.Generator, fanouts: list[int] | None = None,
                 walk_length: int = 3,
                 partition: Optional[np.ndarray] = None,
                 parts_per_batch: int = 4,
                 idx_pool: Optional[np.ndarray] = None
                 ) -> list[SamplerBatch]:
    """One epoch of pre-sampled batches for any sampler, materialized.

    The single sampling front shared by the host loop, the sampler epoch
    executor packer, the benches and the parity tests: for a given rng
    state every consumer sees the identical batch stream, which is what
    makes loop-vs-executor loss traces comparable step by step.
    """
    pool = idx_pool if idx_pool is not None else g.train_idx
    if method == "ns-sage":
        it = ns_sage_batches(g, batch_size, fanouts or [5], rng, pool)
    elif method == "labor":
        it = labor_batches(g, batch_size, fanouts or [5], rng, pool)
    elif method == "cluster-gcn":
        if partition is None:
            raise ValueError("cluster-gcn needs a partition= array")
        it = cluster_gcn_batches(g, partition, parts_per_batch, rng)
    elif method == "graphsaint-rw":
        it = graphsaint_rw_batches(g, batch_size, walk_length, rng, pool)
    else:
        raise ValueError(
            f"unknown sampler {method!r}; expected one of {SAMPLER_METHODS}")
    return list(it)


# ---------------------------------------------------------------------------
# VQ/sampling hybrid batches (DESIGN.md section 12)
# ---------------------------------------------------------------------------

def hybrid_epoch_batches(g: Graph, batch_size: int, fanouts: list[int],
                         rng: np.random.Generator,
                         n_ctx: Optional[int] = None,
                         idx_pool: Optional[np.ndarray] = None
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Sampler-expanded [S, b + n_ctx] batches for the VQ epoch executor.

    Each batch holds ``batch_size`` seed slots (an ``epoch_slices``
    partition of the pool: every node seeds exactly one batch per epoch,
    keeping every codeword assignment fresh) plus ``n_ctx`` context slots
    filled with LABOR-sampled multi-hop neighbors of the seeds.  Fed to
    the UNCHANGED ``vq_train_epoch``: ``vq_apply`` already routes in-batch
    messages through the exact intra SpMM and everything else through the
    VQ context kernel, so widening the batch with the sampled neighborhood
    makes the messages sampling would keep EXACT while VQ covers the
    out-of-batch remainder sampling would drop (Message Invariance,
    PAPERS.md).

    Returned slot mask is 1.0 only on loss-bearing seed slots: context
    slots train nothing directly (their messages and assignment refreshes
    are the point).  All ids within one row are DISTINCT (the
    ``refresh_assignment`` scatter contract): sampled duplicates are
    deduped, shortfalls are filled with out-of-batch nodes in id order.
    ``n_ctx=0`` degenerates to the plain VQ batches bit-for-bit.
    """
    pool = idx_pool if idx_pool is not None else np.arange(g.n)
    ids, smask = epoch_slices(rng.permutation(pool), batch_size)
    if ids.size == 0:
        return ids, smask
    b = ids.shape[1]
    n_ctx = b if n_ctx is None else n_ctx
    n_ctx = min(n_ctx, g.n - b)
    if n_ctx <= 0:
        return ids, smask
    out_ids = np.zeros((ids.shape[0], b + n_ctx), np.int64)
    out_mask = np.zeros((ids.shape[0], b + n_ctx), np.float32)
    for s in range(ids.shape[0]):
        seeds = ids[s]
        in_batch = np.zeros(g.n, bool)
        in_batch[seeds] = True
        picked: list[int] = []
        frontier = seeds
        for r in fanouts:
            sel = _labor_select(g.in_csr, frontier, r, rng.random(g.n))
            fresh = []
            for ns in sel:
                for t in ns:
                    t = int(t)
                    if not in_batch[t]:
                        in_batch[t] = True
                        fresh.append(t)
            picked.extend(fresh)
            frontier = np.array(sorted(fresh), np.int64)
            if len(picked) >= n_ctx:
                break
        ctx = np.array(picked[:n_ctx], np.int64)
        if len(ctx) < n_ctx:
            free = np.where(~in_batch)[0]
            ctx = np.concatenate([ctx, free[:n_ctx - len(ctx)]])
        out_ids[s, :b] = seeds
        out_ids[s, b:] = ctx
        out_mask[s, :b] = smask[s]
    return out_ids, out_mask
