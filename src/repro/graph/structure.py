"""Graph containers: CSR adjacency (both directions), features, labels, splits.

Host-side (numpy) structures feeding the device pipeline.  Max degree is
tracked so every mini-batch packs neighbors into a static ELLPACK layout
(DESIGN.md section 3: TPU wants regular shapes; degree capping happens at
dataset construction with renormalization, recorded on the dataset).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray   # [n+1] int64
    indices: np.ndarray  # [m]   int32

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.float32)

    def neighbors(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]:self.indptr[i + 1]]

    def max_degree(self) -> int:
        return int(np.diff(self.indptr).max(initial=0))


def csr_from_coo(src: np.ndarray, dst: np.ndarray, n: int) -> CSR:
    """Build CSR of in-edges: row i lists the sources j of edges j -> i."""
    order = np.argsort(dst, kind='stable')
    dst_s, src_s = dst[order], src[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, dst_s + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=src_s.astype(np.int32))


@dataclasses.dataclass
class Graph:
    """A (possibly directed) graph with node features and task labels."""
    in_csr: CSR                   # in-edges: messages INTO node i
    out_csr: CSR                  # out-edges: messages FROM node i
    features: np.ndarray          # [n, f] float32
    labels: np.ndarray            # [n] int64 or [n, c] float32 (multilabel)
    train_idx: np.ndarray
    val_idx: np.ndarray
    test_idx: np.ndarray
    multilabel: bool = False
    name: str = "graph"
    # link prediction extras
    train_edges: Optional[np.ndarray] = None   # [e, 2]
    val_edges: Optional[np.ndarray] = None
    val_neg_edges: Optional[np.ndarray] = None
    test_edges: Optional[np.ndarray] = None
    test_neg_edges: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return self.in_csr.n

    @property
    def m(self) -> int:
        return self.in_csr.m

    @property
    def f(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        if self.multilabel:
            return self.labels.shape[1]
        return int(self.labels.max()) + 1

    def degrees(self) -> np.ndarray:
        return self.in_csr.degrees()

    def max_degree(self) -> int:
        return max(self.in_csr.max_degree(), self.out_csr.max_degree())


def build_graph(src: np.ndarray, dst: np.ndarray, n: int,
                features: np.ndarray, labels: np.ndarray,
                splits: tuple[np.ndarray, np.ndarray, np.ndarray],
                multilabel: bool = False, name: str = "graph",
                **link_kwargs) -> Graph:
    """Deduplicate edges, build both CSR directions."""
    eid = src.astype(np.int64) * n + dst.astype(np.int64)
    keep = np.unique(eid, return_index=True)[1]
    src, dst = src[keep], dst[keep]
    return Graph(
        in_csr=csr_from_coo(src, dst, n),
        out_csr=csr_from_coo(dst, src, n),
        features=features.astype(np.float32),
        labels=labels,
        train_idx=splits[0], val_idx=splits[1], test_idx=splits[2],
        multilabel=multilabel, name=name, **link_kwargs)


def induced_subgraph(g: Graph, nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Edges of the induced subgraph, relabeled.  Returns (src, dst, nodes)."""
    nodes = np.unique(nodes)
    inv = np.full(g.n, -1, np.int64)
    inv[nodes] = np.arange(len(nodes))
    srcs, dsts = [], []
    for new_i, i in enumerate(nodes):
        nbrs = g.in_csr.neighbors(i)
        loc = inv[nbrs]
        sel = loc >= 0
        srcs.append(loc[sel])
        dsts.append(np.full(sel.sum(), new_i, np.int64))
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    return src, dst, nodes
