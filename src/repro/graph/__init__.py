"""Graph substrate: structures, synthetic datasets, batching, samplers."""
from repro.graph.structure import CSR, Graph, build_graph, csr_from_coo
from repro.graph.datasets import DATASETS
from repro.graph.batching import (FullGraphOperands, full_operands,
                                  inductive_view, make_pack,
                                  make_stripe_index, minibatch_stream,
                                  subgraph_operands)
