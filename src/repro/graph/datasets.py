"""Synthetic look-alikes of the paper's benchmarks (no network access).

Each generator produces an SBM-style graph whose (n, avg degree, feature
dim, #classes, split fraction, task type) are scaled-down matches of the
paper's Table 6 statistics.  Features are class-conditioned Gaussians plus a
structural component (neighbor mixing), so message passing is genuinely
useful -- plain MLPs cap well below GNN accuracy, which is what lets the
benchmark discriminate VQ-GNN vs sampling baselines the way the paper does.

Degree is capped at ``max_degree`` with renormalization (recorded on the
dataset) so mini-batch neighbor lists pack into static ELLPACK slots.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, build_graph


def _sbm_edges(rng: np.random.Generator, labels: np.ndarray, avg_deg: float,
               homophily: float, max_degree: int) -> tuple[np.ndarray, np.ndarray]:
    """Degree-capped stochastic block model edges (undirected, symmetrized)."""
    n = len(labels)
    n_classes = labels.max() + 1
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    half = max(1, int(avg_deg) // 2)
    degs = np.clip(rng.poisson(half, n), 1, max_degree // 2)
    total = int(degs.sum())
    srcs = np.repeat(np.arange(n), degs)
    same = rng.random(total) < homophily
    # homophilous endpoints: uniform within own class; else uniform global
    dst = rng.integers(0, n, total)
    for c in range(n_classes):
        sel = same & (labels[srcs] == c)
        if sel.any():
            dst[sel] = rng.choice(by_class[c], size=int(sel.sum()))
    # drop self loops, symmetrize
    keep = srcs != dst
    s, d = srcs[keep], dst[keep]
    src_all = np.concatenate([s, d])
    dst_all = np.concatenate([d, s])
    # degree cap: keep first max_degree in-edges per node
    order = rng.permutation(len(src_all))
    src_all, dst_all = src_all[order], dst_all[order]
    count = np.zeros(n, np.int64)
    keep = np.zeros(len(dst_all), bool)
    for idx in range(len(dst_all)):
        t = dst_all[idx]
        if count[t] < max_degree:
            count[t] += 1
            keep[idx] = True
    return src_all[keep], dst_all[keep]


def _features(rng: np.random.Generator, labels: np.ndarray, f: int,
              noise: float, src: np.ndarray, dst: np.ndarray,
              mix: float = 0.3, sub_clusters: int = 6) -> np.ndarray:
    """Class-conditioned features with sub-cluster structure.

    Real benchmark features (averaged word embeddings, bag-of-words PCA) are
    highly clusterable -- the paper's App. G ablation shows codebook size 64
    already works on ogbn-arxiv.  We reproduce that regime: each class owns
    ``sub_clusters`` sub-centers; within-sub-cluster noise is a fraction of
    the between-center spread.
    """
    n_classes = labels.max() + 1
    centers = rng.normal(0, 1, (n_classes, f)).astype(np.float32)
    subs = centers[:, None, :] + 0.6 * rng.normal(
        0, 1, (n_classes, sub_clusters, f)).astype(np.float32)
    sub_of = rng.integers(0, sub_clusters, len(labels))
    x = subs[labels, sub_of] + (0.35 * noise) * rng.normal(
        0, 1, (len(labels), f)).astype(np.float32)
    # structural mixing: one hop of averaging pushes information into the
    # graph structure (GNNs beat MLPs; message dropping hurts)
    agg = np.zeros_like(x)
    cnt = np.zeros(len(labels), np.float32)
    np.add.at(agg, dst, x[src])
    np.add.at(cnt, dst, 1.0)
    agg /= np.maximum(cnt, 1.0)[:, None]
    return ((1 - mix) * x + mix * agg).astype(np.float32)


def _splits(rng: np.random.Generator, n: int,
            train_frac: float) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    perm = rng.permutation(n)
    n_tr = int(train_frac * n)
    n_val = int(0.15 * n)
    return perm[:n_tr], perm[n_tr:n_tr + n_val], perm[n_tr + n_val:]


def _node_classification(name: str, n: int, f: int, n_classes: int,
                         avg_deg: float, homophily: float, noise: float,
                         train_frac: float, max_degree: int,
                         seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n)
    src, dst = _sbm_edges(rng, labels, avg_deg, homophily, max_degree)
    x = _features(rng, labels, f, noise, src, dst)
    return build_graph(src, dst, n, x, labels.astype(np.int64),
                       _splits(rng, n, train_frac), name=name)


# --- the five benchmarks of Tables 4, 6, 7 (scaled-down stats) -------------

def synthetic_arxiv(n: int = 6000, seed: int = 0) -> Graph:
    """ogbn-arxiv look-alike: citation graph, 40 classes, deg ~ 7, f = 128."""
    return _node_classification("arxiv-syn", n, 128, 40, avg_deg=7.0,
                                homophily=0.65, noise=0.8, train_frac=0.54,
                                max_degree=32, seed=seed)


def synthetic_reddit(n: int = 4000, seed: int = 1) -> Graph:
    """Reddit look-alike: dense social graph, 41 classes, deg ~ 25 (capped),
    f = 64 (stands in for 602; dense-degree is the stressor, Table 6)."""
    return _node_classification("reddit-syn", n, 64, 41, avg_deg=25.0,
                                homophily=0.7, noise=0.7, train_frac=0.66,
                                max_degree=48, seed=seed)


def synthetic_flickr(n: int = 5000, seed: int = 2) -> Graph:
    """Flickr look-alike: 7 classes, deg ~ 10, f = 100."""
    return _node_classification("flickr-syn", n, 100, 7, avg_deg=10.0,
                                homophily=0.55, noise=1.0, train_frac=0.50,
                                max_degree=32, seed=seed)


def synthetic_ppi(n: int = 4000, n_labels: int = 24, seed: int = 3) -> Graph:
    """PPI look-alike: inductive, multi-label (121 -> 24), deg ~ 14.

    Inductive split: test nodes' edges to train nodes are REMOVED from the
    training graph view (handled by repro.graph.batching.inductive_view).
    """
    rng = np.random.default_rng(seed)
    # latent communities drive both edges and the multilabel targets
    z = rng.integers(0, 12, n)
    src, dst = _sbm_edges(rng, z, 14.0, 0.6, max_degree=40)
    proto = rng.random((12, n_labels)) < 0.3
    flip = rng.random((n, n_labels)) < 0.1
    y = np.logical_xor(proto[z], flip).astype(np.float32)
    x = _features(rng, z, 50, 1.0, src, dst)
    return build_graph(src, dst, n, x, y, _splits(rng, n, 0.79),
                       multilabel=True, name="ppi-syn")


def synthetic_collab(n: int = 5000, seed: int = 4) -> Graph:
    """ogbl-collab look-alike: link prediction, deg ~ 5, f = 128.

    Positive edges split into message-passing/train/val/test; negatives
    sampled uniformly.  Metric: Hits@50 (benchmarks/bench_performance.py).
    """
    rng = np.random.default_rng(seed)
    z = rng.integers(0, 30, n)
    src, dst = _sbm_edges(rng, z, 8.0, 0.7, max_degree=32)
    x = _features(rng, z, 128, 0.9, src, dst)

    und = src < dst
    edges = np.stack([src[und], dst[und]], 1)
    perm = rng.permutation(len(edges))
    n_val = n_test = max(64, len(edges) // 10)
    val_e = edges[perm[:n_val]]
    test_e = edges[perm[n_val:n_val + n_test]]
    msg_e = edges[perm[n_val + n_test:]]

    def negs(count):
        return np.stack([rng.integers(0, n, count),
                         rng.integers(0, n, count)], 1)

    s2, d2 = msg_e[:, 0], msg_e[:, 1]
    return build_graph(np.concatenate([s2, d2]), np.concatenate([d2, s2]), n,
                       x, z.astype(np.int64), _splits(rng, n, 0.8),
                       name="collab-syn",
                       train_edges=msg_e, val_edges=val_e,
                       val_neg_edges=negs(len(val_e)), test_edges=test_e,
                       test_neg_edges=negs(len(test_e)))


DATASETS = {
    "arxiv": synthetic_arxiv,
    "reddit": synthetic_reddit,
    "flickr": synthetic_flickr,
    "ppi": synthetic_ppi,
    "collab": synthetic_collab,
}
