"""Host-side mini-batch packing: Graph -> MinibatchPack (static ELL shapes).

The packer is the only host<->device seam of the graph path: it ships, per
mini-batch, Theta(b * D) integers/floats -- batch features, padded neighbor
ids, in-batch positions -- never O(n).  At pod scale this runs per-host on
its data shard; here it is a numpy routine feeding jit'd steps.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.conv import MinibatchPack
from repro.graph.structure import CSR, Graph
from repro.kernels.spmm_ell_hbm import StripeIndex, clamp_tiles


def _pack_rows(csr: CSR, ids: np.ndarray, deg_cap: int,
               inv: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    b = len(ids)
    nbr = np.zeros((b, deg_cap), np.int32)
    mask = np.zeros((b, deg_cap), np.float32)
    pos = np.full((b, deg_cap), -1, np.int32)
    for r, i in enumerate(ids):
        ns = csr.neighbors(i)[:deg_cap]
        d = len(ns)
        nbr[r, :d] = ns
        mask[r, :d] = 1.0
        pos[r, :d] = inv[ns]
    return nbr, mask, pos


def make_stripe_index(nbr_idx: np.ndarray, n_src: int, *,
                      mask: np.ndarray | None = None,
                      bb: int = 128, stripe: int = 512,
                      max_stripes: int | None = None) -> StripeIndex:
    """Host-side tile->stripes metadata for the HBM SpMM kernel.

    Built at batch-pack time so the scalar-prefetch operands ride along
    with the pack instead of being recomputed in-jit every step.  ``mask``
    marks real (non-padding) neighbor slots; padding slots touch no stripe.
    Mirrors the kernel's tile clamping (``clamp_tiles``) so the index is
    valid for ``spmm_ell_hbm_pallas`` on a [len(nbr_idx), n_src-row] call.

    The ids width is shape-derived -- min(n_stripes, bb * deg) -- NOT the
    batch's observed maximum, so successive packs of the same dataset keep
    identical shapes and jit'd steps never retrace.  ``max_stripes`` caps
    it tighter (e.g. a measured dataset locality bound, keeping the
    scalar-prefetch operand small on huge graphs); a batch exceeding the
    cap raises rather than silently dropping stripes.
    """
    nbr_idx = np.asarray(nbr_idx)
    b, deg = nbr_idx.shape
    bb, stripe = clamp_tiles(b, n_src, bb, stripe)
    bp = (b + bb - 1) // bb * bb
    nt = bp // bb
    n_stripes = (n_src + stripe - 1) // stripe
    sid = np.zeros((bp, deg), np.int64)
    valid = np.zeros((bp, deg), bool)
    sid[:b] = np.clip(nbr_idx, 0, None) // stripe
    valid[:b] = np.ones((b, deg), bool) if mask is None \
        else np.asarray(mask) != 0
    sid, valid = sid.reshape(nt, bb * deg), valid.reshape(nt, bb * deg)
    per_tile = [np.unique(sid[t][valid[t]]) for t in range(nt)]
    ms = max_stripes if max_stripes is not None \
        else max(1, min(n_stripes, bb * deg))
    worst = max((len(u) for u in per_tile), default=0)
    if worst > ms:
        raise ValueError(
            f"a row tile touches {worst} stripes > max_stripes={ms}; "
            f"raise the cap or the stripe size")
    ids = np.zeros((nt, ms), np.int32)
    counts = np.zeros((nt,), np.int32)
    for t, u in enumerate(per_tile):
        ids[t, :len(u)] = u
        counts[t] = len(u)
    return StripeIndex(jnp.asarray(ids), jnp.asarray(counts),
                       bb=bb, stripe=stripe, n_src=n_src)


def make_pack(g: Graph, batch_ids: np.ndarray, deg_cap: int | None = None,
              *, stripe_index: bool = False, stripe_bb: int = 128,
              stripe: int = 512) -> MinibatchPack:
    """Pack a mini-batch; with ``stripe_index=True`` also emit the
    tile->stripes metadata the HBM SpMM kernel's scalar prefetch needs for
    the intra-batch term (source rows = batch positions)."""
    deg_cap = deg_cap or g.max_degree()
    inv = np.full(g.n, -1, np.int32)
    inv[batch_ids] = np.arange(len(batch_ids), dtype=np.int32)
    nbr, nmask, npos = _pack_rows(g.in_csr, batch_ids, deg_cap, inv)
    rev, rmask, rpos = _pack_rows(g.out_csr, batch_ids, deg_cap, inv)
    sidx: Optional[StripeIndex] = None
    if stripe_index:
        # intra-term gather source is x_b: indices are in-batch positions,
        # valid only where the neighbor is itself in the batch
        sidx = make_stripe_index(np.maximum(npos, 0), len(batch_ids),
                                 mask=(npos >= 0) & (nmask != 0),
                                 bb=stripe_bb, stripe=stripe)
    return MinibatchPack(
        batch_ids=jnp.asarray(batch_ids.astype(np.int32)),
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(nmask),
        nbr_pos=jnp.asarray(npos),
        rev_ids=jnp.asarray(rev), rev_mask=jnp.asarray(rmask),
        rev_pos=jnp.asarray(rpos), stripe_index=sidx)


class FullGraphOperands(NamedTuple):
    """Whole-(sub)graph ELL operands for exact message passing.

    Used by the full-graph oracle, the sampling baselines (on their sampled
    subgraphs) and the inference path.  NamedTuple -> a jit-able pytree.
    ``stripe_index`` (optional) carries the tile->stripes metadata that
    routes the [n, f] feature matrix through the HBM SpMM variant when it
    exceeds the VMEM envelope (DESIGN.md section 3).
    """
    nbr_ids: jnp.ndarray    # [n, D]
    nbr_mask: jnp.ndarray   # [n, D]
    degrees: jnp.ndarray    # [n]
    stripe_index: Optional[StripeIndex] = None


def full_operands(g: Graph, deg_cap: int | None = None, *,
                  stripe_index: bool = False, stripe_bb: int = 128,
                  stripe: int = 512) -> FullGraphOperands:
    deg_cap = deg_cap or g.max_degree()
    inv = np.arange(g.n, dtype=np.int32)   # every node is "in batch"
    ids = np.arange(g.n)
    nbr, mask, _ = _pack_rows(g.in_csr, ids, deg_cap, inv)
    sidx = make_stripe_index(nbr, g.n, mask=mask, bb=stripe_bb,
                             stripe=stripe) if stripe_index else None
    return FullGraphOperands(
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(mask),
        degrees=jnp.asarray(g.degrees()), stripe_index=sidx)


def subgraph_operands(src: np.ndarray, dst: np.ndarray, n_sub: int,
                      deg_cap: int) -> FullGraphOperands:
    from repro.graph.structure import csr_from_coo
    csr = csr_from_coo(src.astype(np.int64), dst.astype(np.int64), n_sub)
    inv = np.arange(n_sub, dtype=np.int32)
    nbr, mask, _ = _pack_rows(csr, np.arange(n_sub), deg_cap, inv)
    return FullGraphOperands(
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(mask),
        degrees=jnp.asarray(csr.degrees()))


def inductive_view(g: Graph) -> Graph:
    """Training view for the inductive setting (PPI): val/test nodes and all
    their edges are invisible during training (paper Sec. 6)."""
    visible = np.zeros(g.n, bool)
    visible[g.train_idx] = True
    keep_src, keep_dst = [], []
    for i in np.where(visible)[0]:
        ns = g.in_csr.neighbors(i)
        ns = ns[visible[ns]]
        keep_src.append(ns)
        keep_dst.append(np.full(len(ns), i, np.int64))
    src = np.concatenate(keep_src) if keep_src else np.zeros(0, np.int64)
    dst = np.concatenate(keep_dst) if keep_dst else np.zeros(0, np.int64)
    from repro.graph.structure import build_graph
    return build_graph(src, dst, g.n, g.features, g.labels,
                       (g.train_idx, g.val_idx, g.test_idx),
                       multilabel=g.multilabel, name=g.name + "-inductive")


def minibatch_stream(g: Graph, batch_size: int, rng: np.random.Generator,
                     idx_pool: np.ndarray | None = None,
                     deg_cap: int | None = None) -> Iterator[MinibatchPack]:
    """Random-node mini-batches covering the pool once per epoch (the
    paper's default sampling strategy; App. G shows edge/RW sampling give
    the same accuracy)."""
    pool = idx_pool if idx_pool is not None else np.arange(g.n)
    perm = rng.permutation(pool)
    for s in range(0, len(perm) - batch_size + 1, batch_size):
        yield make_pack(g, perm[s:s + batch_size], deg_cap)
