"""Host-side mini-batch packing: Graph -> MinibatchPack (static ELL shapes).

The packer is the only host<->device seam of the graph path: it ships, per
mini-batch, Theta(b * D) integers/floats -- batch features, padded neighbor
ids, in-batch positions -- never O(n).  At pod scale this runs per-host on
its data shard; here it is a numpy routine feeding jit'd steps.

Epoch executor (DESIGN.md section 9): :func:`build_epoch_plan` packs the
WHOLE graph once into device-resident per-node neighbor tables; after that
every epoch's S stacked [S, b, D] batches are derived *in-jit* from a node
permutation by :func:`plan_batch` (gather rows + recompute in-batch
positions with a node->slot scatter), so the training loop never returns to
host-side packing.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.conv import MinibatchPack
from repro.graph.structure import CSR, Graph
from repro.kernels.spmm_ell_hbm import StripeIndex, clamp_tiles


def _pack_rows(csr: CSR, ids: np.ndarray, deg_cap: int,
               inv: np.ndarray | None = None
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    """Padded (ELLPACK) neighbor rows for ``ids`` -- fully vectorized CSR
    slicing (one fancy-gather over ``csr.indices``, no per-row Python loop:
    the per-batch host cost is a handful of numpy kernels regardless of b).
    ``inv`` (node -> in-batch position, -1 elsewhere) is optional: callers
    that do not need positions -- or recompute them in-jit, like
    ``build_epoch_plan`` -- pass None and skip that [b, D] gather."""
    ids = np.asarray(ids, np.int64)
    b = len(ids)
    starts = csr.indptr[ids]                                  # [b]
    degs = np.minimum(csr.indptr[ids + 1] - starts, deg_cap)  # [b]
    offs = np.arange(deg_cap, dtype=np.int64)[None, :]        # [1, D]
    valid = offs < degs[:, None]                              # [b, D]
    if csr.m == 0:
        nbr = np.zeros((b, deg_cap), np.int32)
    else:
        nbr = csr.indices[np.where(valid, starts[:, None] + offs, 0)
                          ].astype(np.int32)
        nbr[~valid] = 0
    mask = valid.astype(np.float32)
    pos = None if inv is None else \
        np.where(valid, inv[nbr], np.int32(-1)).astype(np.int32)
    return nbr, mask, pos


def make_stripe_index(nbr_idx: np.ndarray, n_src: int, *,
                      mask: np.ndarray | None = None,
                      bb: int = 128, stripe: int = 512,
                      max_stripes: int | None = None) -> StripeIndex:
    """Host-side tile->stripes metadata for the HBM SpMM kernel.

    Built at batch-pack time so the scalar-prefetch operands ride along
    with the pack instead of being recomputed in-jit every step.  ``mask``
    marks real (non-padding) neighbor slots; padding slots touch no stripe.
    Mirrors the kernel's tile clamping (``clamp_tiles``) so the index is
    valid for ``spmm_ell_hbm_pallas`` on a [len(nbr_idx), n_src-row] call.

    The ids width is shape-derived -- min(n_stripes, bb * deg) -- NOT the
    batch's observed maximum, so successive packs of the same dataset keep
    identical shapes and jit'd steps never retrace.  ``max_stripes`` caps
    it tighter (e.g. a measured dataset locality bound, keeping the
    scalar-prefetch operand small on huge graphs); a batch exceeding the
    cap raises rather than silently dropping stripes.
    """
    nbr_idx = np.asarray(nbr_idx)
    b, deg = nbr_idx.shape
    bb, stripe = clamp_tiles(b, n_src, bb, stripe)
    bp = (b + bb - 1) // bb * bb
    nt = bp // bb
    n_stripes = (n_src + stripe - 1) // stripe
    sid = np.zeros((bp, deg), np.int64)
    valid = np.zeros((bp, deg), bool)
    sid[:b] = np.clip(nbr_idx, 0, None) // stripe
    valid[:b] = np.ones((b, deg), bool) if mask is None \
        else np.asarray(mask) != 0
    sid, valid = sid.reshape(nt, bb * deg), valid.reshape(nt, bb * deg)
    per_tile = [np.unique(sid[t][valid[t]]) for t in range(nt)]
    ms = max_stripes if max_stripes is not None \
        else max(1, min(n_stripes, bb * deg))
    worst = max((len(u) for u in per_tile), default=0)
    if worst > ms:
        raise ValueError(
            f"a row tile touches {worst} stripes > max_stripes={ms}; "
            f"raise the cap or the stripe size")
    ids = np.zeros((nt, ms), np.int32)
    counts = np.zeros((nt,), np.int32)
    for t, u in enumerate(per_tile):
        ids[t, :len(u)] = u
        counts[t] = len(u)
    return StripeIndex(jnp.asarray(ids), jnp.asarray(counts),
                       bb=bb, stripe=stripe, n_src=n_src)


def make_pack(g: Graph, batch_ids: np.ndarray, deg_cap: int | None = None,
              *, stripe_index: bool = False, stripe_bb: int = 128,
              stripe: int = 512,
              slot_mask: np.ndarray | None = None) -> MinibatchPack:
    """Pack a mini-batch; with ``stripe_index=True`` also emit the
    tile->stripes metadata the HBM SpMM kernel's scalar prefetch needs for
    the intra-batch term (source rows = batch positions).  ``slot_mask``
    (optional, [b]) marks padding slots of a wrap-padded tail batch with 0
    so the loss skips them (:func:`epoch_slices`)."""
    deg_cap = deg_cap or g.max_degree()
    inv = np.full(g.n, -1, np.int32)
    inv[batch_ids] = np.arange(len(batch_ids), dtype=np.int32)
    nbr, nmask, npos = _pack_rows(g.in_csr, batch_ids, deg_cap, inv)
    rev, rmask, rpos = _pack_rows(g.out_csr, batch_ids, deg_cap, inv)
    sidx: Optional[StripeIndex] = None
    if stripe_index:
        # intra-term gather source is x_b: indices are in-batch positions,
        # valid only where the neighbor is itself in the batch
        sidx = make_stripe_index(np.maximum(npos, 0), len(batch_ids),
                                 mask=(npos >= 0) & (nmask != 0),
                                 bb=stripe_bb, stripe=stripe)
    return MinibatchPack(
        batch_ids=jnp.asarray(batch_ids.astype(np.int32)),
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(nmask),
        nbr_pos=jnp.asarray(npos),
        rev_ids=jnp.asarray(rev), rev_mask=jnp.asarray(rmask),
        rev_pos=jnp.asarray(rpos), stripe_index=sidx,
        slot_mask=None if slot_mask is None
        else jnp.asarray(slot_mask.astype(np.float32)))


class FullGraphOperands(NamedTuple):
    """Whole-(sub)graph ELL operands for exact message passing.

    Used by the full-graph oracle, the sampling baselines (on their sampled
    subgraphs) and the inference path.  NamedTuple -> a jit-able pytree.
    ``stripe_index`` (optional) carries the tile->stripes metadata that
    routes the [n, f] feature matrix through the HBM SpMM variant when it
    exceeds the VMEM envelope (DESIGN.md section 3).
    """
    nbr_ids: jnp.ndarray    # [n, D]
    nbr_mask: jnp.ndarray   # [n, D]
    degrees: jnp.ndarray    # [n]
    stripe_index: Optional[StripeIndex] = None


def full_operands(g: Graph, deg_cap: int | None = None, *,
                  stripe_index: bool = False, stripe_bb: int = 128,
                  stripe: int = 512) -> FullGraphOperands:
    deg_cap = deg_cap or g.max_degree()
    ids = np.arange(g.n)
    nbr, mask, _ = _pack_rows(g.in_csr, ids, deg_cap)
    sidx = make_stripe_index(nbr, g.n, mask=mask, bb=stripe_bb,
                             stripe=stripe) if stripe_index else None
    return FullGraphOperands(
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(mask),
        degrees=jnp.asarray(g.degrees()), stripe_index=sidx)


def subgraph_operands(src: np.ndarray, dst: np.ndarray, n_sub: int,
                      deg_cap: int) -> FullGraphOperands:
    from repro.graph.structure import csr_from_coo
    csr = csr_from_coo(src.astype(np.int64), dst.astype(np.int64), n_sub)
    nbr, mask, _ = _pack_rows(csr, np.arange(n_sub), deg_cap)
    return FullGraphOperands(
        nbr_ids=jnp.asarray(nbr), nbr_mask=jnp.asarray(mask),
        degrees=jnp.asarray(csr.degrees()))


def inductive_view(g: Graph) -> Graph:
    """Training view for the inductive setting (PPI): val/test nodes and all
    their edges are invisible during training (paper Sec. 6)."""
    visible = np.zeros(g.n, bool)
    visible[g.train_idx] = True
    keep_src, keep_dst = [], []
    for i in np.where(visible)[0]:
        ns = g.in_csr.neighbors(i)
        ns = ns[visible[ns]]
        keep_src.append(ns)
        keep_dst.append(np.full(len(ns), i, np.int64))
    src = np.concatenate(keep_src) if keep_src else np.zeros(0, np.int64)
    dst = np.concatenate(keep_dst) if keep_dst else np.zeros(0, np.int64)
    from repro.graph.structure import build_graph
    return build_graph(src, dst, g.n, g.features, g.labels,
                       (g.train_idx, g.val_idx, g.test_idx),
                       multilabel=g.multilabel, name=g.name + "-inductive")


PAD_BUCKET_CAP = 1 << 22


def pad_bucket(n: int, cap: int = PAD_BUCKET_CAP) -> int:
    """Round a sampled-subgraph size up to a power-of-two bucket (>= 256),
    clamped to ``cap``, so one compile is reused: varying sampled-subgraph
    shapes otherwise recompile every batch and eventually exhaust the XLA
    CPU JIT.

    A subgraph larger than the cap is a hard error -- silently clamping
    ``n`` itself would drop real nodes (`.at[:n_real].set` overflow) and
    surface as a bare IndexError far from the cause.  With ``n <= cap``
    enforced, the bucket clamp can only shrink padding (sizes in
    (cap/2, cap] share the cap bucket), never drop real nodes."""
    if n > cap:
        raise ValueError(
            f"sampled subgraph has {n} nodes, above the pad-bucket cap "
            f"{cap}: shrink the sampler batch size / walk length / fanout "
            f"or raise the cap")
    b = 256
    while b < n:
        b *= 2
    return min(b, cap)


def epoch_slices(perm: np.ndarray,
                 batch_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Split a node permutation into S static-shape batches: [S, b] ids +
    [S, b] slot mask.

    The tail batch is wrap-padded with nodes from the START of the
    permutation (real nodes -> their messages and assignment refreshes stay
    valid; they merely occur twice in the epoch) and the padding slots are
    masked out of the loss via the 0 entries of the slot mask.  Shared by
    the host-driven stream and the device-resident epoch executor so both
    paths traverse identical batches for the same permutation.

    ``batch_size`` is clamped to the pool size, which guarantees every
    batch holds DISTINCT nodes (for S >= 2 the pad, < b, comes from batch
    0's range; for S == 1 there is no pad): duplicate ids inside one batch
    would make the node->slot scatter order-dependent and corrupt the
    counts arithmetic of ``refresh_assignment``.
    """
    perm = np.asarray(perm)
    n = len(perm)
    batch_size = min(batch_size, n)
    if n == 0:
        return (np.zeros((0, 0), np.int64), np.zeros((0, 0), np.float32))
    n_batches = -(-n // batch_size)
    pad = n_batches * batch_size - n
    ids = np.concatenate([perm, perm[:pad]]) if pad else perm
    slot_mask = np.ones(n_batches * batch_size, np.float32)
    slot_mask[n:] = 0.0
    return (ids.reshape(n_batches, batch_size),
            slot_mask.reshape(n_batches, batch_size))


def inference_slices(n: int,
                     batch_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Static-shape inference batches: :func:`epoch_slices` over the
    identity permutation (inference traverses every node once, in order --
    shuffling buys nothing without a loss).  Shared by ``vq_inference``,
    the serving warm pass, and the inference benchmark so every consumer
    inherits the wrap-padded tail-batch contract instead of re-inventing a
    ragged tail (the pre-executor path recompiled per layer whenever
    ``n % batch_size != 0``)."""
    return epoch_slices(np.arange(n), batch_size)


def minibatch_stream(g: Graph, batch_size: int, rng: np.random.Generator,
                     idx_pool: np.ndarray | None = None,
                     deg_cap: int | None = None) -> Iterator[MinibatchPack]:
    """Random-node mini-batches covering the pool once per epoch (the
    paper's default sampling strategy; App. G shows edge/RW sampling give
    the same accuracy).  The tail batch is wrap-padded to the static batch
    size with loss-masked slots (``epoch_slices``) so every node of the
    pool is traversed every epoch -- the freshness contract of
    ``node_loss``'s docstring."""
    pool = idx_pool if idx_pool is not None else np.arange(g.n)
    ids, slot_mask = epoch_slices(rng.permutation(pool), batch_size)
    for s in range(ids.shape[0]):
        yield make_pack(g, ids[s], deg_cap, slot_mask=slot_mask[s])


# ---------------------------------------------------------------------------
# device-resident epoch plans (DESIGN.md section 9)
# ---------------------------------------------------------------------------

class EpochPlan(NamedTuple):
    """Pack-once, device-resident neighbor tables for the epoch executor.

    Built ONCE per (graph, deg_cap) by :func:`build_epoch_plan`; holds the
    padded in-/out-edge lists of EVERY node as [n, D] device arrays.  An
    epoch's S stacked batches (logically [S, b, D]) are materialized lazily
    inside jit by :func:`plan_batch`: gather the rows of a batch's node ids
    and recompute ``nbr_pos``/``rev_pos`` with a node->slot scatter.  A
    reshuffle therefore costs one device gather per batch -- zero host-side
    pack work inside the epoch loop.
    """
    nbr_ids: jnp.ndarray    # [n, D]   in-neighbor global ids (0 on padding)
    nbr_mask: jnp.ndarray   # [n, D]   1.0 on real in-edges
    rev_ids: jnp.ndarray    # [n, Dr]  out-edge target global ids
    rev_mask: jnp.ndarray   # [n, Dr]

    @property
    def n(self) -> int:
        return self.nbr_ids.shape[0]


def build_epoch_plan(g: Graph, deg_cap: int | None = None, *,
                     full_ops: Optional[FullGraphOperands] = None
                     ) -> EpochPlan:
    """One-time whole-graph pack (vectorized CSR slicing) -> device tables.

    O(n * D) device bytes -- the same order as the ``full_operands`` the
    trainer already keeps resident for evaluation.  Pass those as
    ``full_ops`` and the plan ALIASES their in-edge tables (when the
    deg_cap matches) instead of packing and storing the [n, D] forward
    tables a second time; only the reverse tables are new.
    """
    deg_cap = deg_cap or g.max_degree()
    ids = np.arange(g.n)
    # no inv: positions are recomputed in-jit by plan_batch per batch
    if full_ops is not None and tuple(full_ops.nbr_ids.shape) == \
            (g.n, deg_cap):
        nbr_d, nmask_d = full_ops.nbr_ids, full_ops.nbr_mask
    else:
        nbr, nmask, _ = _pack_rows(g.in_csr, ids, deg_cap)
        nbr_d, nmask_d = jnp.asarray(nbr), jnp.asarray(nmask)
    rev, rmask, _ = _pack_rows(g.out_csr, ids, deg_cap)
    return EpochPlan(nbr_ids=nbr_d, nbr_mask=nmask_d,
                     rev_ids=jnp.asarray(rev), rev_mask=jnp.asarray(rmask))


def plan_batch(plan: EpochPlan, batch_ids: jnp.ndarray,
               slot_mask: Optional[jnp.ndarray] = None) -> MinibatchPack:
    """In-jit MinibatchPack for one batch of a permutation (node->slot
    scatter + row gather; bit-identical to ``make_pack`` on the same ids,
    minus the host-only stripe-index option)."""
    b = batch_ids.shape[0]
    batch_ids = batch_ids.astype(jnp.int32)
    slot = jnp.full((plan.n,), -1, jnp.int32).at[batch_ids].set(
        jnp.arange(b, dtype=jnp.int32))
    nbr = plan.nbr_ids[batch_ids]
    nmask = plan.nbr_mask[batch_ids]
    rev = plan.rev_ids[batch_ids]
    rmask = plan.rev_mask[batch_ids]
    npos = jnp.where(nmask != 0, slot[nbr], -1).astype(jnp.int32)
    rpos = jnp.where(rmask != 0, slot[rev], -1).astype(jnp.int32)
    return MinibatchPack(
        batch_ids=batch_ids, nbr_ids=nbr, nbr_mask=nmask, nbr_pos=npos,
        rev_ids=rev, rev_mask=rmask, rev_pos=rpos,
        stripe_index=None, slot_mask=slot_mask)


def _inbatch_positions(batch_ids: jnp.ndarray, ids: jnp.ndarray,
                       mask: jnp.ndarray) -> jnp.ndarray:
    """node id -> in-batch position (-1 when absent/masked) via
    argsort+searchsorted over the b batch ids instead of ``plan_batch``'s
    O(n) node->slot scatter.  The sharded executor uses this because a
    transient [n] slot array would reintroduce the per-device O(n) memory
    the row sharding just removed.  For distinct batch ids the result is
    identical to the scatter; for duplicate ids (serve path) it picks one
    authoritative slot, which references the same feature row -- the
    downstream gathers are value-identical either way."""
    b = batch_ids.shape[0]
    order = jnp.argsort(batch_ids)
    sb = batch_ids[order]
    j = jnp.clip(jnp.searchsorted(sb, ids), 0, b - 1)
    hit = (sb[j] == ids) & (mask != 0)
    return jnp.where(hit, order[j], -1).astype(jnp.int32)


def plan_batch_sharded(plan: EpochPlan, batch_ids: jnp.ndarray,
                       axis_name: str,
                       slot_mask: Optional[jnp.ndarray] = None
                       ) -> MinibatchPack:
    """:func:`plan_batch` against a ROW-SHARDED EpochPlan, inside
    shard_map: ``plan``'s tables are each shard's contiguous
    [n_local, D] row block of the padded global tables, and the row
    gathers go cross-shard through
    :func:`repro.distributed.collectives.gather_from_shards`.  The id
    and mask tables are concatenated to [n_local, D+Dr] before the
    gather so one batch costs two cross-shard gathers (one int, one
    float) instead of four.  Positions come from
    :func:`_inbatch_positions` (no O(n) transient).  Value-identical to
    ``plan_batch`` on the unsharded plan for the same batch."""
    from repro.distributed.collectives import gather_from_shards

    d = plan.nbr_ids.shape[1]
    batch_ids = batch_ids.astype(jnp.int32)
    ids_tab = jnp.concatenate([plan.nbr_ids, plan.rev_ids], axis=1)
    mask_tab = jnp.concatenate([plan.nbr_mask, plan.rev_mask], axis=1)
    ids_rows = gather_from_shards(ids_tab, batch_ids, axis_name)
    mask_rows = gather_from_shards(mask_tab, batch_ids, axis_name)
    nbr, rev = ids_rows[:, :d], ids_rows[:, d:]
    nmask, rmask = mask_rows[:, :d], mask_rows[:, d:]
    npos = _inbatch_positions(batch_ids, nbr, nmask)
    rpos = _inbatch_positions(batch_ids, rev, rmask)
    return MinibatchPack(
        batch_ids=batch_ids, nbr_ids=nbr, nbr_mask=nmask, nbr_pos=npos,
        rev_ids=rev, rev_mask=rmask, rev_pos=rpos,
        stripe_index=None, slot_mask=slot_mask)


# ---------------------------------------------------------------------------
# sampler epoch plans (DESIGN.md section 12)
# ---------------------------------------------------------------------------

class SamplerEpochPlan(NamedTuple):
    """An epoch of pre-sampled induced subgraphs, stacked to static shape.

    Built once per epoch by :func:`pack_sampler_epoch` from a sampler's
    batch list; holds every batch's padded-ELL subgraph operands as
    [S, P, ...] device tables so ``models.gnn.sampler_train_epoch`` can run
    the whole epoch as ONE ``lax.scan`` -- the same pack-once/scan regime
    VQ training rides (section 9), applied to the sampling baselines so the
    Table 2/4 comparison is executor-vs-executor instead of
    executor-vs-host-loop.

    ``nbr_ids`` are LOCAL subgraph positions (the per-step scan body treats
    each [P, D] slice as a self-contained ``FullGraphOperands``); padding
    rows have empty neighbor lists, zero degree, ``node_ids`` 0 and
    ``loss_mask`` 0, so they feed nothing into real rows and contribute
    nothing to the masked loss.
    """
    node_ids: jnp.ndarray    # [S, P]    global node ids (0 on padding rows)
    nbr_ids: jnp.ndarray     # [S, P, D] in-neighbor LOCAL positions
    nbr_mask: jnp.ndarray    # [S, P, D] 1.0 on real in-edges
    degrees: jnp.ndarray     # [S, P]    in-degree within the subgraph
    loss_mask: jnp.ndarray   # [S, P]    seed weight (0 on padding/non-seed)

    @property
    def s(self) -> int:
        return self.node_ids.shape[0]

    @property
    def p(self) -> int:
        return self.node_ids.shape[1]


def pack_sampler_epoch(batches: list[tuple], deg_cap: int,
                       n_pad: Optional[int] = None) -> SamplerEpochPlan:
    """Stack one epoch of sampler 5-tuples into a :class:`SamplerEpochPlan`.

    batches: list of ``(src, dst, nodes, seed_pos, seed_weight)`` (the
    ``repro.graph.sampling`` contract).  All subgraphs are padded to one
    shared width -- ``n_pad`` or the power-of-two bucket of the epoch's
    largest subgraph (:func:`pad_bucket`, so the bucket rarely moves across
    epochs and the scanned executable is reused) -- and neighbor lists to
    ``deg_cap`` (within-subgraph degree is bounded by the graph's, so the
    global cap is always safe).
    """
    from repro.graph.structure import csr_from_coo
    if not batches:
        raise ValueError("pack_sampler_epoch needs at least one batch")
    sizes = [len(nodes) for _, _, nodes, _, _ in batches]
    p = n_pad if n_pad is not None else pad_bucket(max(sizes))
    if max(sizes) > p:
        raise ValueError(f"subgraph of {max(sizes)} nodes exceeds "
                         f"n_pad={p}")
    s = len(batches)
    node_ids = np.zeros((s, p), np.int64)
    nbr = np.zeros((s, p, deg_cap), np.int32)
    mask = np.zeros((s, p, deg_cap), np.float32)
    degs = np.zeros((s, p), np.float32)
    loss = np.zeros((s, p), np.float32)
    for i, (src, dst, nodes, seed_pos, seed_w) in enumerate(batches):
        csr = csr_from_coo(np.asarray(src, np.int64),
                           np.asarray(dst, np.int64), p)
        nbr[i], mask[i], _ = _pack_rows(csr, np.arange(p), deg_cap)
        degs[i] = csr.degrees()
        node_ids[i, :len(nodes)] = nodes
        loss[i, np.asarray(seed_pos)] = np.asarray(seed_w, np.float32)
    return SamplerEpochPlan(
        node_ids=jnp.asarray(node_ids), nbr_ids=jnp.asarray(nbr),
        nbr_mask=jnp.asarray(mask), degrees=jnp.asarray(degs),
        loss_mask=jnp.asarray(loss))
