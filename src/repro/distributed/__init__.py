"""repro subpackage."""
