"""Weight-only int8 quantization for serving (beyond-paper lever).

Decode is memory-bound on the weight stream (EXPERIMENTS.md deep-dive 3);
per-output-channel int8 storage halves the bytes/step vs bf16.  On TPU the
int8->bf16 convert fuses into the MXU feed; numerically the per-channel
scale keeps matmul outputs within ~0.5% of bf16 (test_quantization.py).

Applied at the params-pytree level: every >=2D weight leaf becomes
(int8 values, f32 per-channel scales); 1D scales/norms stay bf16.
``dequantize_tree`` restores a dense pytree for the unmodified model code
-- under jit, XLA keeps the int8 buffers as the stored representation and
materializes bf16 tiles on the fly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array        # int8, same shape as the original
    scale: jax.Array    # f32 [..., 1, out] per-output-channel scales


def quantize_tensor(w: jax.Array) -> QTensor:
    """Per-output-channel (last axis) symmetric int8."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)),
                   keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def dequantize_tensor(t: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def _is_weight(leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
        leaf.dtype in (jnp.float32, jnp.bfloat16)


def quantize_tree(params: Any) -> Any:
    """int8-quantize every >=2D float leaf of a params pytree."""
    return jax.tree_util.tree_map(
        lambda w: quantize_tensor(w) if _is_weight(w) else w, params)


def dequantize_tree(qparams: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree_util.tree_map(
        lambda t: dequantize_tensor(t, dtype) if isinstance(t, QTensor)
        else t, qparams, is_leaf=lambda x: isinstance(x, QTensor))


def tree_bytes(params: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))
