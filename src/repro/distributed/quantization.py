"""Weight-only int8 quantization for serving (beyond-paper lever).

Decode is memory-bound on the weight stream (EXPERIMENTS.md deep-dive 3);
per-output-channel int8 storage halves the bytes/step vs bf16.  On TPU the
int8->bf16 convert fuses into the MXU feed; numerically the per-channel
scale keeps matmul outputs within ~0.5% of bf16 (test_quantization.py).

Applied at the params-pytree level: every >=2D weight leaf becomes
(int8 values, f32 per-channel scales); 1D scales/norms stay bf16.
``dequantize_tree`` restores a dense pytree for the unmodified model code
-- under jit, XLA keeps the int8 buffers as the stored representation and
materializes bf16 tiles on the fly.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array        # int8, same shape as the original
    scale: jax.Array    # f32 [..., 1, out] per-output-channel scales


def quantize_tensor(w: jax.Array) -> QTensor:
    """Per-output-channel (last axis) symmetric int8."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)),
                   keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def dequantize_tensor(t: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


# Drift band of the codeword-table requantization (kernels int8 path,
# DESIGN.md section 13): the previous step's scale is reused while the new
# amax stays within [prev_amax / drift, prev_amax], so the quantization grid
# only moves when the codebook actually drifts -- stable grids keep the
# serving-side int8 tables byte-identical across EMA steps that barely move.
CODEWORD_SCALE_DRIFT = 1.25


def quantize_codewords(cw: jax.Array,
                       prev: "QTensor | None" = None,
                       drift: float = CODEWORD_SCALE_DRIFT) -> QTensor:
    """Per-branch/per-channel symmetric int8 for codeword tables.

    cw: [n_branches, k, f_blk] -> QTensor(q int8 [nb, k, f_blk],
    scale f32 [nb, 1, f_blk]): the amax reduces over the k codewords only,
    so every (branch, channel) pair keeps its own scale -- the layout the
    int8 context/SpMM kernels consume as a flat [1, nb * f_blk] epilogue
    row (scales are k-independent, so the dequant multiply commutes with
    the over-neighbors accumulate and runs once per output tile).

    ``prev`` enables the drift-aware rescale (quantize-on-update): the
    previous scale is kept wherever the new amax still fits its range and
    has not shrunk below ``1/drift`` of it.  jit-friendly (``jnp.where``).
    """
    cw32 = cw.astype(jnp.float32)
    amax = jnp.max(jnp.abs(cw32), axis=-2, keepdims=True)   # [nb, 1, f_blk]
    scale = amax / 127.0 + 1e-12
    if prev is not None:
        prev_amax = (prev.scale - 1e-12) * 127.0
        keep = jnp.logical_and(amax <= prev_amax,
                               amax >= prev_amax / drift)
        scale = jnp.where(keep, prev.scale, scale)
    q = jnp.clip(jnp.round(cw32 / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def _is_weight(leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
        leaf.dtype in (jnp.float32, jnp.bfloat16)


def quantize_tree(params: Any) -> Any:
    """int8-quantize every >=2D float leaf of a params pytree."""
    return jax.tree_util.tree_map(
        lambda w: quantize_tensor(w) if _is_weight(w) else w, params)


def dequantize_tree(qparams: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree_util.tree_map(
        lambda t: dequantize_tensor(t, dtype) if isinstance(t, QTensor)
        else t, qparams, is_leaf=lambda x: isinstance(x, QTensor))


def tree_bytes(params: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))
