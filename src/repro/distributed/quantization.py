"""Weight-only int8 quantization for serving (beyond-paper lever).

Decode is memory-bound on the weight stream (EXPERIMENTS.md deep-dive 3);
per-output-channel int8 storage halves the bytes/step vs bf16.  On TPU the
int8->bf16 convert fuses into the MXU feed; numerically the per-channel
scale keeps matmul outputs within ~0.5% of bf16 (test_quantization.py).

Applied at the params-pytree level: every >=2D weight leaf becomes
(int8 values, f32 per-channel scales); 1D scales/norms stay bf16.
``dequantize_tree`` restores a dense pytree for the unmodified model code
-- under jit, XLA keeps the int8 buffers as the stored representation and
materializes bf16 tiles on the fly.

This module also owns the VQ operand tiers (DESIGN.md sections 13/15):
``quantize_codewords`` (int8 and float8_e4m3fn codeword snapshots with
per-branch/per-channel f32 scales + the drift band) and the nibble-packed
assignment machinery (``pack_nibbles`` / ``unpack_nibbles`` /
``PackedAssignment``) behind the ``+a4`` tiers for k <= 16 product
branches, plus ``dtype_nbits`` -- the one sub-byte-aware size table shared
by the HLO dump parser and the state-bytes accounting.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jax.Array        # int8/fp8, same shape as the original
    scale: jax.Array    # f32 [..., 1, out] per-output-channel scales


# HLO short dtype names (as printed in compiled-module shapes) -> bit widths.
# Shared with launch/dryrun.py, which parses HLO buffer-assignment dumps.
_HLO_NBITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "s32": 32, "u32": 32, "s64": 64, "u64": 64,
    "f8e4m3fn": 8, "f8e5m2": 8, "bf16": 16, "f16": 16, "f32": 32, "f64": 64,
    "c64": 64, "c128": 128,
}

# numpy reports itemsize=1 for the ml_dtypes sub-byte ints (one id per host
# byte); on device they pack two per byte, and the size accounting here is
# about device residency.
_SUB_BYTE_NBITS = {"int4": 4, "uint4": 4}


def dtype_nbits(dt) -> int:
    """Bits per element of a dtype, sub-byte aware.

    Accepts anything ``jnp.dtype`` does (jnp/np dtypes, instances, names)
    plus the HLO short names ("f8e4m3fn", "s32", ...) that appear in
    compiled-module dumps.  Raises KeyError/TypeError on unknown inputs so
    callers that scan heterogeneous dumps can skip unparseable entries.
    """
    if isinstance(dt, str) and dt in _HLO_NBITS:
        return _HLO_NBITS[dt]
    d = jnp.dtype(dt)
    return _SUB_BYTE_NBITS.get(d.name, d.itemsize * 8)


def quantize_tensor(w: jax.Array, dtype=jnp.int8) -> QTensor:
    """Per-output-channel (last axis) symmetric int8 or fp8.

    ``dtype`` picks the storage grid (:func:`codeword_qmax`): int8 rounds
    to the integer lattice, float8_e4m3fn keeps the mantissa rounding of
    the hardware cast -- both dequantize as ``q * scale``."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)),
                   keepdims=True)
    qmax = codeword_qmax(dtype)
    scale = amax / qmax + 1e-12
    scaled = w32 / scale
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(scaled, -qmax, qmax).astype(dtype)
    return QTensor(q, scale)


def dequantize_tensor(t: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


# Drift band of the codeword-table requantization (kernels int8 path,
# DESIGN.md section 13): the previous step's scale is reused while the new
# amax stays within [prev_amax / drift, prev_amax], so the quantization grid
# only moves when the codebook actually drifts -- stable grids keep the
# serving-side int8 tables byte-identical across EMA steps that barely move.
CODEWORD_SCALE_DRIFT = 1.25

# Largest representable magnitude per codeword storage dtype: the quantizer
# maps each (branch, channel) amax onto it, so scale = amax / qmax.
_CODEWORD_QMAX = {
    jnp.dtype(jnp.int8): 127.0,
    jnp.dtype(jnp.float8_e4m3fn): 448.0,
}


def codeword_qmax(dtype) -> float:
    """amax -> grid-top mapping for a codeword storage dtype."""
    d = jnp.dtype(dtype)
    if d not in _CODEWORD_QMAX:
        raise ValueError(
            f"unsupported codeword storage dtype {d.name!r}; want one of "
            f"{sorted(x.name for x in _CODEWORD_QMAX)}")
    return _CODEWORD_QMAX[d]


def quantize_codewords(cw: jax.Array,
                       prev: "QTensor | None" = None,
                       drift: float = CODEWORD_SCALE_DRIFT,
                       dtype=jnp.int8) -> QTensor:
    """Per-branch/per-channel symmetric int8 or fp8 for codeword tables.

    cw: [n_branches, k, f_blk] -> QTensor(q int8/fp8 [nb, k, f_blk],
    scale f32 [nb, 1, f_blk]): the amax reduces over the k codewords only,
    so every (branch, channel) pair keeps its own scale -- the layout the
    quantized context/SpMM kernels consume as a flat [1, nb * f_blk]
    epilogue row (scales are k-independent, so the dequant multiply
    commutes with the over-neighbors accumulate and runs once per output
    tile).

    ``dtype`` picks the storage grid: ``jnp.int8`` (uniform, amax/127
    steps) or ``jnp.float8_e4m3fn`` (amax scaled onto +-448, keeping fp8's
    3-mantissa-bit relative precision across the whole per-channel dynamic
    range -- the tier for codebooks whose channels span decades).  When
    ``prev`` is given its storage dtype wins, so quantize-on-update
    requantizes in whatever tier the serving state was built with.

    ``prev`` enables the drift-aware rescale (quantize-on-update): the
    previous scale is kept wherever the new amax still fits its range and
    has not shrunk below ``1/drift`` of it.  jit-friendly (``jnp.where``).
    """
    if prev is not None:
        dtype = prev.q.dtype
    qmax = codeword_qmax(dtype)
    cw32 = cw.astype(jnp.float32)
    amax = jnp.max(jnp.abs(cw32), axis=-2, keepdims=True)   # [nb, 1, f_blk]
    scale = amax / qmax + 1e-12
    if prev is not None:
        prev_amax = (prev.scale - 1e-12) * qmax
        keep = jnp.logical_and(amax <= prev_amax,
                               amax >= prev_amax / drift)
        scale = jnp.where(keep, prev.scale, scale)
    scaled = cw32 / scale
    if jnp.dtype(dtype) == jnp.int8:
        q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    else:
        # fp8: round-to-nearest happens in the cast; clip keeps drift-band
        # outliers (amax marginally above the reused grid top) finite.
        q = jnp.clip(scaled, -qmax, qmax).astype(dtype)
    return QTensor(q, scale)


# ---------------------------------------------------------------------------
# nibble-packed assignment tables (the +a4 tiers, k <= 16)
# ---------------------------------------------------------------------------


def pack_nibbles(ids: jax.Array) -> jax.Array:
    """Pack ids (< 16) along the last axis, two per byte -> uint8.

    [..., m] -> [..., ceil(m / 2)]; even index -> low nibble, odd index ->
    high nibble; an odd-length tail pads the final high nibble with 0.
    """
    m = ids.shape[-1]
    u = ids.astype(jnp.uint8)
    if m % 2:
        pad = [(0, 0)] * (u.ndim - 1) + [(0, 1)]
        u = jnp.pad(u, pad)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of ``pack_nibbles``: [..., ceil(n/2)] uint8 -> [..., n] uint8."""
    lo = packed & 0xF
    hi = packed >> 4
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return out[..., :n].astype(jnp.uint8)


def gather_nibbles(packed: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather ids' nibbles from a last-axis-packed table.

    packed [..., ceil(n/2)] uint8, ids [...] int -> uint8 values with shape
    packed.shape[:-1] + ids.shape (the same broadcast a plain
    ``table[..., ids]`` gather would produce on the unpacked table).
    """
    idx = ids.astype(jnp.int32)
    byte = packed[..., idx >> 1].astype(jnp.int32)
    return ((byte >> ((idx & 1) * 4)) & 0xF).astype(jnp.uint8)


def scatter_nibbles(packed: jax.Array, ids: jax.Array,
                    vals: jax.Array) -> jax.Array:
    """Scatter vals (< 16) into a last-axis-packed table at node ids.

    packed [..., nbytes] uint8, ids [m] int (DISTINCT -- duplicate ids
    would race within a parity pass), vals [..., m] uint8.  Two passes,
    one per parity: within a pass every touched byte index is unique, so a
    read-modify-write of the byte (keep the sibling nibble, replace ours)
    is exact; entries of the other parity scatter to an out-of-range byte
    index and drop.
    """
    nbytes = packed.shape[-1]
    idx = ids.astype(jnp.int32)
    byte_ids = idx >> 1
    v = (vals & 0xF).astype(jnp.uint8)
    for parity in (0, 1):
        cur = packed[..., byte_ids]            # re-gather: sees pass 0's writes
        if parity == 0:
            newb = (cur & 0xF0) | v
        else:
            newb = (cur & 0x0F) | (v << 4)
        dst = jnp.where((idx & 1) == parity, byte_ids, nbytes)
        packed = packed.at[..., dst].set(newb, mode="drop")
    return packed


@jax.tree_util.register_pytree_node_class
class PackedAssignment:
    """Nibble-packed [n_branches, n] VQ assignment table (k <= 16).

    ``packed`` holds two node ids per byte along the node axis
    ([n_branches, ceil(n/2)] uint8) -- 0.5 bytes/entry, 8x smaller than
    the int32 table and half the uint8 one, which is what doubles the
    fused-dispatch VMEM crossover again (DESIGN.md section 15).  The node
    count ``n`` is static aux data (the pytree idiom of
    ``spmm_ell_hbm.StripeIndex``), so the wrapper flows through jit /
    scan / shard_map like any array leaf.
    """

    def __init__(self, packed: jax.Array, n: int):
        self.packed = packed
        self.n = int(n)

    @classmethod
    def pack(cls, assignment: jax.Array) -> "PackedAssignment":
        return cls(pack_nibbles(assignment), assignment.shape[-1])

    @property
    def shape(self) -> tuple:
        return (*self.packed.shape[:-1], self.n)

    def unpack(self) -> jax.Array:
        return unpack_nibbles(self.packed, self.n)

    def gather(self, ids: jax.Array) -> jax.Array:
        return gather_nibbles(self.packed, ids)

    def scatter(self, ids: jax.Array, vals: jax.Array) -> "PackedAssignment":
        return PackedAssignment(scatter_nibbles(self.packed, ids, vals),
                                self.n)

    def tree_flatten(self):
        return (self.packed,), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)
        obj.packed = children[0]
        obj.n = aux[0]
        return obj

    def __repr__(self):
        return f"PackedAssignment(shape={self.shape}, packed={self.packed!r})"


def _is_weight(leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
        leaf.dtype in (jnp.float32, jnp.bfloat16)


def quantize_tree(params: Any) -> Any:
    """int8-quantize every >=2D float leaf of a params pytree."""
    return jax.tree_util.tree_map(
        lambda w: quantize_tensor(w) if _is_weight(w) else w, params)


def dequantize_tree(qparams: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree_util.tree_map(
        lambda t: dequantize_tensor(t, dtype) if isinstance(t, QTensor)
        else t, qparams, is_leaf=lambda x: isinstance(x, QTensor))


def tree_bytes(params: Any) -> int:
    """Device-resident bytes of a pytree, sub-byte dtypes counted exactly.

    ``PackedAssignment`` leaves are already their packed uint8 buffer;
    ml_dtypes int4 arrays (one id per host byte) count 4 bits/element.
    """
    return sum((x.size * dtype_nbits(x.dtype) + 7) // 8
               for x in jax.tree_util.tree_leaves(params))
