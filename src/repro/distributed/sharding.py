"""Per-architecture sharding strategies (DESIGN.md section 5).

Strategies (chosen by `strategy_for(cfg)` from head/ff divisibility):
  tp_fsdp     -- Megatron tensor parallelism on the `model` axis (q heads /
                 d_ff / vocab / experts) + FSDP/ZeRO-3 of params & optimizer
                 states over the data axes ("pod","data").  Named-rule based:
                 column-parallel wq/wk/wv/w1/w3, row-parallel wo/w2 (so the
                 pair needs one psum, not a resharding all-gather).
  fsdp        -- no TP (head counts indivisible by 16): params sharded over
                 the flattened mesh on their largest divisible dim; sequence
                 parallelism on `model` for activations.
  replicate   -- tiny models (whisper-tiny): pure DP, weights replicated.

All rules check divisibility against the actual mesh -- a dim that does not
divide stays unsharded (never crashes the compile).  Everything is written
against axis NAMES so single-pod (data,model) and multi-pod
(pod,data,model) bind the same rules.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import axes_size, dp_axes

# stacked-layer containers: leading dims are scan axes, never sharded
_STACKED1 = ("blocks", "pairs", "enc_blocks", "cross_blocks")
_STACKED2 = ("mamba",)


def strategy_for(cfg: ArchConfig, mesh: Mesh) -> str:
    tp = mesh.shape["model"]
    if cfg.param_count() < 200e6:
        return "replicate"
    if cfg.family == "moe" and cfg.n_experts % tp == 0:
        # Perf iteration 2 (EXPERIMENTS.md): Megatron-TP on a d_model=2048
        # attention is collective-bound; experts-on-model + DP attention
        # cuts per-layer all-reduces 4x -> 1x
        return "moe_ep_dp"
    if cfg.n_heads % tp == 0 and (cfg.d_ff == 0 or cfg.d_ff % tp == 0):
        return "tp_fsdp"
    return "fsdp"


def _divides(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _spec_for_leaf(pathstr: str, shape: tuple[int, ...], strategy: str,
                   mesh: Mesh, cfg: ArchConfig) -> P:
    dp = dp_axes(mesh)
    dp_n = axes_size(mesh, dp)
    tp_n = mesh.shape["model"]
    all_ax = dp + ("model",)
    all_n = dp_n * tp_n

    # number of leading stacked dims to skip
    skip = 0
    if any(f"['{k}']" in pathstr for k in _STACKED1):
        skip = 1
    if any(f"['{k}']" in pathstr for k in _STACKED2):
        skip = 2
    dims = list(shape[skip:])
    lead = [None] * skip

    def out(spec_tail):
        return P(*lead, *spec_tail)

    if len(dims) == 0:
        return out([])

    if strategy == "replicate":
        return out([None] * len(dims))

    # vocab-parallel embedding/head in EVERY sharded strategy (the CE loss
    # is matmul-only so the vocab axis never needs gathering; Perf iter. 1).
    # The d_model axis stays UNSHARDED: putting dp on it makes the lookup
    # output d@dp, which conflicts with batch@dp activations and GSPMD
    # resolves by replicating the batch (+20 GiB/chip on the 405B cell --
    # Perf iteration 5b).
    if "['embed']" in pathstr and len(dims) == 2:
        spec = [None, None]
        if _divides(dims[0], tp_n):
            spec[0] = "model"
        elif _divides(dims[0], dp_n):
            spec[0] = dp          # odd vocabs: shard vocab over dp instead
        return out(spec)
    if "['head']" in pathstr and len(dims) == 2:
        spec = [None, None]
        if _divides(dims[1], tp_n):
            spec[1] = "model"
        elif _divides(dims[1], dp_n):
            spec[1] = dp
        return out(spec)

    if strategy == "moe_ep_dp":
        # experts over `model` (EP); everything else ZeRO-sharded over dp,
        # replicated over `model` (attention runs pure-DP)
        spec = [None] * len(dims)
        if (".w1" in pathstr or ".w3" in pathstr or ".w2" in pathstr) \
                and len(dims) == 3:
            if _divides(dims[0], tp_n):
                spec[0] = "model"
            rest = 1 if ".w2" not in pathstr else 2
            if _divides(dims[rest], dp_n):
                spec[rest] = dp
            return out(spec)
        if ".router" in pathstr and len(dims) == 2:
            if _divides(dims[1], tp_n):
                spec[1] = "model"
            return out(spec)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if _divides(dims[i], dp_n):
                spec[i] = dp
                break
        return out(spec)

    if strategy == "fsdp":
        # shard the largest dim divisible by the whole mesh; else by dp
        spec = [None] * len(dims)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if _divides(dims[i], all_n):
                spec[i] = all_ax
                return out(spec)
        for i in order:
            if _divides(dims[i], dp_n):
                spec[i] = dp
                return out(spec)
        return out(spec)

    # ----- tp_fsdp: named Megatron rules + generic fallback -----
    def col(d_in_idx: int, d_out_idx: int):
        """column-parallel: out dim over model, in dim over dp (ZeRO-3)."""
        spec = [None] * len(dims)
        if _divides(dims[d_out_idx], tp_n):
            spec[d_out_idx] = "model"
        if _divides(dims[d_in_idx], dp_n):
            spec[d_in_idx] = dp
        return out(spec)

    def row(d_in_idx: int, d_out_idx: int):
        """row-parallel: in dim over model, out dim over dp."""
        spec = [None] * len(dims)
        if _divides(dims[d_in_idx], tp_n):
            spec[d_in_idx] = "model"
        if _divides(dims[d_out_idx], dp_n):
            spec[d_out_idx] = dp
        return out(spec)

    if ".wq" in pathstr or ".wv" in pathstr or ".wk" in pathstr:
        if "cross" in pathstr or len(dims) == 2:
            return col(0, 1)
    if ".wo" in pathstr and len(dims) == 2:
        return row(0, 1)
    if ".w1" in pathstr or ".w3" in pathstr:
        if len(dims) == 2:
            return col(0, 1)
        if len(dims) == 3:     # MoE experts [E, d, eff]: EP over model
            spec = [None, None, None]
            if _divides(dims[0], tp_n):
                spec[0] = "model"
            if _divides(dims[1], dp_n):
                spec[1] = dp
            return out(spec)
    if ".w2" in pathstr:
        if len(dims) == 2:
            return row(0, 1)
        if len(dims) == 3:     # [E, eff, d]
            spec = [None, None, None]
            if _divides(dims[0], tp_n):
                spec[0] = "model"
            if _divides(dims[2], dp_n):
                spec[2] = dp
            return out(spec)
    if ".router" in pathstr and len(dims) == 2:
        return col(0, 1)
    if "['embed']" in pathstr:
        spec = [None, None]
        if _divides(dims[0], tp_n):
            spec[0] = "model"        # vocab-parallel embedding
        if _divides(dims[1], dp_n):
            spec[1] = dp
        return out(spec)
    if "['head']" in pathstr:
        return col(0, 1)

    # generic fallback (mamba in_proj/out_proj, xlstm projections, ...):
    # last dim over model, largest other dim over dp
    spec = [None] * len(dims)
    if len(dims) >= 2:
        if _divides(dims[-1], tp_n):
            spec[-1] = "model"
        rest = sorted(range(len(dims) - 1), key=lambda i: -dims[i])
        for i in rest:
            if _divides(dims[i], dp_n):
                spec[i] = dp
                break
    return out(spec)


def param_shardings(params: Any, cfg: ArchConfig, mesh: Mesh,
                    strategy: str | None = None) -> Any:
    strategy = strategy or strategy_for(cfg, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pathstr = "".join(str(p) for p in path)
        specs.append(NamedSharding(mesh, _spec_for_leaf(
            pathstr, tuple(leaf.shape), strategy, mesh, cfg)))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# activations / batch / cache shardings
# ---------------------------------------------------------------------------

def token_sharding(batch: int, mesh: Mesh, cfg: ArchConfig,
                   strategy: str = "tp_fsdp") -> NamedSharding:
    """Batch goes over the data axes; when the strategy does not use the
    `model` axis for tensor parallelism (replicate/fsdp), the batch spreads
    over it too (model axis would otherwise idle)."""
    dp = dp_axes(mesh)
    if strategy in ("replicate", "fsdp"):
        allax = dp + ("model",)
        if _divides(batch, axes_size(mesh, allax)):
            return NamedSharding(mesh, P(allax, None))
    b_spec = dp if _divides(batch, axes_size(mesh, dp)) else None
    return NamedSharding(mesh, P(b_spec, None))


def _seq_axes_for(seq: int, batch: int, mesh: Mesh):
    """For decode caches: shard sequence over as much mesh as the batch
    leaves unused (long_500k batch=1 -> sequence over the whole mesh)."""
    dp = dp_axes(mesh)
    if _divides(batch, axes_size(mesh, dp)):
        return dp, ("model",) if _divides(seq, mesh.shape["model"]) else None
    # batch unshardable: put everything on the sequence
    allax = dp + ("model",)
    if _divides(seq, axes_size(mesh, allax)):
        return None, allax
    return None, ("model",) if _divides(seq, mesh.shape["model"]) else None


def cache_shardings(cache: Any, cfg: ArchConfig, mesh: Mesh, batch: int,
                    seq_len: int) -> Any:
    """Shardings for the serve-step cache pytree (built by eval_shape)."""
    dp = dp_axes(mesh)
    b_ax, s_ax = _seq_axes_for(seq_len, batch, mesh)

    def spec(path, leaf) -> NamedSharding:
        pathstr = "".join(str(p) for p in path)
        shape = leaf.shape
        pspec: list = [None] * len(shape)
        # identify batch dim: first dim of size `batch` after the layer dim
        for i, d in enumerate(shape):
            if i == 0:
                continue           # stacked layer dim
            if d == batch and b_ax is not None:
                pspec[i] = b_ax
                break
        if (pathstr.endswith(".k") or pathstr.endswith(".v")
                or "win_" in pathstr or "cross_" in pathstr
                or "sum_" in pathstr):
            # KV-like tensors: shard their sequence/window/codebook dim
            for i, d in enumerate(shape):
                if i == 0 or pspec[i] is not None:
                    continue
                if d in (seq_len, cfg.vq_k, cfg.n_patches, cfg.enc_seq) \
                        and s_ax is not None and _divides(
                            d, axes_size(mesh, s_ax)):
                    pspec[i] = s_ax
                    break
        return NamedSharding(mesh, P(*pspec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# GNN epoch-executor data parallelism (DESIGN.md section 9)
# ---------------------------------------------------------------------------

def graph_dp_mesh(n_devices: int | None = None) -> Mesh:
    """1-axis "data" mesh for the VQ epoch executor's shard_map data
    parallelism (params/codebooks replicated, batch axis sharded) and for
    the row-sharded graph state (node tables split over the same axis --
    :func:`shard_rows_spec`).  Raises when fewer devices exist than
    requested -- an explicit parallelism/capacity ask must never silently
    under-provision."""
    devs = jax.devices()
    if n_devices is not None:
        if len(devs) < n_devices:
            raise ValueError(
                f"requested a {n_devices}-device data mesh but only "
                f"{len(devs)} device(s) exist -- each mesh device owns a "
                f"1/{n_devices} contiguous row block of the sharded graph "
                f"state (node tables padded to a multiple of {n_devices} "
                f"rows, shard_padded_rows); on CPU hosts add "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{n_devices} for virtual devices")
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), ("data",))


def epoch_batch_spec() -> P:
    """PartitionSpec of the stacked [S, b] epoch arrays (perm / slot mask):
    scan axis replicated, batch axis split over "data"."""
    return P(None, "data")


def serve_batch_spec() -> P:
    """PartitionSpec of a serving request micro-batch [b] of node ids
    (``launch/serve_gnn.py``): the single batch axis split over "data" --
    :func:`epoch_batch_spec` minus the scan axis.  Placing the ids with
    this spec lets jit's SPMD partitioner split the O(b) serve step
    (gathers + codeword forward) across the mesh while the plan/codebook
    tables stay replicated."""
    return P("data")


# ---------------------------------------------------------------------------
# Row-sharded graph state (DESIGN.md section 14)
# ---------------------------------------------------------------------------
#
# Every node-indexed table (EpochPlan neighbor structures, node features,
# the [n+1, f] inference activation table) is split by node id into
# contiguous row blocks over the "data" mesh axis, so per-device graph
# state drops ~1/ndev and mesh size becomes a *capacity* knob.  The tiny
# [k, f] codebooks, their counts/sums/revival state, the [nb, n]
# assignment tables, and the [n] degree vector stay replicated: the
# context kernel and `out_of_batch_cluster_mass` need global random
# access to assignments, and degrees cost 4 bytes/node -- sharding them
# would trade O(1) lookups for collectives with no memory story.

def shard_padded_rows(n: int, ndev: int) -> int:
    """Padded global row count for an ``n``-row node table sharded over
    ``ndev`` devices.  One extra *sacrificial* row (global id ``n``)
    absorbs the wrap-pad / masked-slot writes of the inference scatter,
    then the total is rounded up so every shard owns an equal contiguous
    block.  Pad rows land on the last shard by construction ("wrap-pad
    rows pinned to the owning shard")."""
    if ndev <= 0:
        raise ValueError(f"ndev must be positive, got {ndev}")
    return -(-(n + 1) // ndev) * ndev


def shard_rows_spec(ndim: int = 1) -> P:
    """PartitionSpec splitting a node table's leading (row) axis over the
    "data" mesh axis; remaining axes replicated."""
    return P(*(("data",) + (None,) * (ndim - 1)))


def scan_shard_spec(ndim: int = 2) -> P:
    """PartitionSpec splitting the *scan* axis of the stacked [S, b]
    epoch/inference arrays over "data": each shard runs S/ndev full
    batches, which keeps every batch's in-batch positions exact (the
    sharded inference executor's parity-by-construction trick) while the
    per-layer compute still splits ndev ways."""
    return P(*(("data",) + (None,) * (ndim - 1)))


def node_to_shard(gid, n_local: int):
    """Owning shard of global node id(s) under contiguous-block
    ownership: shard ``s`` owns rows ``[s*n_local, (s+1)*n_local)``."""
    return gid // n_local


def global_to_local(gid, shard, n_local: int):
    """Local row of global id(s) on ``shard`` (meaningful only when
    ``node_to_shard(gid, n_local) == shard``)."""
    return gid - shard * n_local


def local_to_global(lid, shard, n_local: int):
    """Global node id of local row(s) ``lid`` on ``shard``."""
    return lid + shard * n_local


def pad_rows(x, n_pad: int, fill=0):
    """Pad a node table's leading axis to ``n_pad`` rows with ``fill``
    (numpy or jax input; returns the same kind)."""
    n = x.shape[0]
    if n > n_pad:
        raise ValueError(f"table has {n} rows > padded target {n_pad}")
    if n == n_pad:
        return x
    xp = jax.numpy if isinstance(x, jax.Array) else np
    pad = xp.full((n_pad - n,) + tuple(x.shape[1:]), fill, dtype=x.dtype)
    return xp.concatenate([x, pad], axis=0)


def shard_rows(x, mesh: Mesh, n_pad: int | None = None, fill=0):
    """Place a node table on ``mesh`` with its rows split over "data",
    padding to ``n_pad`` (default :func:`shard_padded_rows`) first."""
    ndev = mesh.shape["data"]
    if n_pad is None:
        n_pad = shard_padded_rows(x.shape[0] - 1, ndev) \
            if x.shape[0] % ndev else x.shape[0]
    x = pad_rows(x, n_pad, fill)
    return jax.device_put(
        x, NamedSharding(mesh, shard_rows_spec(x.ndim)))


def per_device_bytes(tree) -> int:
    """Peak per-device bytes of a pytree of placed arrays: max over
    devices of the sum of addressable shard sizes.  This is the honest
    capacity metric for the sharded-vs-replicated bench rows -- a
    replicated table counts fully on every device, a row-sharded one
    ~1/ndev."""
    per_dev: dict[Any, int] = {}
    for leaf in jax.tree_util.tree_leaves(tree):
        if not isinstance(leaf, jax.Array):
            continue
        for s in leaf.addressable_shards:
            per_dev[s.device] = per_dev.get(s.device, 0) + s.data.nbytes
    return max(per_dev.values(), default=0)
