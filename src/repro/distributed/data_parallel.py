"""shard_map data parallelism for the VQ epoch executor (DESIGN.md sec. 9).

Shards the BATCH axis of the stacked epoch arrays over the 1-axis "data"
mesh: each device runs the full ``lax.scan`` over the S steps on its b/ndev
rows of every batch, treating its rows as a VQ mini-batch of their own
(cross-device in-batch neighbors ride the codeword context, exactly the
paper's out-of-batch approximation).  The per-replica body IS
``models.gnn._vq_epoch_body`` -- the same implementation the single-device
executor jits -- with ``axis_name="data"``, which turns on three
collectives per step:

  * param grads          -- ``collectives.psum_tree`` (uncompressed; exact),
  * codebook statistics  -- the fused ``vq_assign_update`` (counts, sums)
    and the whitening batch moments, psum'd INSIDE ``codebook.update`` via
    its ``axis_name`` hook, so every replica computes the same EMA step as
    a single device seeing the pooled batch;
  * assignment sync      -- each device's refreshed rows are all-gathered
    and scattered into the (replicated) global assignment table, so tables
    never diverge.

The ndev=1 instantiation is numerically identical to
``models.gnn.vq_train_epoch``; the multi-device run is identical to the
same body under ``jax.vmap(axis_name=...)`` over the sub-batch axis (the
parity oracles in tests/test_epoch_executor.py).

Eq. 7 backward under DP: the injection's residuals are *lazy*
(``core/message_passing.py`` / DESIGN.md section 10) -- each replica's
scan carry holds only its [b/ndev, Dr] reverse-edge operands plus the
replicated O(k * f) codebook and assignment tables it keeps anyway, and
the backward streams the phantom term through the fused
``kops.context_ell`` dispatch per replica with no collective (the
codeword tables are replica-identical by the psum rule above).  Nothing
per-replica scales as [b/ndev, Dr, f_grad].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.sharding import epoch_batch_spec, graph_dp_mesh, \
    scan_shard_spec, shard_padded_rows, shard_rows, shard_rows_spec
from repro.graph.batching import EpochPlan
from repro.models.gnn import GNNConfig, _vq_epoch_body, \
    _vq_infer_layer_sharded, _vq_serve_body_sharded
from repro.train.optimizer import Optimizer

__all__ = ["graph_dp_mesh", "vq_train_epoch_dp", "ShardedGraphState",
           "vq_train_epoch_sharded", "vq_infer_epoch_sharded",
           "vq_serve_batch_sharded"]


@functools.partial(jax.jit, static_argnames=("mesh", "cfg", "opt"),
                   donate_argnums=(0, 1, 2))
def _dp_epoch_jit(params, vq_states, opt_state, plan, perm, slot_mask,
                  x, labels, train_mask, degrees, *, mesh: Mesh,
                  cfg: GNNConfig, opt: Optimizer):
    # the shard_map wrapper is rebuilt per trace (cheap); caching lives in
    # jit's executable cache keyed on the static (mesh, cfg, opt) -- the
    # same convention as vq_train_step's static opt, and no extra
    # permanently-retained closure cache
    body = functools.partial(_vq_epoch_body, cfg=cfg, opt=opt,
                             axis_name="data")
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), epoch_batch_spec(),
                  epoch_batch_spec(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_rep=False)
    return sharded(params, vq_states, opt_state, plan, perm, slot_mask,
                   x, labels, train_mask, degrees)


def vq_train_epoch_dp(mesh: Mesh, params, vq_states, opt_state,
                      plan: EpochPlan, perm, slot_mask, x, labels,
                      train_mask, degrees, cfg: GNNConfig, opt: Optimizer):
    """Data-parallel ``vq_train_epoch``: one jit'd shard_map call per epoch.

    Same signature/returns as the single-device executor plus the leading
    ``mesh`` (1-axis "data", e.g. ``graph_dp_mesh()``); the batch axis of
    ``perm``/``slot_mask`` [S, b] must divide by the mesh size.
    """
    nd = mesh.shape["data"]
    if perm.shape[1] % nd != 0:
        raise ValueError(
            f"batch size {perm.shape[1]} not divisible by the data mesh "
            f"size {nd}")
    return _dp_epoch_jit(params, vq_states, opt_state, plan, perm,
                         slot_mask, x, labels, train_mask, degrees,
                         mesh=mesh, cfg=cfg, opt=opt)


# ---------------------------------------------------------------------------
# Row-sharded graph state executors (DESIGN.md section 14)
# ---------------------------------------------------------------------------

class ShardedGraphState:
    """Every node-indexed table of a graph, row-sharded over ``mesh``.

    Host-side, built once per graph: pads each [n, ...] table to
    ``shard_padded_rows(n, ndev)`` rows (one sacrificial row for the
    inference scatter's wrap-pad writes, then round up to equal
    contiguous blocks) and places it with a :func:`shard_rows_spec`
    NamedSharding, so shard_map receives the per-device blocks without
    any resharding transfer.  ``degrees`` stays REPLICATED by design:
    ``fixed_edge_values`` indexes it by arbitrary neighbor ids on the
    per-batch hot path and it costs only 4 bytes/node -- same reasoning
    as the replicated [k, f] codebooks and [nb, n] assignment tables.
    """

    def __init__(self, mesh: Mesh, plan: EpochPlan, x, degrees,
                 labels=None, train_mask=None):
        self.mesh = mesh
        self.ndev = int(mesh.shape["data"])
        self.n = int(plan.n)
        self.n_pad = shard_padded_rows(self.n, self.ndev)
        self.n_local = self.n_pad // self.ndev
        put = functools.partial(shard_rows, mesh=mesh, n_pad=self.n_pad)
        self.plan = EpochPlan(
            nbr_ids=put(plan.nbr_ids), nbr_mask=put(plan.nbr_mask),
            rev_ids=put(plan.rev_ids), rev_mask=put(plan.rev_mask))
        self.x = put(jnp.asarray(x))
        self.degrees = jax.device_put(
            jnp.asarray(degrees), shd.replicated(mesh))
        self.labels = None if labels is None else put(jnp.asarray(labels))
        self.train_mask = None if train_mask is None \
            else put(jnp.asarray(train_mask))

    def per_device_bytes(self) -> int:
        """Peak per-device bytes of the held graph state (the bench's
        capacity metric; ~1/ndev of the replicated footprint plus the
        replicated [n] degree vector)."""
        return shd.per_device_bytes(
            [self.plan, self.x, self.degrees, self.labels, self.train_mask])

    def unshard(self, table) -> np.ndarray:
        """Host copy of a row-sharded [n_pad, ...] output with the pad
        rows stripped -- the parity-test / eval convenience."""
        return np.asarray(table)[: self.n]


def _pad_scan_axis(perm, slot_mask, ndev: int):
    """Pad the scan axis of the stacked [S, b] inference arrays to a
    multiple of ``ndev`` with all-masked batches (ids 0, mask 0), so the
    scan-axis shards run equal step counts and the per-step collectives
    stay lockstep.  The padding batches write only the sacrificial row."""
    s = perm.shape[0]
    s_pad = -(-s // ndev) * ndev
    if s_pad == s:
        return jnp.asarray(perm), jnp.asarray(slot_mask)
    perm = jnp.asarray(perm)
    slot_mask = jnp.asarray(slot_mask)
    zp = jnp.zeros((s_pad - s,) + perm.shape[1:], perm.dtype)
    zm = jnp.zeros((s_pad - s,) + slot_mask.shape[1:], slot_mask.dtype)
    return jnp.concatenate([perm, zp]), jnp.concatenate([slot_mask, zm])


@functools.partial(jax.jit,
                   static_argnames=("mesh", "cfg", "opt", "compress"),
                   donate_argnums=(0, 1, 2))
def _sharded_epoch_jit(params, vq_states, opt_state, plan, perm, slot_mask,
                       x, labels, train_mask, degrees, *, mesh: Mesh,
                       cfg: GNNConfig, opt: Optimizer, compress: bool):
    body = functools.partial(_vq_epoch_body, cfg=cfg, opt=opt,
                             axis_name="data", sharded_state=True,
                             compress=compress)
    rows = shard_rows_spec()
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), rows, epoch_batch_spec(),
                  epoch_batch_spec(), rows, rows, rows, P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_rep=False)
    return sharded(params, vq_states, opt_state, plan, perm, slot_mask,
                   x, labels, train_mask, degrees)


def vq_train_epoch_sharded(state: ShardedGraphState, params, vq_states,
                           opt_state, perm, slot_mask, cfg: GNNConfig,
                           opt: Optimizer, *, compress: bool = False):
    """``vq_train_epoch_dp`` against row-sharded graph state: the batch
    axis still splits over "data" (each shard trains on its b/ndev rows)
    but the EpochPlan / feature / label / mask tables are per-shard row
    blocks and every per-batch row access goes cross-shard.  Value-
    identical to the replicated DP executor at the same mesh size (the
    gathers reassemble the exact same batches); per-device graph-state
    bytes drop ~1/ndev.  Same returns as ``vq_train_epoch``."""
    nd = state.ndev
    if perm.shape[1] % nd != 0:
        raise ValueError(
            f"batch size {perm.shape[1]} not divisible by the data mesh "
            f"size {nd} -- the sharded-state executor splits each batch "
            f"over the mesh; pick b as a multiple of {nd} (the trainer "
            f"clamps batch_size to the {state.n}-node pool first)")
    if state.labels is None or state.train_mask is None:
        raise ValueError(
            "ShardedGraphState built without labels/train_mask cannot "
            "train -- pass them at construction")
    return _sharded_epoch_jit(params, vq_states, opt_state, state.plan,
                              jnp.asarray(perm), jnp.asarray(slot_mask),
                              state.x, state.labels, state.train_mask,
                              state.degrees, mesh=state.mesh, cfg=cfg,
                              opt=opt, compress=compress)


@functools.partial(jax.jit,
                   static_argnames=("mesh", "cfg", "layer", "inductive",
                                    "n_global", "compress"))
def _sharded_infer_layer_jit(params_l, vq_state, plan, perm, slot_mask,
                             acts, degrees, *, mesh: Mesh, cfg: GNNConfig,
                             layer: int, inductive: bool, n_global: int,
                             compress: bool):
    body = functools.partial(_vq_infer_layer_sharded, cfg=cfg, layer=layer,
                             axis_name="data", n_global=n_global,
                             inductive=inductive, compress=compress)
    rows = shard_rows_spec()
    scan = scan_shard_spec()
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rows, scan, scan, rows, P()),
        out_specs=(rows, P()),
        check_rep=False)
    return sharded(params_l, vq_state, plan, perm, slot_mask, acts,
                   degrees)


def vq_infer_epoch_sharded(state: ShardedGraphState, params, vq_states,
                           perm, slot_mask, cfg: GNNConfig, *,
                           inductive: bool = False,
                           compress: bool = False):
    """``vq_infer_epoch`` against row-sharded graph state: n_layers jit'd
    shard_map calls, each sweeping the S batches with the SCAN axis split
    over the mesh (S/ndev full batches per shard -- exact full-batch
    positions, so the result is bit-identical to the replicated ndev=1
    executor) and the [n_pad, f] activation tables row-sharded
    throughout.  Returns (acts, states) with ``acts`` the row-sharded
    [n_pad, f_out] table -- ``state.unshard(acts)`` for the [n, f_out]
    host view."""
    perm, slot_mask = _pad_scan_axis(perm, slot_mask, state.ndev)
    acts = state.x
    states = list(vq_states)
    for l in range(cfg.n_layers):
        acts, states[l] = _sharded_infer_layer_jit(
            params[l], states[l], state.plan, perm, slot_mask, acts,
            state.degrees, mesh=state.mesh, cfg=cfg, layer=l,
            inductive=inductive, n_global=state.n, compress=compress)
    return acts, states


@functools.partial(jax.jit, static_argnames=("mesh", "cfg", "compress"))
def _sharded_serve_jit(params, vq_states, plan, bids, x, degrees, *,
                       mesh: Mesh, cfg: GNNConfig, compress: bool):
    body = functools.partial(_vq_serve_body_sharded, cfg=cfg,
                             axis_name="data", compress=compress)
    rows = shard_rows_spec()
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rows, P(), rows, P()),
        out_specs=P(),
        check_rep=False)
    return sharded(params, vq_states, plan, bids, x, degrees)


def vq_serve_batch_sharded(state: ShardedGraphState, params, vq_states,
                           bids, cfg: GNNConfig, *,
                           compress: bool = False):
    """``vq_serve_batch`` against row-sharded graph state: request ids
    replicated, plan/feature rows cross-shard-gathered, forward exact --
    the serve endpoint's capacity mode (``serve_gnn --mesh N`` with
    sharding on)."""
    return _sharded_serve_jit(params, vq_states, state.plan,
                              jnp.asarray(bids), state.x, state.degrees,
                              mesh=state.mesh, cfg=cfg, compress=compress)
