"""shard_map data parallelism for the VQ epoch executor (DESIGN.md sec. 9).

Shards the BATCH axis of the stacked epoch arrays over the 1-axis "data"
mesh: each device runs the full ``lax.scan`` over the S steps on its b/ndev
rows of every batch, treating its rows as a VQ mini-batch of their own
(cross-device in-batch neighbors ride the codeword context, exactly the
paper's out-of-batch approximation).  The per-replica body IS
``models.gnn._vq_epoch_body`` -- the same implementation the single-device
executor jits -- with ``axis_name="data"``, which turns on three
collectives per step:

  * param grads          -- ``collectives.psum_tree`` (uncompressed; exact),
  * codebook statistics  -- the fused ``vq_assign_update`` (counts, sums)
    and the whitening batch moments, psum'd INSIDE ``codebook.update`` via
    its ``axis_name`` hook, so every replica computes the same EMA step as
    a single device seeing the pooled batch;
  * assignment sync      -- each device's refreshed rows are all-gathered
    and scattered into the (replicated) global assignment table, so tables
    never diverge.

The ndev=1 instantiation is numerically identical to
``models.gnn.vq_train_epoch``; the multi-device run is identical to the
same body under ``jax.vmap(axis_name=...)`` over the sub-batch axis (the
parity oracles in tests/test_epoch_executor.py).

Eq. 7 backward under DP: the injection's residuals are *lazy*
(``core/message_passing.py`` / DESIGN.md section 10) -- each replica's
scan carry holds only its [b/ndev, Dr] reverse-edge operands plus the
replicated O(k * f) codebook and assignment tables it keeps anyway, and
the backward streams the phantom term through the fused
``kops.context_ell`` dispatch per replica with no collective (the
codeword tables are replica-identical by the psum rule above).  Nothing
per-replica scales as [b/ndev, Dr, f_grad].
"""
from __future__ import annotations

import functools

import jax

from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import epoch_batch_spec, graph_dp_mesh
from repro.graph.batching import EpochPlan
from repro.models.gnn import GNNConfig, _vq_epoch_body
from repro.train.optimizer import Optimizer

__all__ = ["graph_dp_mesh", "vq_train_epoch_dp"]


@functools.partial(jax.jit, static_argnames=("mesh", "cfg", "opt"),
                   donate_argnums=(0, 1, 2))
def _dp_epoch_jit(params, vq_states, opt_state, plan, perm, slot_mask,
                  x, labels, train_mask, degrees, *, mesh: Mesh,
                  cfg: GNNConfig, opt: Optimizer):
    # the shard_map wrapper is rebuilt per trace (cheap); caching lives in
    # jit's executable cache keyed on the static (mesh, cfg, opt) -- the
    # same convention as vq_train_step's static opt, and no extra
    # permanently-retained closure cache
    body = functools.partial(_vq_epoch_body, cfg=cfg, opt=opt,
                             axis_name="data")
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), epoch_batch_spec(),
                  epoch_batch_spec(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(), P(), P()),
        check_rep=False)
    return sharded(params, vq_states, opt_state, plan, perm, slot_mask,
                   x, labels, train_mask, degrees)


def vq_train_epoch_dp(mesh: Mesh, params, vq_states, opt_state,
                      plan: EpochPlan, perm, slot_mask, x, labels,
                      train_mask, degrees, cfg: GNNConfig, opt: Optimizer):
    """Data-parallel ``vq_train_epoch``: one jit'd shard_map call per epoch.

    Same signature/returns as the single-device executor plus the leading
    ``mesh`` (1-axis "data", e.g. ``graph_dp_mesh()``); the batch axis of
    ``perm``/``slot_mask`` [S, b] must divide by the mesh size.
    """
    nd = mesh.shape["data"]
    if perm.shape[1] % nd != 0:
        raise ValueError(
            f"batch size {perm.shape[1]} not divisible by the data mesh "
            f"size {nd}")
    return _dp_epoch_jit(params, vq_states, opt_state, plan, perm,
                         slot_mask, x, labels, train_mask, degrees,
                         mesh=mesh, cfg=cfg, opt=opt)
