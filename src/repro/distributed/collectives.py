"""Collective helpers: int8-compressed gradient all-reduce w/ error feedback.

Cross-pod links are the scarcest bandwidth on a multi-pod job (DCN between
pods is ~10x slower than in-pod ICI).  The compressed all-reduce quantizes
each gradient tensor to int8 with a per-tensor scale before the cross-pod
reduction (in-pod reductions stay bf16), and keeps the quantization residual
as error feedback added to the next step -- the standard 1-bit-Adam-family
trick, which preserves convergence (residual is O(quantization step), test:
tests/test_distributed.py::test_compressed_allreduce_converges).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def psum_tree(tree: PyTree, axis_name: str) -> PyTree:
    """Leaf-wise uncompressed psum -- the exact all-reduce of the VQ epoch
    executor's data parallelism (param grads and codebook statistics must
    stay bit-consistent across replicas so the codebooks and assignment
    tables never diverge; the int8 path below is for cross-pod links)."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x, axis_name), tree)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    residual: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, jax.Array]:
    """int8 all-reduce over `axis_name` with error feedback.

    Returns (sum, new_residual).  Inside shard_map/pmap only.
    """
    x32 = x.astype(jnp.float32)
    if residual is not None:
        x32 = x32 + residual
    q, scale = quantize_int8(x32)
    new_residual = x32 - dequantize_int8(q, scale)
    # sum int8 payloads in int32 (wraparound-safe for the axis sizes here),
    # scales reduced separately; dequantize with the max scale (conservative)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    return qsum.astype(jnp.float32) * smax, new_residual


def compressed_grad_allreduce(grads: PyTree, axis_name: str,
                              residuals: Optional[PyTree] = None
                              ) -> tuple[PyTree, PyTree]:
    """Tree-wise compressed_psum (one scale per tensor)."""
    if residuals is None:
        residuals = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(residuals)[0]
    outs, news = [], []
    for g, r in zip(flat_g, flat_r):
        o, nr = compressed_psum(g, axis_name, r)
        outs.append(o)
        news.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, news))
