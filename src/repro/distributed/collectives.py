"""Collective helpers: int8-compressed gradient all-reduce w/ error feedback.

Cross-pod links are the scarcest bandwidth on a multi-pod job (DCN between
pods is ~10x slower than in-pod ICI).  The compressed all-reduce quantizes
each gradient tensor to int8 with a per-tensor scale before the cross-pod
reduction (in-pod reductions stay bf16), and keeps the quantization residual
as error feedback added to the next step -- the standard 1-bit-Adam-family
trick, which preserves convergence (residual is O(quantization step), test:
tests/test_distributed.py::test_compressed_allreduce_converges).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def psum_tree(tree: PyTree, axis_name: str) -> PyTree:
    """Leaf-wise uncompressed psum -- the exact all-reduce of the VQ epoch
    executor's data parallelism (param grads and codebook statistics must
    stay bit-consistent across replicas so the codebooks and assignment
    tables never diverge; the int8 path below is for cross-pod links)."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x, axis_name), tree)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    residual: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, jax.Array]:
    """int8 all-reduce over `axis_name` with error feedback.

    Returns (sum, new_residual).  Inside shard_map/pmap only.
    """
    x32 = x.astype(jnp.float32)
    if residual is not None:
        x32 = x32 + residual
    q, scale = quantize_int8(x32)
    new_residual = x32 - dequantize_int8(q, scale)
    # sum int8 payloads in int32 (wraparound-safe for the axis sizes here),
    # scales reduced separately; dequantize with the max scale (conservative)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    smax = jax.lax.pmax(scale, axis_name)
    return qsum.astype(jnp.float32) * smax, new_residual


def compressed_grad_allreduce(grads: PyTree, axis_name: str,
                              residuals: Optional[PyTree] = None
                              ) -> tuple[PyTree, PyTree]:
    """Tree-wise compressed_psum (one scale per tensor)."""
    if residuals is None:
        residuals = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(residuals)[0]
    outs, news = [], []
    for g, r in zip(flat_g, flat_r):
        o, nr = compressed_psum(g, axis_name, r)
        outs.append(o)
        news.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, news))


# ---------------------------------------------------------------------------
# Cross-shard row gather / scatter for the row-sharded graph state
# (DESIGN.md section 14).  Ownership is contiguous-block: shard s of the
# "data" axis owns global rows [s*n_local, (s+1)*n_local) of a table
# whose sharded operand inside shard_map is the [n_local, ...] block.
# ---------------------------------------------------------------------------

def all_gather_rows(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather along `axis_name`, flattening the shard axis into the
    leading row axis (shard-major order -- matches contiguous-block row
    ownership, so gathering each shard's [n_local, ...] block yields the
    padded global table)."""
    g = jax.lax.all_gather(x, axis_name)
    return g.reshape((-1,) + x.shape[1:])


def gather_from_shards(table: jax.Array, ids: jax.Array, axis_name: str,
                       *, compress: bool = False) -> jax.Array:
    """Cross-shard `table[ids]` for a row-sharded table.

    Every shard contributes its [n_local, ...] block of the padded global
    table and a local request vector `ids` of *global* row indices; each
    shard answers the all-gathered requests for the rows it owns
    (masked-zero elsewhere), a psum superposes the answers (each row has
    exactly one owner, so the sum is exact), and each shard slices its
    own request window back out.  Integer payloads are summed in int32
    and cast back -- bit-exact; fp8 payloads (the fp8 codeword tier) move
    as bitcast uint8 bytes the same way, also bit-exact.  ``compress=True``
    moves float payloads
    as int8 -- the bandwidth knob for large feature gathers over slow
    links.  Unlike :func:`compressed_psum` (per-shard scales + error
    feedback, right for gradients averaged over many steps), the gather
    quantizes every shard against ONE pmax-shared scale: each row has
    exactly one owner, so the dequantized sum is then exact up to a
    single quantization half-step (max|table| / 254).

    Inside shard_map only.  `ids` must index the padded global table
    (0 <= id < n_local * ndev).
    """
    n_local = table.shape[0]
    b = ids.shape[0]
    me = jax.lax.axis_index(axis_name)
    all_ids = all_gather_rows(ids.astype(jnp.int32), axis_name)
    loc = all_ids - me * n_local
    own = (loc >= 0) & (loc < n_local)
    rows = table[jnp.clip(loc, 0, n_local - 1)]
    mask = own.reshape((-1,) + (1,) * (rows.ndim - 1))
    if table.dtype in (jnp.dtype(jnp.float8_e4m3fn), jnp.dtype(jnp.float8_e5m2)):
        # fp8 codeword payloads move as raw bytes: bitcast to uint8, sum in
        # int32 (one owner per row and fp8 zero is 0x00, so the superposition
        # is the owner's bit pattern), and bitcast back -- bit-exact, same
        # wire bytes as the int8 tier.
        bits = jnp.where(mask, jax.lax.bitcast_convert_type(
            rows, jnp.uint8).astype(jnp.int32), 0)
        full = jax.lax.bitcast_convert_type(
            jax.lax.psum(bits, axis_name).astype(jnp.uint8), table.dtype)
    elif jnp.issubdtype(table.dtype, jnp.integer) or table.dtype == jnp.bool_:
        contrib = jnp.where(mask, rows.astype(jnp.int32), 0)
        full = jax.lax.psum(contrib, axis_name).astype(table.dtype)
    elif compress:
        contrib = jnp.where(mask, rows.astype(jnp.float32), 0.0)
        scale = jax.lax.pmax(jnp.max(jnp.abs(contrib)), axis_name) \
            / 127.0 + 1e-12
        q = jnp.round(contrib / scale).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        full = (qsum.astype(jnp.float32) * scale).astype(table.dtype)
    else:
        contrib = jnp.where(mask, rows, jnp.zeros_like(rows))
        full = jax.lax.psum(contrib, axis_name)
    return jax.lax.dynamic_slice_in_dim(full, me * b, b, axis=0)


def shard_scatter_rows(table: jax.Array, ids: jax.Array, rows: jax.Array,
                       axis_name: str) -> jax.Array:
    """Cross-shard `table.at[ids].set(rows)` for a row-sharded table.

    All shards' (global id, row) pairs are all-gathered; each shard
    rewrites the rows it owns and parks foreign/duplicate-pad writes on a
    transient extra local row that is sliced off afterward.  `ids` must
    be distinct across the whole gather wherever they target real rows
    (the inference executor guarantees this: each batch writes distinct
    node ids, wrap-pad slots are diverted to the sacrificial global row,
    which is itself row-sharded state and may be clobbered freely).

    Inside shard_map only.  Returns the updated [n_local, ...] block.
    """
    n_local = table.shape[0]
    me = jax.lax.axis_index(axis_name)
    all_ids = all_gather_rows(ids.astype(jnp.int32), axis_name)
    all_rows = all_gather_rows(rows, axis_name)
    loc = all_ids - me * n_local
    own = (loc >= 0) & (loc < n_local)
    dst = jnp.where(own, jnp.clip(loc, 0, n_local - 1), n_local)
    park = jnp.zeros((1,) + tuple(table.shape[1:]), table.dtype)
    out = jnp.concatenate([table, park], axis=0)
    out = out.at[dst].set(all_rows.astype(table.dtype))
    return out[:n_local]
