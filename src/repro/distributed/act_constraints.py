"""Activation sharding constraints (GSPMD guidance).

Contracting a batch-sharded activation with an FSDP-sharded weight gives
GSPMD two competing uses of the data axes; left to itself it sometimes
re-shards the ACTIVATION (replicating the batch -- observed +20 GiB/chip on
the 405B cell, Perf iteration 5c) instead of all-gathering the weight.
Pinning the activation sharding at block boundaries forces the correct
resolution.

The policy is process-global and set by the launcher/dry-run before
lowering; when unset (unit tests, single-device smoke) every call is a
no-op, so the model code stays device-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: Optional[tuple] = None   # (mesh, batch_axes)


def set_policy(mesh: Mesh, batch_axes) -> None:
    global _POLICY
    _POLICY = (mesh, batch_axes)


def clear_policy() -> None:
    global _POLICY
    _POLICY = None


def constrain_tokens(x: jax.Array) -> jax.Array:
    """Pin [batch, seq, d_model] activations: batch over the data axes,
    seq/d replicated (Megatron layout; the TP all-reduces handle d)."""
    if _POLICY is None or x.ndim < 2:
        return x
    mesh, batch_axes = _POLICY
    if x.shape[0] % _axes_size(mesh, batch_axes) != 0:
        return x
    spec = P(batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
