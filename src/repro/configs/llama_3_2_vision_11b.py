"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) ff14336
vocab 128256; cross-attention image layers every 5; patch frontend STUB.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    cross_attn_period=5, n_patches=1024)


def smoke() -> ArchConfig:
    return ArchConfig(name="llamavis-smoke", family="vlm", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=256, cross_attn_period=2, n_patches=16,
                      remat=False, dtype="float32")
