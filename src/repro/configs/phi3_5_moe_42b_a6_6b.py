"""phi3.5-moe-42b-a6.6b [moe]: 32L d4096 32H (GQA kv=8) expert_ff=6400
vocab 32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064,
    n_experts=16, top_k=2)


def smoke() -> ArchConfig:
    return ArchConfig(name="phi35moe-smoke", family="moe", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=48,
                      vocab=256, n_experts=4, top_k=2, remat=False,
                      dtype="float32")
