"""Architecture configuration schema + input-shape sets.

One ``ArchConfig`` per assigned architecture lives in
``repro/configs/<id>.py`` (exact published numbers); every config also
provides ``smoke()`` -- a reduced same-family variant for CPU tests.

Input shapes (assigned set; LM shapes are seq_len x global_batch):
  train_4k      seq 4096,    batch 256  -> train_step
  prefill_32k   seq 32768,   batch 32   -> prefill_step
  decode_32k    seq 32768,   batch 128  -> serve_step (1 token, full cache)
  long_500k     seq 524288,  batch 1    -> serve_step; needs sub-quadratic
                attention: native for ssm/hybrid, via VQ-Attention for
                dense/moe/vlm/audio (the paper's technique), skipped for
                pure full-attention variants (DESIGN.md Arch-applicability)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    attn_period: int = 0        # hybrid: 1 shared attn block per N ssm layers
    # encoder-decoder (audio)
    enc_layers: int = 0
    enc_seq: int = 1500         # stub frame count
    # VLM
    cross_attn_period: int = 0
    n_patches: int = 1024       # stub patch count
    # VQ-Attention (the paper's technique as a first-class feature)
    vq_attn: bool = False
    vq_k: int = 1024
    vq_window: int = 512
    # engineering
    remat: bool = True
    remat_group: int = 0     # >0: sqrt-remat -- checkpoint groups of this
    # many layers (outer scan) instead of every layer (Perf iteration 3)
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_vq(self, k: int = 1024, window: int = 512) -> "ArchConfig":
        return dataclasses.replace(self, vq_attn=True, vq_k=k,
                                   vq_window=window)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hq, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        mlp = 3 * d * ff
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + mlp + 2 * d
        elif self.family == "moe":
            per_layer = attn + 2 * d + d * self.n_experts \
                + self.n_experts * 3 * d * ff
        elif self.family == "ssm":
            pass  # xlstm counted below
        total = self.n_layers * per_layer
        if self.family == "hybrid":
            di = 2 * d
            n = self.ssm_state
            h = di // 64
            mamba = d * (2 * di + 2 * n + h) + 4 * (di + 2 * n) + di * d + di
            shared = attn + 3 * d * ff + 2 * d
            total = self.n_layers * mamba + shared
        if self.family == "ssm":
            dk = d // self.n_heads
            mlstm = 3 * d * d + 2 * d * self.n_heads + 2 * d * d
            slstm = 8 * d * d + d * d
            total = (self.n_layers // 2) * (mlstm + slstm)
        if self.family == "audio":
            total += self.enc_layers * (attn + mlp + 2 * d) \
                + self.n_layers * (attn + 2 * d)   # decoder cross-attn
        if self.family == "vlm" and self.cross_attn_period:
            total += (self.n_layers // self.cross_attn_period) * (attn + 2 * d)
        total += v * d * 2 + d  # embed + head + final norm
        return total


SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
