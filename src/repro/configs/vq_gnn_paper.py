"""The paper's own experimental configurations (App. F hyper-parameters),
as GNNConfig presets keyed by (dataset, backbone).

Paper setup: 3 layers, hidden 128, codebook 1024 (256 "should also work"),
f_prod=4 product VQ, RMSprop(alpha=0.99) lr 3e-3, batch 40K on 169K nodes
(~n/4).  The synthetic look-alikes are ~40x smaller, so the presets scale
k and batch proportionally while keeping every ratio (k/n, b/n, f_prod).
"""
from __future__ import annotations

from repro.core.codebook import CodebookConfig
from repro.graph.structure import Graph
from repro.models.gnn import GNNConfig

PAPER_HIDDEN = 128
PAPER_LAYERS = 3
PAPER_F_PROD = 4
PAPER_LR = 3e-3           # RMSprop, App. F


def paper_config(g: Graph, backbone: str = "gcn",
                 full_scale: bool = False) -> GNNConfig:
    """GNNConfig matching the paper's App. F setup, scaled to the graph."""
    if full_scale:
        k, hidden, layers = 1024, PAPER_HIDDEN, PAPER_LAYERS
    else:
        k = max(64, min(1024, g.n // 8))
        hidden, layers = 64, 2
    task = "link" if g.train_edges is not None else "node"
    return GNNConfig(
        backbone=backbone, f_in=g.f, hidden=hidden,
        n_out=(hidden if task == "link" else g.num_classes),
        n_layers=layers, task=task, multilabel=g.multilabel,
        codebook=CodebookConfig(k=k, f_prod=PAPER_F_PROD))


def paper_batch_size(g: Graph) -> int:
    """40K of 169K nodes ~ n/4 (App. F)."""
    return max(64, g.n // 4)
