"""Scenario-matrix registry: the GNN axes of the paper's comparison.

The matrix ``benchmarks/bench_ablation.py`` sweeps -- and the CI
``scenario-matrix`` job gates -- is backbone x scale method x task:

  backbones      the paper's Table 2 convolution types (``nn.gnn_layers``)
  scale methods  how training fits in device memory: the full-graph oracle,
                 VQ-GNN (Alg. 1), the four sampling baselines on the
                 sampler epoch executor, and the VQ/sampling hybrid
                 (``train.gnn_trainer.train_scenario`` dispatch)
  tasks          node classification / link prediction

This module is deliberately SEPARATE from ``configs.registry``: that file
enumerates the LM/speech/vision architecture seeds of the generic launch
harness (llama/whisper/moe, quarantined from the GNN path) and must never
leak into the matrix -- ``tests/test_scenarios.py`` pins both sets.
"""
from repro.train.gnn_trainer import SCALE_METHODS

# pinned tuple (not BACKBONES.keys()) so an accidental registration in
# nn.gnn_layers widens the CI matrix only after an explicit review here;
# the consistency test asserts the two stay equal.
MATRIX_BACKBONES = ("gcn", "sage", "gat", "gin", "transformer")

MATRIX_TASKS = ("node", "link")

# env knobs honored by train_scenario / the benchmark driver
SCENARIO_KNOBS = {
    "REPRO_SCALE_METHOD": "scale method when not passed explicitly "
                          f"(one of {SCALE_METHODS}; default 'vq')",
    "REPRO_SAMPLER_FANOUT": "per-layer fanout for ns_sage/labor/hybrid "
                            "(default 5)",
    "REPRO_WALK_LENGTH": "GraphSAINT random-walk length (default 3)",
    "REPRO_N_PARTS": "Cluster-GCN partition count (default 32)",
    "REPRO_HYBRID_CTX": "hybrid context-slot budget per batch "
                        "(default batch_size)",
    "REPRO_SAMPLER_EXECUTOR": "0 -> per-batch host loop instead of the "
                              "sampler epoch executor (default on)",
}


def matrix_cells(tasks=("node",)):
    """Enumerate (backbone, scale_method, task) cells of the matrix."""
    return [(b, m, t) for t in tasks for b in MATRIX_BACKBONES
            for m in SCALE_METHODS]


def assert_gnn_only(names) -> None:
    """Guard used by the matrix path: raise if any LM/speech/vision arch id
    from ``configs.registry`` shows up where a GNN backbone is expected."""
    from repro.configs.registry import ARCHS
    leaked = sorted(set(names) & set(ARCHS))
    if leaked:
        raise ValueError(
            f"non-GNN arch ids {leaked} leaked into the scenario matrix; "
            f"matrix cells enumerate MATRIX_BACKBONES only")
    unknown = sorted(set(names) - set(MATRIX_BACKBONES))
    if unknown:
        raise ValueError(
            f"unknown backbones {unknown}; expected a subset of "
            f"{MATRIX_BACKBONES}")
