"""qwen3-32b [dense]: 64L d5120 64H (GQA kv=8) ff25600 vocab 151936, qk_norm.
[hf:Qwen/Qwen3-8B family; hf-verified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151936, qk_norm=True)


def smoke() -> ArchConfig:
    return ArchConfig(name="qwen3-smoke", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=256, qk_norm=True, remat=False, dtype="float32")
