"""llama3-405b [dense]: 126L d16384 128H (GQA kv=8) ff53248 vocab 128256.
[arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    remat_group=14)


def smoke() -> ArchConfig:
    return ArchConfig(name="llama405b-smoke", family="dense", n_layers=3,
                      d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
                      vocab=256, remat=False, dtype="float32")
