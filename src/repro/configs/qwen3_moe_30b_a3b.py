"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) expert_ff=768
vocab 151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf-verified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936, qk_norm=True,
    n_experts=128, top_k=8)


def smoke() -> ArchConfig:
    return ArchConfig(name="qwen3moe-smoke", family="moe", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
                      vocab=256, qk_norm=True, n_experts=8, top_k=2,
                      remat=False, dtype="float32")
