"""xlstm-350m [ssm]: 24L d1024 4H vocab 50304; sLSTM + mLSTM pairs.
[arXiv:2405.04517; unverified]
Attention-free: VQ-GNN technique inapplicable (DESIGN.md
Arch-applicability); long_500k runs natively (linear recurrence)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304)


def smoke() -> ArchConfig:
    return ArchConfig(name="xlstm-smoke", family="ssm", n_layers=4,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
                      vocab=256, remat=False, dtype="float32")
