"""llama3.2-3b [dense]: 28L d3072 24H (GQA kv=8) ff8192 vocab 128256.
[hf:meta-llama/Llama-3.2-1B family; unverified]
24 heads do not divide the 16-way model axis -> FSDP sharding strategy
(DESIGN.md section 5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab=128256)


def smoke() -> ArchConfig:
    return ArchConfig(name="llama3b-smoke", family="dense", n_layers=2,
                      d_model=48, n_heads=6, n_kv_heads=2, d_ff=96,
                      vocab=256, remat=False, dtype="float32")
