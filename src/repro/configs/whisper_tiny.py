"""whisper-tiny [audio]: 4L d384 6H ff1536 vocab 51865; enc-dec, conv
frontend STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]
6 heads do not divide the 16-way model axis -> replicated-DP strategy
(37M params).  Decode shapes exercise the decoder only."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    enc_layers=4, enc_seq=1500)


def smoke() -> ArchConfig:
    return ArchConfig(name="whisper-smoke", family="audio", n_layers=2,
                      d_model=48, n_heads=3, n_kv_heads=3, d_ff=96,
                      vocab=256, enc_layers=2, enc_seq=32, remat=False,
                      dtype="float32")
