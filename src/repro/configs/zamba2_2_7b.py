"""zamba2-2.7b [hybrid]: 54L d2560 32H (GQA kv=32) ff10240 vocab 32000,
Mamba2 ssm_state=64 + shared attention block.  [arXiv:2411.15242; hf]
Mamba2 scan is attention-free (VQ inapplicable); the shared attention
block takes VQ-Attention for long_500k (DESIGN.md Arch-applicability)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
    ssm_state=64, attn_period=6)


def smoke() -> ArchConfig:
    return ArchConfig(name="zamba2-smoke", family="hybrid", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab=256, ssm_state=16, attn_period=2, remat=False,
                      dtype="float32")
