"""LM/speech/vision architecture registry: --arch <id> -> ArchConfig.

QUARANTINED from the GNN scenario-matrix path: these are the generic
launch-harness seeds (llama/whisper/moe), kept for ``launch/train.py`` and
friends.  The scenario matrix enumerates GNN backbones from
``repro.configs.scenarios`` exclusively, and
``scenarios.assert_gnn_only`` / ``tests/test_scenarios.py`` enforce that
none of these ids ever appear as a matrix cell.
"""
from repro.configs.base import ArchConfig

from repro.configs import (granite_3_8b, llama3_405b, qwen3_32b, llama3_2_3b,
                           xlstm_350m, qwen3_moe_30b_a3b,
                           phi3_5_moe_42b_a6_6b, zamba2_2_7b, whisper_tiny,
                           llama_3_2_vision_11b)

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "llama3-405b": llama3_405b,
    "qwen3-32b": qwen3_32b,
    "llama3.2-3b": llama3_2_3b,
    "xlstm-350m": xlstm_350m,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b_a6_6b,
    "zamba2-2.7b": zamba2_2_7b,
    "whisper-tiny": whisper_tiny,
    "llama-3.2-vision-11b": llama_3_2_vision_11b,
}

# LM_ARCHS is the quarantine-explicit name; ARCHS stays as an alias for
# the existing launch/test import sites.
LM_ARCHS = {name: m.CONFIG for name, m in _MODULES.items()}
ARCHS = LM_ARCHS
SMOKES = {name: m.smoke for name, m in _MODULES.items()}


def get_arch(name: str) -> ArchConfig:
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return SMOKES[name]()
