"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 vocab 49155.
[hf:ibm-granite/granite-3.0-2b-base family; hf-verified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12800, vocab=49155)


def smoke() -> ArchConfig:
    return ArchConfig(name="granite-smoke", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=256, remat=False, dtype="float32")
