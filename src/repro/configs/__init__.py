"""Per-architecture configs (assigned pool) + paper GNN configs."""
