"""Pod serving launcher: batched decode with exact or VQ-compressed KV.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \
        --tokens 32 [--vq]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, SMOKES
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--vq", action="store_true",
                    help="VQ-compressed KV cache (paper technique)")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=1024)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = SMOKES[args.arch]() if args.smoke else ARCHS[args.arch]
    if args.vq:
        cfg = cfg.with_vq(k=min(cfg.vq_k, 128), window=64)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    strategy = shd.strategy_for(cfg, mesh)

    with mesh:
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        cache = lm.init_serve_cache(cfg, args.batch, args.context)
        step = jax.jit(lambda p, t, c: lm.serve_step(p, t, c, cfg))
        tok = jnp.zeros((args.batch, 1), jnp.int32)
        logits, cache = step(params, tok, cache)          # compile
        t0 = time.time()
        for _ in range(args.tokens):
            logits, cache = step(params, tok, cache)
            tok = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(logits)
        dt = time.time() - t0
    cache_mb = sum(np.asarray(x).nbytes for x in
                   jax.tree_util.tree_leaves(cache)) / 2**20
    print(f"{cfg.name} strategy={strategy} vq={cfg.vq_attn}: "
          f"{args.tokens*args.batch/dt:.1f} tok/s, cache {cache_mb:.1f} MB")


if __name__ == "__main__":
    main()
