"""repro subpackage."""
