"""Pod training launcher: pjit-sharded train loop on an explicit mesh.

On real hardware this runs under `python -m repro.launch.train --arch <id>`
per host (jax.distributed initializes from the TPU environment); on the CPU
host it runs the same code on a small host mesh -- which is exactly what
tests/test_distributed.py does with forced virtual devices.

Fault tolerance contract (DESIGN.md section 5):
  * checkpoint every --ckpt-every steps (atomic, versioned);
  * on start: resume from latest checkpoint if present;
  * data shards are pure functions of (seed, step) -> a restarted or
    *re-sized* job replays the identical global batch sequence (elastic
    re-sharding is just restoring logical arrays under new shardings).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, SMOKES
from repro.data.tokens import TokenStreamConfig, batch_shard
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.loop import TrainState, make_train_step
from repro.train.optimizer import adam, warmup_cosine


def build_sharded_step(cfg, mesh, opt, accum: int):
    strategy = shd.strategy_for(cfg, mesh)
    step_fn = make_train_step(cfg, opt, accum=accum,
                              accum_dtype=jnp.bfloat16)
    params_shape = jax.eval_shape(
        lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    state_sh = TrainState(
        params=shd.param_shardings(params_shape, cfg, mesh, strategy),
        opt=type(opt_shape)(
            step=shd.replicated(mesh),
            mu=shd.param_shardings(opt_shape.mu, cfg, mesh, strategy),
            nu=shd.param_shardings(opt_shape.nu, cfg, mesh, strategy)),
        step=shd.replicated(mesh))
    return jax.jit(step_fn, in_shardings=(state_sh, None),
                   out_shardings=(state_sh, shd.replicated(mesh))), state_sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = SMOKES[args.arch]() if args.smoke else ARCHS[args.arch]
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    opt = adam(warmup_cosine(args.lr, 10, args.steps), clip_norm=1.0,
               moment_dtype=jnp.bfloat16)
    step, state_sh = build_sharded_step(cfg, mesh, opt, args.accum)

    with mesh:
        params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32))
        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            state, manifest = ckpt.restore(args.ckpt_dir, state)
            start = manifest["step"]
            print(f"resumed from step {start}")
        ds = TokenStreamConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                               global_batch=args.batch, seed=args.seed)
        t0 = time.time()
        for s in range(start, args.steps):
            tokens = jnp.asarray(batch_shard(ds, s, 0, 1))
            state, metrics = step(state, tokens)
            if (s + 1) % 10 == 0:
                print(f"step {s+1:5d}  loss {float(metrics['loss']):.4f}  "
                      f"{(s+1-start)/(time.time()-t0):.2f} it/s")
            if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, s + 1, state, {"seed": args.seed})
    print("done")


if __name__ == "__main__":
    main()
