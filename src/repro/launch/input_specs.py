"""ShapeDtypeStruct stand-ins for every (arch x input-shape) cell.

No device allocation ever happens here: params/optimizer/cache structures
come from jax.eval_shape over the real init functions, so the dry-run
lowers the exact same pytrees the runtime uses.  Modality frontends are
STUBS per the assignment spec: [audio] gets precomputed frame embeddings,
[vlm] precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import lm
from repro.train.loop import TrainState
from repro.train.optimizer import adam


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def arch_for_cell(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Cell-specific config adjustments (DESIGN.md Arch-applicability):
    long_500k needs sub-quadratic attention -> VQ-Attention is enabled for
    the attention families; ssm/hybrid run natively."""
    if shape_name == "long_500k" and cfg.family in (
            "dense", "moe", "vlm", "audio"):
        return cfg.with_vq(k=1024, window=512)
    return cfg


def aux_embed_spec(cfg: ArchConfig, batch: int):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        return sds((batch, cfg.enc_seq, cfg.d_model), dt)
    if cfg.family == "vlm":
        return sds((batch, cfg.n_patches, cfg.d_model), dt)
    return None


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """Abstract inputs for the cell's entry point.

    kind == train   -> {state, tokens(+1 for targets), aux_embeds?}
    kind == prefill -> {params, tokens, aux_embeds?}
    kind == decode  -> {params, token, cache}
    """
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    cfg = arch_for_cell(cfg, shape_name)

    params = jax.eval_shape(
        lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))

    if sh["kind"] == "train":
        opt = adam(moment_dtype=jnp.bfloat16)
        opt_state = jax.eval_shape(lambda p: opt.init(p), params)
        state = TrainState(params, opt_state, sds((), jnp.int32))
        out = {"state": state, "tokens": sds((b, s + 1))}
        aux = aux_embed_spec(cfg, b)
        if aux is not None:
            out["aux_embeds"] = aux
        return out

    if sh["kind"] == "prefill":
        out = {"params": params, "tokens": sds((b, s))}
        aux = aux_embed_spec(cfg, b)
        if aux is not None:
            out["aux_embeds"] = aux
        return out

    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(
        lambda: lm.init_serve_cache(cfg, b, s))
    return {"params": params, "token": sds((b, 1)), "cache": cache}
