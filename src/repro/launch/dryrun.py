import os
if __name__ == "__main__":
    # the CLI's 512 virtual devices; guarded so merely importing this
    # module never mutates the process environment (import-time side
    # effects are banned -- repro.analysis REPRO005)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, and extract the roofline terms from the compiled HLO.

As a CLI (``python -m repro.launch.dryrun``) the XLA_FLAGS mutation above
runs before ANY other import (jax locks the device count on first init);
programmatic users must set XLA_FLAGS themselves before importing jax.
Never import this module from tests/benches.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell this produces <out>/<arch>__<shape>__<mesh>.json with:
  memory_analysis (bytes/device), cost_analysis (flops, bytes accessed),
  collective bytes by kind (parsed from the optimized HLO), lowering and
  compile wall-times -- benchmarks/bench_roofline.py turns these into the
  EXPERIMENTS.md roofline table.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.distributed import sharding as shd
from repro.distributed.act_constraints import clear_policy, set_policy
from repro.distributed.quantization import dtype_nbits
from repro.launch.input_specs import arch_for_cell, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.train.loop import make_train_step
from repro.train.optimizer import adam

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,4096]{1,0}' -> byte count (handles tuple shapes).

    Dtype widths come from the shared
    :func:`repro.distributed.quantization.dtype_nbits` HLO short-name map
    (one table for HLO dumps, device arrays, and sub-byte packed operands);
    unknown short names are skipped, matching its lookup contract.
    """
    total_bits = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        try:
            nbits = dtype_nbits(dt)
        except (KeyError, TypeError):
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_bits += n * nbits
    return (total_bits + 7) // 8


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO,
    split by enclosing computation kind.

    XLA's cost/HLO accounting counts a while-loop body ONCE regardless of
    trip count, so collectives are attributed to 'entry' (top-level module,
    executed once per step) vs 'loop' (inside a while/scan body, executed
    trip-count times).  benchmarks/bench_roofline.py multiplies the 'loop'
    bucket by the recorded trip hints and applies ring factors ((n-1)/n per
    all-gather/reduce-scatter, 2(n-1)/n per all-reduce); here we record raw
    payload bytes.
    """
    def empty():
        return {k: 0 for k in _COLLECTIVES}
    out = {"entry": empty(), "loop": empty()}
    counts = {"entry": empty(), "loop": empty()}
    bucket = "entry"
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            bucket = "entry"
            continue
        mc = re.match(r"%?(\S+)\s*\([^)]*\)\s*->", ls)  # computation header
        if mc and "=" not in ls.split("(")[0]:
            name = mc.group(1)
            bucket = "loop" if ("while" in name or "body" in name
                                or "cond" in name or "scan" in name) \
                else "entry"
            continue
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if m:
            out[bucket][m.group(2)] += _shape_bytes(m.group(1))
            counts[bucket][m.group(2)] += 1
    total = {k: out["entry"][k] + out["loop"][k] for k in _COLLECTIVES}
    return {"bytes": total, "entry_bytes": out["entry"],
            "loop_bytes": out["loop"], "counts": counts}


def trip_hints(cfg, sh, arch: str) -> dict:
    """Static trip counts of the scans in this cell's program -- needed to
    de-bias cost_analysis / per-loop collective counts (XLA counts loop
    bodies once).  layer_trips = executions of the (innermost) layer body
    per microbatch; accum = microbatch scan trips."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        layer_trips = cfg.n_layers
    elif fam == "vlm":
        layer_trips = cfg.n_layers          # inner text scan x outer groups
    elif fam == "audio":
        layer_trips = cfg.n_layers + cfg.enc_layers
    elif fam == "ssm":
        layer_trips = cfg.n_layers // 2     # scan over (mLSTM,sLSTM) pairs
    else:                                   # hybrid
        layer_trips = cfg.n_layers
    accum = 1
    if sh["kind"] == "train":
        # fit-constrained accum (EXPERIMENTS.md deep-dive 1: 405B at
        # accum=8 reaches fraction 0.90 but 24.8 GiB > v5e HBM; accum=16
        # fits at 16.0 GiB with fraction ~0.71)
        accum = {"llama3-405b": 16, "qwen3-32b": 8,
                 "qwen3-moe-30b-a3b": 8, "granite-3-8b": 8,
                 "zamba2-2.7b": 8, "llama3.2-3b": 8, "xlstm-350m": 8,
                 "phi3.5-moe-42b-a6.6b": 8}.get(arch, 4)
    inner = 1
    if sh["kind"] in ("train", "prefill"):
        if cfg.vq_attn:
            inner = max(1, sh["seq_len"] // cfg.vq_window)
        else:
            inner = max(1, sh["seq_len"] // 1024)   # query-chunk scan
    return {"layer_trips": layer_trips, "accum": accum,
            "inner_attn_trips": inner}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, force_vq: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + ("__vq" if force_vq
                                                      else "")
    t_start = time.time()
    base_cfg = ARCHS[arch]
    if force_vq:
        base_cfg = base_cfg.with_vq()
    cfg = arch_for_cell(base_cfg, shape_name)
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = shd.strategy_for(cfg, mesh)
    from repro.launch.mesh import dp_axes
    if sh["kind"] in ("train", "prefill") and strategy in ("tp_fsdp",
                                                           "moe_ep_dp"):
        set_policy(mesh, dp_axes(mesh))
    else:
        clear_policy()

    specs = input_specs(base_cfg, shape_name)

    if sh["kind"] == "train":
        # microbatch so per-device activations fit (DESIGN.md section 5)
        # fit-constrained accum (EXPERIMENTS.md deep-dive 1: 405B at
        # accum=8 reaches fraction 0.90 but 24.8 GiB > v5e HBM; accum=16
        # fits at 16.0 GiB with fraction ~0.71)
        accum = {"llama3-405b": 16, "qwen3-32b": 8,
                 "qwen3-moe-30b-a3b": 8, "granite-3-8b": 8,
                 "zamba2-2.7b": 8, "llama3.2-3b": 8, "xlstm-350m": 8,
                 "phi3.5-moe-42b-a6.6b": 8}.get(arch, 4)
        opt = adam(moment_dtype=jnp.bfloat16)
        step = make_train_step(cfg, opt, accum=accum,
                               accum_dtype=jnp.bfloat16)
        state_sh = type(specs["state"])(
            params=shd.param_shardings(specs["state"].params, cfg, mesh,
                                       strategy),
            opt=type(specs["state"].opt)(
                step=shd.replicated(mesh),
                mu=shd.param_shardings(specs["state"].opt.mu, cfg, mesh,
                                       strategy),
                nu=shd.param_shardings(specs["state"].opt.nu, cfg, mesh,
                                       strategy)),
            step=shd.replicated(mesh))
        tok_sh = shd.token_sharding(sh["global_batch"], mesh, cfg, strategy)
        args = [specs["state"], specs["tokens"]]
        in_shardings = [state_sh, tok_sh]
        if "aux_embeds" in specs:
            args.append(specs["aux_embeds"])
            in_shardings.append(shd.token_sharding(
                sh["global_batch"], mesh, cfg, strategy))
        fn = jax.jit(step,
                     in_shardings=tuple(in_shardings),
                     out_shardings=(state_sh, shd.replicated(mesh)))

    elif sh["kind"] == "prefill":
        p_sh = shd.param_shardings(specs["params"], cfg, mesh, strategy)
        tok_sh = shd.token_sharding(sh["global_batch"], mesh, cfg, strategy)
        args = [specs["params"], specs["tokens"]]
        in_shardings = [p_sh, tok_sh]
        if "aux_embeds" in specs:
            args.append(specs["aux_embeds"])
            in_shardings.append(shd.token_sharding(
                sh["global_batch"], mesh, cfg, strategy))

        def pf(params, tokens, aux=None):
            return lm.prefill(params, tokens, cfg, aux)
        fn = jax.jit(pf, in_shardings=tuple(in_shardings),
                     out_shardings=shd.replicated(mesh))

    else:  # decode
        p_sh = shd.param_shardings(specs["params"], cfg, mesh, strategy)
        c_sh = shd.cache_shardings(specs["cache"], cfg, mesh,
                                   sh["global_batch"], sh["seq_len"])
        tok_sh = shd.token_sharding(sh["global_batch"], mesh, cfg, strategy)

        def sv(params, token, cache):
            return lm.serve_step(params, token, cache, cfg)
        fn = jax.jit(sv, in_shardings=(p_sh, tok_sh, c_sh),
                     out_shardings=(shd.replicated(mesh), c_sh))
        args = [specs["params"], specs["token"], specs["cache"]]

    with mesh:
        t0 = time.time()
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "strategy": strategy,
        "kind": sh["kind"], "seq_len": sh["seq_len"],
        "global_batch": sh["global_batch"],
        "vq_attn": cfg.vq_attn,
        "param_count": cfg.param_count(),
        "trip_hints": trip_hints(cfg, sh, arch),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "cost": {k: cost.get(k, 0.0) for k in
                 ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "wall_s": round(time.time() - t_start, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--vq", action="store_true",
                    help="force VQ-Attention for the cell (perf variants)")
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(args.out, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {cell}")
                    continue
                try:
                    r = run_cell(arch, shape_name, mp, args.out,
                                 force_vq=args.vq)
                    print(f"[ok]   {cell}  flops={r['cost']['flops']:.3e} "
                          f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB "
                          f"compile={r['compile_s']}s")
                except Exception as e:  # noqa: BLE001
                    failures.append((cell, repr(e)))
                    print(f"[FAIL] {cell}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for c, e in failures:
            print(" ", c, e)
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
