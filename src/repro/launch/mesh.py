"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before the first jax
device query, and smoke tests must keep seeing 1 device.

Topology (TPU v5e target):
  single-pod: (16, 16)    = ("data", "model")   -- 256 chips
  multi-pod:  (2, 16, 16) = ("pod", "data", "model") -- 512 chips, the
              "pod" axis composes with "data" for DP/FSDP so adding pods
              widens the FSDP axis (elastic posture: shardings are written
              against axis NAMES, so any pod count re-binds cleanly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axes_size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
