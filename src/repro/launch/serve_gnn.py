"""GNN serving endpoint: codeword-context inference as a traffic-shaped
service (the paper's Sec. 6 claim -- sampling baselines need the O(d^L)
L-hop neighborhood per request, VQ-GNN serves a request batch with O(b)
work -- finally exercised by an actual request loop).

    PYTHONPATH=src python -m repro.launch.serve_gnn --n 2000 --batch 256 \
        --requests 200 [--mesh 2] [--train-epochs 3] [--json out.json]

The server keeps params, per-layer VQ states, node features, and the
pack-once :class:`~repro.graph.batching.EpochPlan` device-resident.  Start
up = one `refresh` pass of the inference executor with feature-half
assignment (``vq_infer_epoch(inductive=True)``) so every node -- including
nodes unseen at train time -- holds a fresh codeword, then ONE compile of
the serve step (``models.gnn.vq_serve_batch``: in-jit ``plan_batch`` +
all-layer codeword forward).  After that the request loop never compiles:
requests are coalesced onto the static [batch] shape by the micro-batcher
(small requests share a step, large requests span several), and the report
gives nodes/s throughput plus p50/p99 step and request latency.

``--mesh N`` shards the micro-batch axis over a 1-axis "data" mesh
(``sharding.graph_dp_mesh`` + ``sharding.serve_batch_spec``): ids placed
with the serve spec let jit's SPMD partitioner split the per-request
gathers and forward across devices while plan/codebooks stay replicated.

``--mesh N --shard-graph`` flips the mesh from a throughput knob to a
CAPACITY knob (DESIGN.md section 14): the EpochPlan, feature table, and
per-layer activation tables are row-sharded over the mesh
(``ShardedGraphState``), per-batch rows are cross-shard-gathered, and
peak per-device graph-state bytes drop ~1/N -- the served graph can
outgrow a single device's HBM.  The report's
``graph_state_bytes_per_device`` records exactly that.
"""
from __future__ import annotations

import argparse
import json
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.codebook import CodebookConfig
from repro.distributed import sharding as shd
from repro.distributed.quantization import tree_bytes
from repro.graph.batching import (build_epoch_plan, full_operands,
                                  inference_slices)
from repro.graph.structure import Graph
from repro.kernels import ops as kops
from repro.models.gnn import (GNNConfig, _layer_out_dims, init_gnn,
                              init_vq_states, quantize_vq_states,
                              vq_infer_epoch, vq_serve_batch)


class GNNServer:
    """Device-resident serving state + the precompiled O(b) serve step."""

    def __init__(self, g: Graph, cfg: GNNConfig, params, vq_states,
                 batch: int, mesh: Optional[Mesh] = None,
                 shard_graph: bool = False):
        if batch > g.n:
            batch = g.n            # the id pool bounds a useful micro-batch
        if mesh is not None and not shard_graph \
                and batch % mesh.shape["data"] != 0:
            # SPMD throughput mode splits the batch axis; the sharded-
            # state mode replicates the request ids (rows go cross-shard
            # instead) so any batch size serves
            raise ValueError(
                f"serve micro-batch {batch} is not divisible by the "
                f"{mesh.shape['data']}-device data mesh")
        if shard_graph and mesh is None:
            raise ValueError(
                "shard_graph=True row-shards the graph state over a "
                "mesh -- pass mesh= (graph_dp_mesh) as well")
        self.g, self.cfg, self.batch = g, cfg, batch
        self.mesh = mesh
        self.ops = full_operands(g)
        self.plan = build_epoch_plan(g, full_ops=self.ops)
        self.x = jnp.asarray(g.features)
        self.params = params
        self.vq = list(vq_states)
        self.f_out = _layer_out_dims(cfg)[-1][1]
        self.sstate = None
        if shard_graph:
            from repro.distributed.data_parallel import ShardedGraphState
            self.sstate = ShardedGraphState(mesh, self.plan, self.x,
                                            self.ops.degrees)
            # the replicated copies exist only transiently at build time
            # on a real multi-host deployment; here they back _evaluate-
            # style offline use and the bench's replicated-vs-sharded
            # byte comparison
        self.ids_sharding = None if mesh is None or shard_graph else \
            NamedSharding(mesh, shd.serve_batch_spec())

    def graph_state_bytes_per_device(self) -> int:
        """Peak per-device bytes of the serving graph state (plan +
        features + degrees): the --mesh capacity metric."""
        if self.sstate is not None:
            return self.sstate.per_device_bytes()
        return int(sum(
            v.nbytes for v in (self.plan.nbr_ids, self.plan.nbr_mask,
                               self.plan.rev_ids, self.plan.rev_mask,
                               self.x, self.ops.degrees)))

    def refresh(self) -> float:
        """Refresh every layer's codeword assignment from the current
        features via the inference executor's in-jit feature-half
        assignment (paper Sec. 6 inductive machinery) -- the serving
        analogue of loading fresh historical embeddings.  Returns wall
        seconds (includes the executor's O(n_layers) compiles)."""
        t0 = time.time()
        ids, sm = inference_slices(self.g.n, self.batch)
        if self.sstate is not None:
            from repro.distributed.data_parallel import \
                vq_infer_epoch_sharded
            _, self.vq = vq_infer_epoch_sharded(
                self.sstate, self.params, self.vq,
                jnp.asarray(ids.astype(np.int32)), jnp.asarray(sm),
                self.cfg, inductive=True)
        else:
            _, self.vq = vq_infer_epoch(
                self.params, self.vq, self.plan,
                jnp.asarray(ids.astype(np.int32)), jnp.asarray(sm),
                self.x, self.ops.degrees, self.cfg, inductive=True)
        jax.block_until_ready(self.vq)
        return time.time() - t0

    def warmup(self) -> float:
        """Compile the serve step on the static batch shape; returns wall
        seconds of the (single) compile."""
        t0 = time.time()
        self.step(np.zeros(self.batch, np.int64))
        return time.time() - t0

    def step(self, bids: np.ndarray) -> np.ndarray:
        """One device step over exactly ``batch`` node-id slots."""
        if len(bids) != self.batch:
            # a hard error, not an assert: a wrong-sized id vector would
            # otherwise silently retrace the jitted step on the hot path
            # and defeat the warm single-compile contract
            raise ValueError(
                f"serve step needs exactly {self.batch} id slots, got "
                f"{len(bids)} (use serve() for arbitrary request sizes)")
        ids_d = jnp.asarray(bids.astype(np.int32))
        if self.sstate is not None:
            from repro.distributed.data_parallel import \
                vq_serve_batch_sharded
            y = vq_serve_batch_sharded(self.sstate, self.params, self.vq,
                                       ids_d, self.cfg)
            return np.asarray(y)
        if self.ids_sharding is not None:
            ids_d = jax.device_put(ids_d, self.ids_sharding)
        y = vq_serve_batch(self.params, self.vq, self.plan, ids_d, self.x,
                           self.ops.degrees, self.cfg)
        return np.asarray(y)

    def serve(self, node_ids: np.ndarray) -> np.ndarray:
        """Serve one request of arbitrary size (pads the tail step by
        repeating id 0; duplicate ids are safe, see ``vq_serve_batch``)."""
        node_ids = np.asarray(node_ids)
        if len(node_ids) == 0:
            return np.zeros((0, self.f_out), np.float32)
        outs = []
        for s in range(0, len(node_ids), self.batch):
            chunk = node_ids[s:s + self.batch]
            pad = self.batch - len(chunk)
            step_ids = np.concatenate(
                [chunk, np.zeros(pad, chunk.dtype)]) if pad else chunk
            outs.append(self.step(step_ids)[:len(chunk)])
        return np.concatenate(outs, axis=0)


def drain_requests(server: GNNServer, requests: Sequence[np.ndarray]
                   ) -> dict:
    """Closed-loop micro-batching drain: every queued request contributes
    slots to the next static [batch] step until the step is full (a small
    request shares its step with neighbors in the queue; a large request
    spills over several steps).  A request completes when its last slot's
    step returns; latency is measured against the drain start (all
    requests enqueued at t0 -- the worst-case, queueing-inclusive view).
    """
    b = server.batch
    pend = deque((i, np.asarray(r, np.int64)) for i, r in enumerate(requests))
    remaining = [len(np.asarray(r)) for r in requests]
    done = np.zeros(len(requests))
    step_lat: list[float] = []
    n_nodes = 0
    t0 = time.time()
    while pend:
        slots, members, filled = [], [], 0
        while pend and filled < b:
            i, ids = pend.popleft()
            take = min(b - filled, len(ids))
            slots.append(ids[:take])
            members.append((i, take))
            filled += take
            if take < len(ids):
                pend.appendleft((i, ids[take:]))
        flat = np.concatenate(slots)
        if filled < b:
            flat = np.concatenate([flat, np.zeros(b - filled, np.int64)])
        ts = time.time()
        server.step(flat)
        now = time.time()
        step_lat.append(now - ts)
        n_nodes += filled
        for i, take in members:               # O(1) completion tracking
            remaining[i] -= take
            if remaining[i] == 0:             # last spill completed
                done[i] = now - t0
    wall = time.time() - t0
    lat = np.sort(done)
    sl = np.sort(np.asarray(step_lat))

    def pct(a, q):
        return float(a[min(len(a) - 1, int(q * len(a)))]) if len(a) else 0.0
    return {
        "requests": len(requests), "steps": len(step_lat),
        "nodes": int(n_nodes), "wall_s": wall,
        "nodes_per_s": n_nodes / max(wall, 1e-9),
        "requests_per_s": len(requests) / max(wall, 1e-9),
        "step_p50_ms": pct(sl, 0.50) * 1e3,
        "step_p99_ms": pct(sl, 0.99) * 1e3,
        "request_p50_ms": pct(lat, 0.50) * 1e3,
        "request_p99_ms": pct(lat, 0.99) * 1e3,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256,
                    help="static serve micro-batch (node slots per step)")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-request", type=int, default=64,
                    help="request sizes ~ U[1, max-request] nodes")
    ap.add_argument("--backbone", default="gcn",
                    choices=["gcn", "sage", "gat", "gin", "transformer"])
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--train-epochs", type=int, default=0,
                    help="optional warm training before serving "
                    "(0 = serve from init + assignment refresh)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the micro-batch over an N-device data mesh")
    ap.add_argument("--shard-graph", action="store_true",
                    help="with --mesh N: row-shard the graph state over "
                    "the mesh (capacity mode -- per-device graph bytes "
                    "drop ~1/N, DESIGN.md section 14)")
    ap.add_argument("--precision", default="fp32",
                    choices=list(kops.PRECISIONS),
                    help="kernel operand precision tier: int8/fp8 serve "
                    "uint8 assignment tables + int8/fp8 codeword "
                    "snapshots; the '+a4' variants nibble-pack the "
                    "assignment tables (k <= 16, 2 ids/byte) "
                    "(DESIGN.md sections 13 and 15)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    from repro.graph.datasets import synthetic_arxiv
    g = synthetic_arxiv(n=args.n, seed=args.seed)
    cfg = GNNConfig(backbone=args.backbone, f_in=g.f, hidden=args.hidden,
                    n_out=g.num_classes, n_layers=args.layers,
                    codebook=CodebookConfig(k=args.k, f_prod=4))
    kops.configure_kernel_precision(args.precision)
    if args.train_epochs > 0:
        from repro.train.gnn_trainer import train_vq
        r = train_vq(g, cfg, epochs=args.train_epochs,
                     batch_size=args.batch, eval_every=args.train_epochs)
        params, vq = r["params"], r["vq_states"]
    else:
        params = init_gnn(jax.random.PRNGKey(args.seed), cfg)
        vq = init_vq_states(jax.random.PRNGKey(args.seed + 1), cfg, g.n)
    if args.precision != "fp32":
        vq = quantize_vq_states(vq, cfg, precision=args.precision)

    mesh = shd.graph_dp_mesh(args.mesh) if args.mesh else None
    server = GNNServer(g, cfg, params, vq, args.batch, mesh=mesh,
                       shard_graph=args.shard_graph)
    t_refresh = server.refresh()
    t_warm = server.warmup()

    rng = np.random.default_rng(args.seed)
    sizes = rng.integers(1, args.max_request + 1, args.requests)
    requests = [rng.integers(0, g.n, sz) for sz in sizes]
    report = drain_requests(server, requests)
    report.update({"graph_n": g.n, "batch": server.batch,
                   "backbone": args.backbone,
                   "mesh": args.mesh or 1,
                   "shard_graph": bool(args.shard_graph),
                   "graph_state_bytes_per_device":
                       server.graph_state_bytes_per_device(),
                   "precision": args.precision,
                   "vq_state_bytes": int(sum(
                       tree_bytes((s.assignment,) if s.qcw is None
                                  else (s.assignment, s.qcw))
                       for s in server.vq)),
                   "refresh_s": t_refresh, "warmup_s": t_warm})

    print(f"serve_gnn {args.backbone} n={g.n} batch={server.batch} "
          f"mesh={report['mesh']}"
          f"{' (row-sharded graph state)' if args.shard_graph else ''} "
          f"precision={args.precision} "
          f"(vq operand bytes {report['vq_state_bytes']}, graph state "
          f"{report['graph_state_bytes_per_device']} B/device): "
          f"refresh {t_refresh:.2f}s, warm compile {t_warm:.2f}s")
    print(f"  {report['nodes']} nodes / {report['requests']} requests in "
          f"{report['wall_s']:.3f}s -> {report['nodes_per_s']:.0f} nodes/s, "
          f"{report['requests_per_s']:.1f} req/s")
    print(f"  step   p50 {report['step_p50_ms']:.2f} ms   "
          f"p99 {report['step_p99_ms']:.2f} ms")
    print(f"  request p50 {report['request_p50_ms']:.2f} ms   "
          f"p99 {report['request_p99_ms']:.2f} ms")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
