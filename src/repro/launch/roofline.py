"""Roofline model: compute / memory / collective terms per (arch x shape).

Three sources, cross-checked (EXPERIMENTS.md section Roofline):
  1. analytic model (this file): exact closed-form FLOPs / HBM / collective
     bytes from the architecture, sharding strategy and shape -- the
     primary roofline numbers;
  2. compiled.cost_analysis() from the dry-run -- recorded raw, then
     trip-corrected (XLA counts while bodies once; the dry-run JSON stores
     the static trip counts per cell);
  3. collective payloads parsed from the optimized HLO, split entry/loop
     and trip-corrected, with ring factors applied here.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (1D-ring effective per-chip bandwidth along one axis;
2D-mesh collectives that split over both axes get 2 links).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import SHAPES, ArchConfig

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float         # global 6ND-style useful FLOPs
    hlo_flops: float           # per-device, trip-corrected
    flops_ratio: float         # model / (hlo * chips)
    bottleneck: str
    details: dict


# ---------------------------------------------------------------------------
# analytic FLOPs (global, per step)
# ---------------------------------------------------------------------------

def _attn_flops_per_layer(cfg: ArchConfig, s: int, b: int,
                          causal: bool = True) -> float:
    """QK^T + AV matmul FLOPs, forward, one layer."""
    if cfg.vq_attn:
        ctx = 2 * cfg.vq_window + cfg.vq_k
        return 2.0 * 2 * b * cfg.n_heads * s * ctx * cfg.hd
    factor = 0.5 if causal else 1.0
    return 2.0 * 2 * b * cfg.n_heads * s * s * cfg.hd * factor


def active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE: top-k experts only)."""
    if cfg.family != "moe":
        return float(cfg.param_count())
    d, ff = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
    per_layer = attn + 2 * d + d * cfg.n_experts \
        + cfg.top_k * 3 * d * ff
    return float(cfg.n_layers * per_layer + cfg.vocab * cfg.d_model * 2)


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    n_act = active_params(cfg)
    if sh["kind"] == "train":
        tokens = b * s
        matmul = 6.0 * n_act * tokens
        attn = 3.0 * cfg.n_layers * _attn_flops_per_layer(cfg, s, b)
        if cfg.family == "hybrid":
            attn = 3.0 * (cfg.n_layers // cfg.attn_period) * \
                _attn_flops_per_layer(cfg, s, b)
        if cfg.family in ("ssm",):
            attn = 3.0 * (cfg.n_layers // 2) * \
                _attn_flops_per_layer(cfg, s, b)    # mLSTM parallel form
        return matmul + attn
    if sh["kind"] == "prefill":
        tokens = b * s
        matmul = 2.0 * n_act * tokens
        attn = cfg.n_layers * _attn_flops_per_layer(cfg, s, b)
        return matmul + attn
    # decode: one token per sequence
    matmul = 2.0 * n_act * b
    if cfg.vq_attn:
        ctx = cfg.vq_k + cfg.vq_window
    else:
        ctx = s
    n_attn_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.attn_period
    if cfg.family == "ssm":
        # recurrent state update instead of attention
        return matmul + 2.0 * b * (cfg.n_layers // 2) * (
            3 * cfg.d_model * cfg.d_model)
    attn = 2.0 * 2 * b * cfg.n_heads * ctx * cfg.hd * n_attn_layers
    return matmul + attn


# ---------------------------------------------------------------------------
# analytic HBM traffic (per chip, per step)
# ---------------------------------------------------------------------------

def model_hbm_bytes(cfg: ArchConfig, shape_name: str, chips: int,
                    accum: int, strategy: str) -> float:
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    p_bytes = cfg.param_count() * 2            # bf16
    p_local = p_bytes / chips if strategy != "replicate" else p_bytes
    d = cfg.d_model

    if sh["kind"] == "train":
        # fwd+bwd weight reads per microbatch (remat: fwd again in bwd) +
        # grad write + optimizer read/write (bf16 moments x2)
        weight_traffic = p_local * (3 * accum + 1 + 4)
        act = 2 * (b * s / chips) * d * cfg.n_layers * 2 * 3
        return weight_traffic + act
    if sh["kind"] == "prefill":
        weight_traffic = p_local
        act = 2 * (b * s / chips) * d * cfg.n_layers * 2
        kv = 2 * (b * s / chips) * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers
        return weight_traffic + act + kv
    # decode: weights once + KV cache read once per token
    apar = active_params(cfg) * 2 / chips if strategy != "replicate" \
        else active_params(cfg) * 2
    if cfg.vq_attn:
        kv_tokens = cfg.vq_k + cfg.vq_window
    elif cfg.family == "ssm":
        kv_tokens = 0
    else:
        kv_tokens = s
    n_kv_layers = cfg.n_layers
    if cfg.family == "hybrid":
        n_kv_layers = cfg.n_layers // cfg.attn_period
    kv = 2 * (b / max(1, chips // max(1, _seq_shards(cfg, shape_name, chips)))
              ) * kv_tokens * cfg.n_kv_heads * cfg.hd * 2 * n_kv_layers
    # per chip: the cache is sharded over the mesh; total read = global/chips
    kv = 2 * b * kv_tokens * cfg.n_kv_heads * cfg.hd * 2 * n_kv_layers / chips
    state = 0.0
    if cfg.family in ("ssm", "hybrid"):
        state = 2 * b * cfg.n_layers * (2 * d) * max(cfg.ssm_state, 64) * 4 \
            / chips
    return apar + kv + state


def _seq_shards(cfg, shape_name, chips):
    return 1


# ---------------------------------------------------------------------------
# analytic collective traffic (per chip, per step)
# ---------------------------------------------------------------------------

def model_collective_bytes(cfg: ArchConfig, shape_name: str, chips: int,
                           tp: int, dp: int, accum: int,
                           strategy: str) -> float:
    """Ring-model bytes crossing each chip's ICI links per step."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    d = cfg.d_model
    p_bytes = cfg.param_count() * 2

    if sh["kind"] == "train":
        # data-parallel gradient all-reduce: 2 (n-1)/n x grad bytes/shard
        grad_ar = 2.0 * (dp - 1) / dp * p_bytes / tp
        tok_total = b * s / dp     # tokens passing each chip per STEP
        if strategy == "tp_fsdp":
            # FSDP param all-gather per microbatch (fwd + bwd re-gather)
            fsdp_ag = 2 * accum * (dp - 1) / dp * p_bytes / tp
            # Megatron TP: 2 all-reduces (attn out, mlp out) fwd + 2 bwd
            # per layer; the whole batch's tokens cross once per step
            # (accum only re-gathers params, it does not add token traffic)
            tp_ar = (cfg.n_layers * 4 *
                     2.0 * (tp - 1) / tp * tok_total * d * 2)
            return grad_ar + fsdp_ag + tp_ar
        if strategy == "moe_ep_dp":
            fsdp_ag = 2 * accum * (dp - 1) / dp * p_bytes / tp
            # one combine all-reduce per MoE layer over the token block
            ep_ar = (cfg.n_layers * 2.0 * (tp - 1) / tp * tok_total * d * 2)
            return grad_ar + fsdp_ag + ep_ar
        if strategy == "fsdp":
            fsdp_ag = 2 * accum * (chips - 1) / chips * p_bytes
            return grad_ar + fsdp_ag
        return 2.0 * (chips - 1) / chips * p_bytes   # replicated DP
    if sh["kind"] == "prefill":
        tok_local = b * s / dp
        if strategy == "tp_fsdp":
            return cfg.n_layers * 2 * 2.0 * (tp - 1) / tp * tok_local * d * 2 \
                + (dp - 1) / dp * p_bytes / tp
        if strategy == "moe_ep_dp":
            return cfg.n_layers * 2.0 * (tp - 1) / tp * tok_local * d * 2 \
                + (dp - 1) / dp * p_bytes / tp
        return (chips - 1) / chips * p_bytes
    # decode
    b_local = max(1.0, b / dp)
    if strategy == "tp_fsdp":
        # 2 all-reduces per layer on [b_local, 1, d]
        return cfg.n_layers * 2 * 2.0 * (tp - 1) / tp * b_local * d * 2
    if strategy == "moe_ep_dp":
        return cfg.n_layers * 2.0 * (tp - 1) / tp * b_local * d * 2
    if strategy == "fsdp":
        return (chips - 1) / chips * active_params(cfg) * 2
    return 0.0


# ---------------------------------------------------------------------------
# assemble terms
# ---------------------------------------------------------------------------

def terms_from_cell(cell: dict[str, Any], cfg: ArchConfig) -> RooflineTerms:
    chips = 512 if cell["mesh"] == "pod2x16x16" else 256
    tp = 16
    dp = chips // tp
    hints = cell.get("trip_hints", {})
    accum = hints.get("accum", 1)
    layer_trips = hints.get("layer_trips", cfg.n_layers)
    inner = hints.get("inner_attn_trips", 1)
    strategy = cell["strategy"]
    shape_name = cell["shape"]

    mf = model_flops(cfg, shape_name)
    compute_s = mf / (chips * PEAK_FLOPS)

    hbm = model_hbm_bytes(cfg, shape_name, chips, accum, strategy)
    memory_s = hbm / HBM_BW

    coll = model_collective_bytes(cfg, shape_name, chips, tp, dp, accum,
                                  strategy)
    collective_s = coll / ICI_BW

    # trip-corrected HLO flops (per device)
    raw = cell["cost"]["flops"]
    hlo_flops = raw * layer_trips * accum
    # HLO collectives, trip-corrected, ring factors
    cb = cell["collectives"]
    loop = cb.get("loop_bytes", cb["bytes"])
    entry = cb.get("entry_bytes", {k: 0 for k in loop})
    ring = {"all-gather": (tp - 1) / tp, "reduce-scatter": (tp - 1) / tp,
            "all-reduce": 2 * (tp - 1) / tp, "all-to-all": 1.0 / tp,
            "collective-permute": 1.0}
    hlo_coll = sum(ring[k] * (entry.get(k, 0) +
                              loop.get(k, 0) * layer_trips * accum)
                   for k in ring)

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops=hlo_flops,
        flops_ratio=mf / max(hlo_flops * chips, 1.0),
        bottleneck=bottleneck,
        details={"hbm_bytes": hbm, "coll_bytes": coll,
                 "hlo_coll_bytes": hlo_coll, "chips": chips,
                 "accum": accum, "layer_trips": layer_trips,
                 "inner_attn_trips": inner,
                 "step_time_bound_s": max(terms.values()),
                 "roofline_fraction": compute_s / max(terms.values())})
