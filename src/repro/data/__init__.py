"""repro subpackage."""
