"""Deterministic synthetic token pipeline (sharding- and restart-aware).

Sequences are generated from a seeded per-shard Markov chain over the vocab
(structured enough that a small LM's loss visibly falls).  The stream is
indexed by (epoch, step, shard): any host can regenerate any batch shard
independently -- this is what makes checkpoint/restart and *elastic
re-sharding* trivial: a resumed run with a different host count replays the
exact same global batch sequence (DESIGN.md section 5, fault tolerance).
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np


class TokenStreamConfig(NamedTuple):
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 3          # Markov order of the synthetic language


def _mix(seed: int, *vals: int) -> np.random.Generator:
    h = int(seed)
    for v in vals:
        h = ((h ^ int(v)) * 0x100000001B3) % (1 << 64)
    return np.random.default_rng(h)


def batch_shard(cfg: TokenStreamConfig, step: int, shard: int,
                n_shards: int) -> np.ndarray:
    """The `shard`-th slice of global batch `step`: [B/n_shards, S] int32.

    Pure function of (cfg.seed, step, row index) -- identical global batches
    regardless of how many hosts split them.
    """
    assert cfg.global_batch % n_shards == 0
    rows = cfg.global_batch // n_shards
    out = np.empty((rows, cfg.seq_len), np.int32)
    # the transition TABLE is global to the stream (derived from the seed
    # only): next = table[prev, noise], a lookup structure a small model
    # learns quickly (entropy floor ln(branch)); an arithmetic chain like
    # (a*prev+b) % V is a grokking task and stays at ln(V) for hundreds of
    # steps
    branch = 8
    table = _mix(cfg.seed, 0xC0EF).integers(
        0, cfg.vocab, (cfg.vocab, branch))
    for r in range(rows):
        grow = shard * rows + r
        rng = _mix(cfg.seed, step, grow)
        seq = np.empty(cfg.seq_len, np.int64)
        seq[0] = rng.integers(0, cfg.vocab)
        noise = rng.integers(0, branch, cfg.seq_len)
        for t in range(1, cfg.seq_len):
            seq[t] = table[seq[t - 1], noise[t]]
        out[r] = seq
    return out


def stream(cfg: TokenStreamConfig, start_step: int, shard: int,
           n_shards: int) -> Iterator[tuple[int, np.ndarray]]:
    """Resumable stream: yields (step, batch_shard) from `start_step`."""
    step = start_step
    while True:
        yield step, batch_shard(cfg, step, shard, n_shards)
        step += 1
