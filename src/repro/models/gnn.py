"""Full GNN models: assembly, losses, train steps, VQ mini-batch inference.

Three execution paths over one parameter set:
  * full-graph  -- the paper's oracle ("Full-Graph" rows of Table 4);
  * sampler     -- exact message passing on a sampled subgraph (baselines);
  * VQ          -- the paper's mini-batch algorithm (Alg. 1): approximated
                   message passing + probe-trick gradient taps + streaming
                   codebook/assignment refresh after every step.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import trace_count
from repro.core import codebook as cbm
from repro.core.codebook import CodebookConfig
from repro.core.conv import LayerVQState, MinibatchPack, init_layer_vq_state, \
    quantize_layer_state, refresh_assignment
from repro.distributed.collectives import gather_from_shards, psum_tree, \
    shard_scatter_rows
from repro.distributed.quantization import PackedAssignment
from repro.graph.batching import EpochPlan, FullGraphOperands, plan_batch, \
    plan_batch_sharded
from repro.kernels import ops as kops
from repro.nn.gnn_layers import BACKBONES
from repro.train.optimizer import Optimizer

Params = Any


class GNNConfig(NamedTuple):
    backbone: str = "gcn"
    f_in: int = 128
    hidden: int = 128
    n_out: int = 40
    n_layers: int = 3
    heads: int = 4
    task: str = "node"            # "node" | "link"
    multilabel: bool = False
    grad_inject: bool = True      # Eq. 7 out-of-batch gradient injection
    # (paper-faithful ON; our experiments find forward-VQ alone already
    # reaches parity while stale gradient codewords can add noise --
    # EXPERIMENTS.md "reproduction nuances")
    codebook: CodebookConfig = CodebookConfig(k=256, f_prod=4)

    def layer_dims(self) -> list[tuple[int, int]]:
        dims = []
        f = self.f_in
        for l in range(self.n_layers):
            last = l == self.n_layers - 1
            f_out = (self.n_out if (last and self.task == "node")
                     else self.hidden)
            dims.append((f, f_out))
            f = f_out
        return dims

    def layer_codebook_cfg(self) -> CodebookConfig:
        if self.backbone == "transformer":
            # dense learnable convolution needs full-width codewords
            return self.codebook._replace(f_prod=1 << 30)
        return self.codebook


def init_gnn(key: jax.Array, cfg: GNNConfig) -> list[Params]:
    bk = BACKBONES[cfg.backbone]
    keys = jax.random.split(key, cfg.n_layers)
    params = []
    for k, (fi, fo) in zip(keys, cfg.layer_dims()):
        if cfg.backbone in ("gat", "transformer") and fo % cfg.heads != 0:
            # widen the output of attention layers to a head multiple; a
            # final linear head maps to n_out
            fo = ((fo + cfg.heads - 1) // cfg.heads) * cfg.heads
        params.append(bk.init(k, fi, fo, heads=cfg.heads))
    return params


def _layer_out_dims(cfg: GNNConfig) -> list[tuple[int, int]]:
    dims = cfg.layer_dims()
    if cfg.backbone in ("gat", "transformer"):
        dims = [(fi, ((fo + cfg.heads - 1) // cfg.heads) * cfg.heads)
                for fi, fo in dims]
        fixed = []
        f = cfg.f_in
        for _, fo in dims:
            fixed.append((f, fo))
            f = fo
        return fixed
    return dims


def init_vq_states(key: jax.Array, cfg: GNNConfig,
                   n_nodes: int) -> list[LayerVQState]:
    bk = BACKBONES[cfg.backbone]
    cb_cfg = cfg.layer_codebook_cfg()
    states = []
    for i, (fi, fo) in enumerate(_layer_out_dims(cfg)):
        k = jax.random.fold_in(key, i)
        fg = bk.f_grad(fi, fo, heads=cfg.heads)
        states.append(init_layer_vq_state(k, n_nodes, fi, fg, cb_cfg))
    return states


def quantize_vq_states(vq_states: list[LayerVQState], cfg: GNNConfig,
                       precision: str | None = None) -> list[LayerVQState]:
    """Quantized serving conversion of the per-layer VQ states.

    ``precision`` is a tier from ``kops.PRECISIONS`` (default: the active
    ``kernel_precision()``; plain ``quantize_vq_states(vq, cfg)`` under the
    fp32 default keeps the historical behavior of the int8 tier).  Each
    layer gets a uint8 assignment table (k <= 256 -- the 4x VMEM win on
    the fused context kernel's resident table), nibble-packed two-ids-per-
    byte under the '+a4' tiers (k <= 16, 8x vs int32), and an attached
    QTensor codeword snapshot in the tier's storage dtype (int8 or
    float8_e4m3fn), so every context dispatch downstream consumes
    quantized operands (DESIGN.md sections 13/15).  Idempotent; the fp32
    codebook stays in place for updates and dense (GAT/transformer) reads.
    """
    if precision is None:
        p = kops.kernel_precision()
        precision = p if p != "fp32" else "int8"
    cw_dtype = kops.precision_codeword_dtype(precision)
    if cw_dtype is None:
        return list(vq_states)
    pack = kops.precision_packs_assignment(precision)
    cb_cfg = cfg.layer_codebook_cfg()
    if cb_cfg.k > 256:
        raise ValueError(
            f"quantized assignment tables need k <= 256, got k={cb_cfg.k}")
    if pack and cb_cfg.k > 16:
        raise ValueError(
            f"nibble-packed ('+a4') assignment tables need k <= 16, got "
            f"k={cb_cfg.k}; use precision={precision.split('+')[0]!r}")
    out = []
    for (fi, _), vq in zip(_layer_out_dims(cfg), vq_states):
        a = vq.assignment
        if isinstance(a, PackedAssignment):
            a = a if pack else a.unpack()
        else:
            a = a.astype(jnp.uint8)
            if pack:
                a = PackedAssignment.pack(a)
        st = vq._replace(assignment=a, qcw=None)
        out.append(quantize_layer_state(st, fi, cb_cfg, dtype=cw_dtype))
    return out


def probe_shapes(cfg: GNNConfig, b: int) -> list[tuple[int, ...]]:
    bk = BACKBONES[cfg.backbone]
    return [bk.probe_shape(b, fi, fo, heads=cfg.heads)
            for fi, fo in _layer_out_dims(cfg)]


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _act_for_layer(cfg: GNNConfig, l: int):
    last = l == cfg.n_layers - 1
    return (lambda z: z) if last else jax.nn.relu


def full_forward(params: list[Params], x: jax.Array,
                 ops_: FullGraphOperands, cfg: GNNConfig) -> jax.Array:
    bk = BACKBONES[cfg.backbone]
    for l, p in enumerate(params):
        x = bk.full_apply(p, x, ops_, _act_for_layer(cfg, l))
    return x


def vq_forward(params: list[Params], x_b: jax.Array,
               probes: Optional[list[jax.Array]],
               pack: MinibatchPack, vq_states: list[LayerVQState],
               degrees: jax.Array, cfg: GNNConfig,
               inject: Optional[bool] = None
               ) -> tuple[jax.Array, list[jax.Array]]:
    """Returns (output, per-layer input activations) -- the activations pair
    with the probe cotangents for the codebook update (Alg. 1 line 15).

    ``inject`` overrides ``cfg.grad_inject`` (the Eq. 7 custom-VJP wrapper);
    inference/eval passes False -- the injection only matters under
    ``jax.grad`` and its lazy residuals (message_passing.py) are a
    training-path contract, not an eval cost.  ``probes=None`` skips the
    probe taps entirely (gradient-free paths: inference executor, serving)
    instead of adding per-layer zero tensors.
    """
    bk = BACKBONES[cfg.backbone]
    cb_cfg = cfg.layer_codebook_cfg()
    inject = cfg.grad_inject if inject is None else inject
    acts = []
    x = x_b
    for l, (p, vq, (fi, fo)) in enumerate(
            zip(params, vq_states, _layer_out_dims(cfg))):
        acts.append(x)
        x = bk.vq_apply(p, x, None if probes is None else probes[l],
                        pack, vq, degrees, cb_cfg,
                        _act_for_layer(cfg, l), fi, fo, inject=inject)
    return x, acts


# ---------------------------------------------------------------------------
# losses / metrics
# ---------------------------------------------------------------------------

def node_loss_terms(logits: jax.Array, labels: jax.Array, multilabel: bool,
                    mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(numerator, denominator) of the masked-mean CE/BCE.

    The single-device loss is ``num / max(den, 1)``; the data-parallel
    epoch executor psums each term over the mesh axis before dividing so
    the sharded loss equals the full-batch masked mean exactly."""
    if multilabel:
        per = jnp.mean(
            jnp.maximum(logits, 0) - logits * labels +
            jnp.log1p(jnp.exp(-jnp.abs(logits))), axis=-1)
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        per = -jnp.take_along_axis(logp, labels[:, None], 1)[:, 0]
    return jnp.sum(per * mask), jnp.sum(mask)


def node_loss(logits: jax.Array, labels: jax.Array, multilabel: bool,
              mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean CE/BCE over (optionally masked) rows.  The mask implements the
    paper's transductive mini-batching: batches traverse ALL nodes (so every
    node's codeword assignment stays fresh) but only labeled nodes
    contribute to the loss."""
    if mask is None:
        mask = jnp.ones(logits.shape[0], logits.dtype)
    num, den = node_loss_terms(logits, labels, multilabel, mask)
    return num / jnp.maximum(den, 1.0)


def node_metric(logits: jax.Array, labels: jax.Array,
                multilabel: bool) -> jax.Array:
    if multilabel:   # micro-F1 at threshold 0
        pred = logits > 0
        tp = jnp.sum(pred * labels)
        return 2 * tp / jnp.maximum(jnp.sum(pred) + jnp.sum(labels), 1.0)
    return jnp.mean(jnp.argmax(logits, -1) == labels)


def link_loss(emb: jax.Array, pos: jax.Array, neg: jax.Array,
              pair_mask: Optional[jax.Array] = None) -> jax.Array:
    """emb indexed locally: pos/neg [e, 2] into emb rows.  pair_mask allows
    padding the pair lists to a static size (compile-once semantics)."""
    def score(pairs):
        return jnp.sum(emb[pairs[:, 0]] * emb[pairs[:, 1]], axis=-1)
    sp, sn = score(pos), score(neg)
    # stable BCE: log(1+e^z) = softplus(z) (log1p(exp(.)) overflows at init)
    lp, ln = jax.nn.softplus(-sp), jax.nn.softplus(sn)
    if pair_mask is None:
        return jnp.mean(lp) + jnp.mean(ln)
    m = jnp.maximum(pair_mask.sum(), 1.0)
    return jnp.sum(lp * pair_mask) / m + jnp.sum(ln * pair_mask) / m


def hits_at_k(pos_scores: np.ndarray, neg_scores: np.ndarray,
              k: int = 50) -> float:
    if len(pos_scores) == 0:
        # no positive pairs in the split: hits@k is 0 by convention (the
        # mean of an empty array would silently propagate NaN into the
        # metric history)
        return 0.0
    if len(neg_scores) < k:
        thresh = neg_scores.min() if len(neg_scores) else -np.inf
    else:
        thresh = np.sort(neg_scores)[-k]
    return float((pos_scores > thresh).mean())


# ---------------------------------------------------------------------------
# VQ train step (Alg. 1)
# ---------------------------------------------------------------------------

def _vq_step_body(params, vq_states, opt_state, pack: MinibatchPack,
                  x_b, labels_b, degrees, cfg: GNNConfig, opt: Optimizer,
                  loss_mask=None, neg_pairs=None, pos_pairs=None,
                  axis_name=None):
    """One Alg. 1 step, trace-level -- the ONE implementation behind the
    jit'd per-step entry point, the ``lax.scan`` epoch executor, and (with
    ``axis_name``) the shard_map data-parallel executor, so every path
    stays numerically consistent.

    With ``axis_name`` set (node task only), ``x_b``/``pack`` are this
    replica's shard of the batch and the replicas are glued into one model
    per step: the loss is the GLOBAL masked mean (num/den psum'd), param
    grads are psum'd before the optimizer, codebook (counts, sums) and
    whitening moments are psum'd inside ``cbm.update``, and the refreshed
    assignments are all-gathered into the replicated global table
    (DESIGN.md section 9, "codebook psum rule").
    """
    probes = [jnp.zeros(s, jnp.float32) for s in probe_shapes(cfg, pack.b)]
    if cfg.task == "node":
        lmask = loss_mask if loss_mask is not None \
            else jnp.ones((pack.b,), jnp.float32)
        den = jnp.sum(lmask)
        if axis_name is not None:
            den = jax.lax.psum(den, axis_name)   # independent of params
    else:
        assert axis_name is None, "dp epoch executor is node-task only"

    def loss_fn(params, probes):
        out, acts = vq_forward(params, x_b, probes, pack, vq_states,
                               degrees, cfg)
        if cfg.task == "node":
            num, _ = node_loss_terms(out, labels_b, cfg.multilabel, lmask)
            loss = num / jnp.maximum(den, 1.0)
        else:
            loss = link_loss(out, pos_pairs, neg_pairs)
        return loss, (acts, out)

    (loss, (acts, out)), (gparams, gprobes) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(params, probes)

    if axis_name is not None:
        loss = jax.lax.psum(loss, axis_name)
        gparams = psum_tree(gparams, axis_name)
    new_params, new_opt = opt.update(gparams, opt_state, params)

    # ---- Alg. 1 line 15-16: VQ update + assignment synchronization ----
    # cbm.update is fused (one distance pass per branch, codebook.py module
    # docstring); its UpdateStats also hands back the whitened-space VQ
    # relative error per layer, surfaced to the trainer as a free monitor.
    cb_cfg = cfg.layer_codebook_cfg()
    refresh_ids = pack.batch_ids
    if axis_name is not None:
        refresh_ids = jax.lax.all_gather(
            pack.batch_ids, axis_name).reshape(-1)
    new_states, vq_errs = [], []
    for l, vq in enumerate(vq_states):
        feats = acts[l].astype(jnp.float32)
        grads = gprobes[l].reshape(pack.b, -1).astype(jnp.float32)
        # gradients enter the codebook unscaled: Alg. 2's implicit whitening
        # normalizes every concat dim, so codebook geometry is invariant to
        # their magnitude and the EMA stats are fp32 (no fp-range guard)
        new_cb, stats = cbm.update(vq.codebook, feats, grads, cb_cfg,
                                   axis_name=axis_name)
        assign = stats.assignment
        if axis_name is None:
            vq_errs.append(stats.relative_error())
        else:
            a = jax.lax.all_gather(assign, axis_name)  # [ndev, nb, b_loc]
            assign = a.transpose(1, 0, 2).reshape(a.shape[1], -1)
            vq_errs.append(jnp.sqrt(
                jax.lax.psum(jnp.sum(stats.qerr), axis_name) /
                (jax.lax.psum(jnp.sum(stats.vnorm2), axis_name) + 1e-12)))
        st = refresh_assignment(
            LayerVQState(new_cb, vq.assignment, vq.counts, vq.qcw),
            refresh_ids, assign)
        if vq.qcw is not None:
            # quantize-on-update: rebuild the int8 codeword snapshot from
            # the post-EMA codebook; scales are reused inside the drift
            # band so barely-moving tables keep byte-stable int8 state
            st = quantize_layer_state(st, feats.shape[-1], cb_cfg)
        new_states.append(st)

    return new_params, new_states, new_opt, loss, out, jnp.stack(vq_errs)


@functools.partial(jax.jit, static_argnames=("cfg", "opt"))
def vq_train_step(params, vq_states, opt_state, pack: MinibatchPack,
                  x_b, labels_b, degrees, cfg: GNNConfig, opt: Optimizer,
                  loss_mask=None, neg_pairs=None, pos_pairs=None):
    return _vq_step_body(params, vq_states, opt_state, pack, x_b, labels_b,
                         degrees, cfg, opt, loss_mask=loss_mask,
                         neg_pairs=neg_pairs, pos_pairs=pos_pairs)


def _vq_epoch_body(params, vq_states, opt_state, plan: EpochPlan,
                   perm, slot_mask, x, labels, train_mask, degrees, *,
                   cfg: GNNConfig, opt: Optimizer, axis_name=None,
                   sharded_state=False, compress=False):
    """``lax.scan`` of ``_vq_step_body`` over the S stacked batches of a
    node permutation (trace-level; node task).  Each step slices its batch
    out of the pack-once :class:`~repro.graph.batching.EpochPlan`
    (``plan_batch``: row gather + node->slot scatter, no host round-trip).
    With ``axis_name`` this is the per-replica body of the shard_map
    data-parallel executor (``distributed/data_parallel.py``) and
    ``perm``/``slot_mask`` are the replica's [S, b/ndev] shard.

    With ``sharded_state`` additionally set (DESIGN.md section 14),
    ``plan``/``x``/``labels``/``train_mask`` are this shard's contiguous
    row BLOCK of the padded global node tables rather than full replicas:
    every per-batch row access goes cross-shard
    (``plan_batch_sharded`` + ``gather_from_shards``), while the step
    math -- psum'd grads/loss, codebook counts/sums/revival, assignment
    all-gather -- is byte-identical to the replicated DP path.
    ``compress`` routes the feature-row gather through the int8
    ``compressed_psum`` payload (lossy, opt-in)."""
    def body(carry, xs):
        params, vq, ost = carry
        bids, smask = xs
        if sharded_state:
            pack = plan_batch_sharded(plan, bids, axis_name, smask)
            x_b = gather_from_shards(x, bids, axis_name, compress=compress)
            labels_b = gather_from_shards(labels, bids, axis_name)
            lmask = gather_from_shards(train_mask, bids, axis_name) * smask
        else:
            pack = plan_batch(plan, bids, smask)
            x_b, labels_b = x[bids], labels[bids]
            lmask = train_mask[bids] * smask
        params, vq, ost, loss, _, errs = _vq_step_body(
            params, vq, ost, pack, x_b, labels_b, degrees, cfg,
            opt, loss_mask=lmask, axis_name=axis_name)
        return (params, vq, ost), (loss, errs)

    (params, vq_states, opt_state), (losses, vq_errs) = jax.lax.scan(
        body, (params, vq_states, opt_state), (perm, slot_mask))
    return params, vq_states, opt_state, losses, vq_errs


@functools.partial(jax.jit, static_argnames=("cfg", "opt"),
                   donate_argnames=("params", "vq_states", "opt_state"))
def vq_train_epoch(params, vq_states, opt_state, plan: EpochPlan,
                   perm: jax.Array, slot_mask: jax.Array, x, labels,
                   train_mask, degrees, cfg: GNNConfig, opt: Optimizer):
    """One epoch of Alg. 1 executed entirely on device (DESIGN.md sec. 9):
    one jit call scanning the per-step body over the stacked batches, with
    ``(params, vq_states, opt_state)`` carried in donated buffers.

    perm:       [S, b] int  node ids per batch (``epoch_slices``)
    slot_mask:  [S, b]      0 on wrap-padded tail slots (loss-masked)
    x / labels / train_mask: full [n, ...] device-resident arrays
    Returns (params, vq_states, opt_state, losses [S], vq_errs [S, L]).
    """
    return _vq_epoch_body(params, vq_states, opt_state, plan, perm,
                          slot_mask, x, labels, train_mask, degrees,
                          cfg=cfg, opt=opt)


@functools.partial(jax.jit, static_argnames=("cfg",))
def vq_eval_batch(params, vq_states, pack: MinibatchPack, x_b, degrees,
                  cfg: GNNConfig):
    out, _ = vq_forward(params, x_b, None, pack, vq_states, degrees, cfg,
                        inject=False)
    return out


# ---------------------------------------------------------------------------
# device-resident mini-batched inference (DESIGN.md section 11)
# ---------------------------------------------------------------------------

# Bumped at TRACE time of the jitted inference entry points.  The
# compile-count contract tests and the repro.analysis jaxpr pass pin the
# executor's promise on it: one inference pass costs n_layers layer traces
# (and a serve step one trace), independent of the batch count S and of
# whether the batch size divides n.  Re-exported here for compatibility;
# the counter itself lives in the shared telemetry module.
INFER_TRACE_COUNT = trace_count.INFER_TRACE_COUNT


def _vq_infer_layer_body(params_l, vq_state: LayerVQState, plan: EpochPlan,
                         perm, slot_mask, acts, degrees, *,
                         cfg: GNNConfig, layer: int) -> jax.Array:
    """One layer's sweep over ALL S batches as a single ``lax.scan``
    (trace-level).  Each step derives its pack in-jit from the pack-once
    plan (``plan_batch``), runs the probe-free codeword forward, and
    scatters the batch's output into the [n+1, f_out] activation table
    carried through the scan (in-place on device; the sacrificial row n
    absorbs wrap-padded tail slots so a node duplicated by the padding
    keeps its real-slot output).
    """
    INFER_TRACE_COUNT.bump("layer")
    bk = BACKBONES[cfg.backbone]
    cb_cfg = cfg.layer_codebook_cfg()
    fi, fo = _layer_out_dims(cfg)[layer]
    act = _act_for_layer(cfg, layer)
    n = plan.n

    def body(out, xs):
        bids, smask = xs
        pack = plan_batch(plan, bids, smask)
        y = bk.vq_apply(params_l, acts[bids], None, pack, vq_state,
                        degrees, cb_cfg, act, fi, fo, inject=False)
        dst = jnp.where(smask > 0, bids, n).astype(jnp.int32)
        return out.at[dst].set(y), None

    out0 = jnp.zeros((n + 1, fo), acts.dtype)
    out, _ = jax.lax.scan(body, out0, (perm, slot_mask))
    return out[:n]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "layer", "inductive"))
def vq_infer_layer(params_l, vq_state: LayerVQState, plan: EpochPlan,
                   perm: jax.Array, slot_mask: jax.Array, acts: jax.Array,
                   degrees, cfg: GNNConfig, layer: int,
                   inductive: bool = False
                   ) -> tuple[jax.Array, LayerVQState]:
    """Layer-locked mini-batched codeword inference for ONE layer, entirely
    on device: one jit call scanning all S batches (DESIGN.md section 11).

    perm:       [S, b] int  node ids per batch (``inference_slices``)
    slot_mask:  [S, b]      0 on wrap-padded tail slots (outputs discarded)
    acts:       [n, f_in]   every node's layer input (layer l-1 outputs)

    With ``inductive`` the feature-half codeword assignment of EVERY node
    is refreshed from ``acts`` before the sweep (paper Sec. 6: unseen nodes
    get their nearest codeword by feature distance) -- inside the same jit,
    so the inductive path costs zero extra host round-trips.  Returns the
    [n, f_out] output table and the (possibly refreshed) layer state.
    """
    if inductive:
        fi, _ = _layer_out_dims(cfg)[layer]
        assign = cbm.assign_features_only(
            vq_state.codebook, acts, fi, cfg.layer_codebook_cfg())
        vq_state = refresh_assignment(
            vq_state, jnp.arange(plan.n, dtype=jnp.int32), assign)
    out = _vq_infer_layer_body(params_l, vq_state, plan, perm, slot_mask,
                               acts, degrees, cfg=cfg, layer=layer)
    return out, vq_state


def vq_infer_epoch(params: list[Params], vq_states: list[LayerVQState],
                   plan: EpochPlan, perm: jax.Array, slot_mask: jax.Array,
                   x: jax.Array, degrees, cfg: GNNConfig, *,
                   inductive: bool = False
                   ) -> tuple[jax.Array, list[LayerVQState]]:
    """Whole-network layer-synchronous inference on the epoch executor:
    n_layers jit calls total (one ``vq_infer_layer`` scan per layer, so
    layer l+1 sees refreshed layer-l activations -- and, inductively,
    assignments -- for every node).  Compile count is O(n_layers),
    independent of S and of n % batch_size."""
    acts = x
    states = list(vq_states)
    for l in range(cfg.n_layers):
        acts, states[l] = vq_infer_layer(
            params[l], states[l], plan, perm, slot_mask, acts, degrees,
            cfg, l, inductive)
    return acts, states


@functools.partial(jax.jit, static_argnames=("cfg",))
def vq_serve_batch(params, vq_states, plan: EpochPlan, bids: jax.Array,
                   x: jax.Array, degrees, cfg: GNNConfig) -> jax.Array:
    """ONE-compile serving step: all-layer codeword forward for a request
    micro-batch of node ids (launch/serve_gnn.py).

    O(b) work per request -- in-jit ``plan_batch`` + feature-row gather +
    the probe-free ``vq_forward`` with codeword context standing in for
    every out-of-batch neighbor at every layer: no L-hop neighborhood
    expansion (the paper's Sec. 6 inference claim, served).  Duplicate ids
    (request padding / repeated requests) are safe: the node->slot scatter
    keeps one authoritative slot and all duplicate rows compute identical
    outputs.  Note the regime difference with :func:`vq_infer_epoch`: the
    serve step feeds layer l+1 with the batch's OWN layer-l outputs (for
    identical batch partitions the two coincide exactly; the executor is
    the layer-locked offline sweep, the serve step the online per-request
    form)."""
    INFER_TRACE_COUNT.bump("serve")
    pack = plan_batch(plan, bids.astype(jnp.int32))
    out, _ = vq_forward(params, x[bids], None, pack, vq_states, degrees,
                        cfg, inject=False)
    return out


# ---------------------------------------------------------------------------
# row-sharded inference / serving bodies (DESIGN.md section 14)
# ---------------------------------------------------------------------------

def _vq_infer_layer_body_sharded(params_l, vq_state: LayerVQState,
                                 plan: EpochPlan, perm, slot_mask, acts,
                                 degrees, *, cfg: GNNConfig, layer: int,
                                 axis_name: str, n_global: int,
                                 compress: bool = False) -> jax.Array:
    """Row-sharded twin of :func:`_vq_infer_layer_body` (shard_map body).

    ``plan``/``acts`` are this shard's row blocks of the padded global
    tables; ``perm``/``slot_mask`` are this shard's slice of the SCAN
    axis -- each shard sweeps S/ndev FULL batches per layer, so every
    batch computes with exact full-batch in-batch positions and the
    result is bit-identical to the replicated single-device executor
    while compute and activation storage both split ndev ways.  Batch
    outputs scatter cross-shard (``shard_scatter_rows``); wrap-padded
    and all-masked (scan-padding) slots are diverted to the sacrificial
    global row ``n_global``, which lives inside the padded table and is
    never read back.  Requires S padded to a multiple of ndev
    (all-masked batches) so the per-step collectives stay lockstep.
    """
    INFER_TRACE_COUNT.bump("layer")
    bk = BACKBONES[cfg.backbone]
    cb_cfg = cfg.layer_codebook_cfg()
    fi, fo = _layer_out_dims(cfg)[layer]
    act = _act_for_layer(cfg, layer)

    def body(out, xs):
        bids, smask = xs
        pack = plan_batch_sharded(plan, bids, axis_name, smask)
        x_b = gather_from_shards(acts, bids, axis_name, compress=compress)
        y = bk.vq_apply(params_l, x_b, None, pack, vq_state,
                        degrees, cb_cfg, act, fi, fo, inject=False)
        dst = jnp.where(smask > 0, bids, n_global).astype(jnp.int32)
        return shard_scatter_rows(out, dst, y, axis_name), None

    out0 = jnp.zeros((acts.shape[0], fo), acts.dtype)
    out, _ = jax.lax.scan(body, out0, (perm, slot_mask))
    return out


def _vq_infer_layer_sharded(params_l, vq_state: LayerVQState,
                            plan: EpochPlan, perm, slot_mask, acts,
                            degrees, *, cfg: GNNConfig, layer: int,
                            axis_name: str, n_global: int,
                            inductive: bool = False,
                            compress: bool = False
                            ) -> tuple[jax.Array, LayerVQState]:
    """Sharded twin of :func:`vq_infer_layer` (trace-level; the jit'd
    shard_map wrapper lives in ``distributed/data_parallel.py``).  The
    inductive refresh assigns each shard's LOCAL activation rows
    (``assign_features_only`` is purely row-wise: it whitens with the
    codebook's stored moments), all-gathers the per-shard assignment
    stripes into the replicated global table, and slices off the pad
    rows -- every shard derives the identical refreshed state, keeping
    the replicated-codebook invariant."""
    if inductive:
        fi, _ = _layer_out_dims(cfg)[layer]
        assign_loc = cbm.assign_features_only(
            vq_state.codebook, acts, fi, cfg.layer_codebook_cfg())
        a = jax.lax.all_gather(assign_loc, axis_name)  # [ndev, nb, n_loc]
        assign = a.transpose(1, 0, 2).reshape(a.shape[1], -1)[:, :n_global]
        vq_state = refresh_assignment(
            vq_state, jnp.arange(n_global, dtype=jnp.int32), assign)
    out = _vq_infer_layer_body_sharded(
        params_l, vq_state, plan, perm, slot_mask, acts, degrees, cfg=cfg,
        layer=layer, axis_name=axis_name, n_global=n_global,
        compress=compress)
    return out, vq_state


def _vq_serve_body_sharded(params, vq_states, plan: EpochPlan,
                           bids: jax.Array, x, degrees, cfg: GNNConfig, *,
                           axis_name: str, compress: bool = False
                           ) -> jax.Array:
    """Sharded twin of :func:`vq_serve_batch` (shard_map body): the
    request ids arrive REPLICATED, each shard cross-shard-gathers the
    batch's plan rows and feature rows from its block and then runs the
    identical full-batch probe-free forward -- exact parity with the
    unsharded serve step, with the mesh buying graph-state capacity
    (the O(b*L) serve compute is replicated; serve batches are tiny
    next to the [n, D] state this path exists to split)."""
    INFER_TRACE_COUNT.bump("serve")
    bids = bids.astype(jnp.int32)
    pack = plan_batch_sharded(plan, bids, axis_name)
    x_b = gather_from_shards(x, bids, axis_name, compress=compress)
    out, _ = vq_forward(params, x_b, None, pack, vq_states, degrees,
                        cfg, inject=False)
    return out


# ---------------------------------------------------------------------------
# full-graph / subgraph train steps (oracle + sampling baselines)
# ---------------------------------------------------------------------------

def _full_step_body(params, opt_state, x, ops_: FullGraphOperands,
                    labels, loss_mask, cfg: GNNConfig, opt: Optimizer,
                    neg_pairs=None, pos_pairs=None, pair_mask=None):
    """One exact-message-passing train step, trace-level -- the ONE
    implementation behind the jit'd per-(sub)graph entry point AND the
    ``lax.scan`` sampler epoch executor, mirroring ``_vq_step_body``."""
    def loss_fn(params):
        out = full_forward(params, x, ops_, cfg)
        if cfg.task == "node":
            return node_loss(out, labels, cfg.multilabel, loss_mask)
        return link_loss(out, pos_pairs, neg_pairs, pair_mask)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_opt = opt.update(grads, opt_state, params)
    return new_params, new_opt, loss


@functools.partial(jax.jit, static_argnames=("cfg", "opt"))
def full_train_step(params, opt_state, x, ops_: FullGraphOperands,
                    labels, loss_mask, cfg: GNNConfig, opt: Optimizer,
                    neg_pairs=None, pos_pairs=None, pair_mask=None):
    """loss_mask: [n] float weights over nodes (mask-based so padded
    subgraphs of a bucketed static size reuse one compilation)."""
    return _full_step_body(params, opt_state, x, ops_, labels, loss_mask,
                           cfg, opt, neg_pairs=neg_pairs,
                           pos_pairs=pos_pairs, pair_mask=pair_mask)


@functools.partial(jax.jit, static_argnames=("cfg", "opt"),
                   donate_argnames=("params", "opt_state"))
def sampler_train_epoch(params, opt_state, splan, x, labels,
                        cfg: GNNConfig, opt: Optimizer):
    """One sampling-baseline epoch entirely on device (DESIGN.md sec. 12):
    ``lax.scan`` of the exact-subgraph step over the S stacked batches of a
    :class:`~repro.graph.batching.SamplerEpochPlan`, with ``(params,
    opt_state)`` carried in donated buffers -- the sampler-side twin of
    ``vq_train_epoch``, so VQ-vs-sampling comparisons are
    executor-vs-executor.

    Each step slices its padded subgraph operands out of the plan, gathers
    the batch's features/labels from the full [n, ...] device tables
    in-jit, and runs the shared ``_full_step_body``.  Padding rows (empty
    neighbor lists, loss weight 0) gather node 0's row; they feed no
    messages into real rows and carry no loss, so their cotangents vanish
    identically.  Node task only (link pair mining is host-side).

    Returns (params, opt_state, losses [S]).
    """
    assert cfg.task == "node", "sampler epoch executor is node-task only"

    def body(carry, xs):
        params, ost = carry
        nid, nbr, nmask, deg, lmask = xs
        ops_ = FullGraphOperands(nbr_ids=nbr, nbr_mask=nmask, degrees=deg)
        params, ost, loss = _full_step_body(
            params, ost, x[nid], ops_, labels[nid], lmask, cfg, opt)
        return (params, ost), loss

    (params, opt_state), losses = jax.lax.scan(
        body, (params, opt_state),
        (splan.node_ids, splan.nbr_ids, splan.nbr_mask, splan.degrees,
         splan.loss_mask))
    return params, opt_state, losses


@functools.partial(jax.jit, static_argnames=("cfg",))
def full_predict(params, x, ops_: FullGraphOperands, cfg: GNNConfig):
    return full_forward(params, x, ops_, cfg)
