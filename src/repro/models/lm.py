"""Unified LM backbone covering the six assigned families.

  dense   -- granite-3-8b, llama3-405b, qwen3-32b, llama3.2-3b
  moe     -- qwen3-moe-30b-a3b, phi3.5-moe-42b
  ssm     -- xlstm-350m (mLSTM/sLSTM pairs, attention-free)
  hybrid  -- zamba2-2.7b (Mamba2 stack + one shared attention block)
  audio   -- whisper-tiny (encoder-decoder; stub frame embeddings)
  vlm     -- llama-3.2-vision-11b (cross-attention image layers; stub patches)

All layer stacks are `jax.lax.scan` over stacked params (compile-time O(1)
in depth -- required for the 126-layer dry-run), with optional remat.
Entry points:
  init_lm, train_loss, prefill, serve_step, init_serve_cache

Exact attention is the published-architecture baseline; setting
``cfg.vq_attn`` swaps in VQ-Attention (the paper's technique) behind the
same interface -- sub-quadratic train/prefill and O(k+W) decode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_constraints import constrain_tokens
from repro.nn.attention import (AttnParams, decode_attend, gqa_attend,
                                init_attn, init_kv_cache, qkv)
from repro.nn.ffn import apply_mlp, apply_moe, init_mlp, init_moe
from repro.nn.layers import dense_init, embed_init, rmsnorm
from repro.nn.ssm import (apply_mamba2_step, apply_mamba2_train,
                          init_mamba2, init_mamba2_state)
from repro.nn.vq_attention import (VQAttnConfig, init_vq_cache,
                                   vq_attention_decode, vq_attention_train)
from repro.nn.xlstm import (apply_mlstm_step, apply_mlstm_train,
                            apply_slstm_step, apply_slstm_train, init_mlstm,
                            init_mlstm_state, init_slstm, init_slstm_state)

Params = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _vq_cfg(cfg: ArchConfig) -> VQAttnConfig:
    return VQAttnConfig(k=cfg.vq_k, window=cfg.vq_window)


# ===========================================================================
# block init (per family)
# ===========================================================================

def _init_dense_block(key, cfg: ArchConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = _dtype(cfg)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": init_attn(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, dt),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dt)}


def _init_moe_block(key, cfg: ArchConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = _dtype(cfg)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": init_attn(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, dt),
            "moe": init_moe(km, cfg.d_model, cfg.n_experts, cfg.d_ff, dt)}


def _init_cross_block(key, cfg: ArchConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = _dtype(cfg)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": init_attn(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, dt),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dt),
            "gate": jnp.zeros((), dt)}


def init_lm(key: jax.Array, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ke, kb, kh, kx = jax.random.split(key, 4)
    params: dict = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "head": dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }
    if cfg.family in ("dense", "vlm"):
        init_b = _init_dense_block
    elif cfg.family == "moe":
        init_b = _init_moe_block

    if cfg.family in ("dense", "moe", "vlm"):
        params["blocks"] = jax.vmap(lambda k: init_b(k, cfg))(
            jax.random.split(kb, cfg.n_layers))
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        params["cross_blocks"] = jax.vmap(
            lambda k: _init_cross_block(k, cfg))(
                jax.random.split(kx, n_cross))
    if cfg.family == "audio":
        params["enc_blocks"] = jax.vmap(lambda k: _init_dense_block(k, cfg))(
            jax.random.split(kb, cfg.enc_layers))
        def dec_block(k):
            k1, k2 = jax.random.split(k)
            blk = _init_dense_block(k1, cfg)
            blk["ln_x"] = jnp.ones((cfg.d_model,), dt)
            blk["cross"] = init_attn(k2, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd, dt)
            return blk
        params["blocks"] = jax.vmap(dec_block)(
            jax.random.split(kx, cfg.n_layers))
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), dt)
    if cfg.family == "ssm":
        def pair(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": jnp.ones((cfg.d_model,), dt),
                    "ln2": jnp.ones((cfg.d_model,), dt),
                    "mlstm": init_mlstm(k1, cfg.d_model, cfg.n_heads, dt),
                    "slstm": init_slstm(k2, cfg.d_model, dt)}
        params["pairs"] = jax.vmap(pair)(
            jax.random.split(kb, cfg.n_layers // 2))
    if cfg.family == "hybrid":
        def mblock(k):
            return {"ln": jnp.ones((cfg.d_model,), dt),
                    "mamba": init_mamba2(k, cfg.d_model, cfg.ssm_state, dt)}
        groups = cfg.n_layers // cfg.attn_period
        params["mamba"] = jax.vmap(jax.vmap(mblock))(
            jax.random.split(kb, cfg.n_layers
                             ).reshape(groups, cfg.attn_period, 2))
        params["shared"] = _init_dense_block(kx, cfg)
    return params


# ===========================================================================
# attention sub-blocks (train / decode)
# ===========================================================================

def _attn_train(bp, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    x = constrain_tokens(x)
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = qkv(bp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                  positions, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)
    if cfg.vq_attn:
        o = vq_attention_train(q, k, v, _vq_cfg(cfg))
    else:
        o = gqa_attend(q, k, v, causal=True)
    return constrain_tokens(x + o.reshape(b, s, -1) @ bp["attn"].wo)


def _attn_decode(bp, x, cache, cfg: ArchConfig):
    b = x.shape[0]
    h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    positions = jnp.full((b, 1), cache.pos, jnp.int32)
    q, k, v = qkv(bp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                  positions, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)
    if cfg.vq_attn:
        o, cache = vq_attention_decode(q, k, v, cache, _vq_cfg(cfg))
    else:
        o, cache = decode_attend(q, cache, k, v)
    return x + o.reshape(b, 1, -1) @ bp["attn"].wo, cache


def _ffn(bp, x, cfg: ArchConfig):
    b, s, d = x.shape
    h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    if cfg.family == "moe" or "moe" in bp:
        y, aux = apply_moe(bp["moe"], h.reshape(b * s, d), cfg.top_k)
        return constrain_tokens(x + y.reshape(b, s, d)), aux
    return constrain_tokens(x + apply_mlp(bp["mlp"], h)), jnp.zeros(())


def _cross_attn(bp, x, ctx_k, ctx_v, cfg: ArchConfig, gated: bool = False):
    """Cross-attention to precomputed context K/V.  x: [B, S, d]."""
    b, s, _ = x.shape
    h = rmsnorm(x, bp["ln_x" if "ln_x" in bp else "ln1"], cfg.norm_eps)
    attn = bp["cross" if "cross" in bp else "attn"]
    q = (h @ attn.wq).reshape(b, s, cfg.n_heads, cfg.hd)
    o = gqa_attend(q, ctx_k, ctx_v, causal=False)
    o = o.reshape(b, s, -1) @ attn.wo
    if gated:
        o = jnp.tanh(bp["gate"]) * o
    return x + o


def _ctx_kv(attn: AttnParams, ctx: jax.Array, cfg: ArchConfig):
    b, f, _ = ctx.shape
    k = (ctx @ attn.wk).reshape(b, f, cfg.n_kv_heads, cfg.hd)
    v = (ctx @ attn.wv).reshape(b, f, cfg.n_kv_heads, cfg.hd)
    return k, v


# ===========================================================================
# training forward (per family), scan over stacked blocks
# ===========================================================================

def _scan_blocks(x, blocks, body, cfg: ArchConfig):
    fn = jax.checkpoint(body) if cfg.remat else body
    return jax.lax.scan(fn, x, blocks)


def embed_lookup(embed: jax.Array, tokens: jax.Array,
                 vocab: int) -> jax.Array:
    """Vocab-parallel embedding lookup.

    A plain gather from a vocab-sharded table makes GSPMD replicate the
    whole table ("involuntary full rematerialization" -- Perf iteration 4);
    the one-hot matmul form keeps the vocab axis sharded and reduces with
    one psum.  Processed in sequence chunks so the one-hot never exceeds
    [B, 512, vocab_shard].
    """
    if vocab < 8192:
        return embed[tokens]
    b, s = tokens.shape
    chunk = 512
    if s % chunk != 0:
        return jnp.einsum('bsv,vd->bsd',
                          jax.nn.one_hot(tokens, vocab, dtype=embed.dtype),
                          embed)
    tok_c = jnp.moveaxis(tokens.reshape(b, s // chunk, chunk), 1, 0)

    def body(_, tc):
        oh = jax.nn.one_hot(tc, vocab, dtype=embed.dtype)
        return None, jnp.einsum('bcv,vd->bcd', oh, embed)
    _, xs = jax.lax.scan(body, None, tok_c)
    return jnp.moveaxis(xs, 0, 1).reshape(b, s, embed.shape[1])


def forward_train(params: Params, tokens: jax.Array, cfg: ArchConfig,
                  aux_embeds: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, vocab], moe aux loss)."""
    b, s = tokens.shape
    x = constrain_tokens(embed_lookup(params["embed"], tokens, cfg.vocab))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    moe_aux = jnp.zeros(())

    if cfg.family in ("dense", "moe"):
        def body(xc, bp):
            xc = _attn_train(bp, xc, cfg, positions)
            xc, aux = _ffn(bp, xc, cfg)
            return xc, aux
        gsz = cfg.remat_group
        if cfg.remat and gsz > 1 and cfg.n_layers % gsz == 0:
            # sqrt-remat: checkpoint at group granularity (saves G=L/gsz
            # carries instead of L; recompute peaks at one group)
            groups = cfg.n_layers // gsz
            stacked = jax.tree_util.tree_map(
                lambda a: a.reshape(groups, gsz, *a.shape[1:]),
                params["blocks"])

            def group_body(xc, gblocks):
                # nested remat: per-layer checkpoint INSIDE the group
                # checkpoint, so the group recompute never holds more than
                # one layer's residuals (fwd runs 3x; peak activations
                # G*carry + gsz*carry + 1 layer -- Perf iteration 3b)
                xc, auxs = jax.lax.scan(jax.checkpoint(body), xc, gblocks)
                return xc, jnp.sum(auxs)
            x, auxs = jax.lax.scan(jax.checkpoint(group_body), x, stacked)
        else:
            x, auxs = _scan_blocks(x, params["blocks"], body, cfg)
        moe_aux = jnp.sum(auxs)

    elif cfg.family == "vlm":
        ctx = aux_embeds  # [B, P, d] stub patch embeddings
        period = cfg.cross_attn_period
        groups = cfg.n_layers // period
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape(groups, period, *a.shape[1:]),
            params["blocks"])

        def group_body(xc, gb):
            text_blocks, cross_bp = gb
            def tbody(xc2, bp):
                xc2 = _attn_train(bp, xc2, cfg, positions)
                xc2, _ = _ffn(bp, xc2, cfg)
                return xc2, jnp.zeros(())
            xc, _ = jax.lax.scan(tbody, xc, text_blocks)
            ck, cv = _ctx_kv(cross_bp["attn"], ctx, cfg)
            xc = _cross_attn(cross_bp, xc, ck, cv, cfg, gated=True)
            xc, _ = _ffn(cross_bp, xc, cfg)
            return xc, jnp.zeros(())
        gfn = jax.checkpoint(group_body) if cfg.remat else group_body
        x, _ = jax.lax.scan(gfn, x, (stacked, params["cross_blocks"]))

    elif cfg.family == "audio":
        enc = aux_embeds  # [B, F, d] stub frame embeddings
        fpos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                enc.shape[:2])

        def ebody(ec, bp):
            h = rmsnorm(ec, bp["ln1"], cfg.norm_eps)
            q, k, v = qkv(bp["attn"], h, cfg.n_heads, cfg.n_kv_heads,
                          cfg.hd, fpos, rope_theta=cfg.rope_theta)
            ec = ec + gqa_attend(q, k, v, causal=False
                                 ).reshape(*ec.shape[:2], -1) @ bp["attn"].wo
            ec, _ = _ffn(bp, ec, cfg)
            return ec, jnp.zeros(())
        enc, _ = _scan_blocks(enc, params["enc_blocks"], ebody, cfg)
        enc = rmsnorm(enc, params["enc_ln_f"], cfg.norm_eps)

        def dbody(xc, bp):
            xc = _attn_train(bp, xc, cfg, positions)
            ck, cv = _ctx_kv(bp["cross"], enc, cfg)
            xc = _cross_attn(bp, xc, ck, cv, cfg)
            xc, _ = _ffn(bp, xc, cfg)
            return xc, jnp.zeros(())
        x, _ = _scan_blocks(x, params["blocks"], dbody, cfg)

    elif cfg.family == "ssm":
        def body(xc, bp):
            xc = xc + apply_mlstm_train(
                bp["mlstm"], rmsnorm(xc, bp["ln1"], cfg.norm_eps),
                cfg.n_heads)
            xc = xc + apply_slstm_train(
                bp["slstm"], rmsnorm(xc, bp["ln2"], cfg.norm_eps))
            return xc, jnp.zeros(())
        x, _ = _scan_blocks(x, params["pairs"], body, cfg)

    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(xc, mblocks):
            def mbody(xc2, bp):
                xc2 = xc2 + apply_mamba2_train(
                    bp["mamba"], rmsnorm(xc2, bp["ln"], cfg.norm_eps),
                    cfg.d_model, cfg.ssm_state)
                return xc2, jnp.zeros(())
            xc, _ = jax.lax.scan(mbody, xc, mblocks)
            xc = _attn_train(shared, xc, cfg, positions)
            xc, _ = _ffn(shared, xc, cfg)
            return xc, jnp.zeros(())
        gfn = jax.checkpoint(group_body) if cfg.remat else group_body
        x, _ = jax.lax.scan(gfn, x, params["mamba"])

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, moe_aux


def train_loss(params: Params, tokens: jax.Array, cfg: ArchConfig,
               aux_embeds: jax.Array | None = None) -> jax.Array:
    """Next-token cross entropy (mean over tokens) + MoE aux.

    CE is computed matmul-style (one-hot einsum for the target logit +
    streaming logsumexp) so the vocab axis stays model-sharded end to end
    -- a take_along_axis gather on a sharded vocab forces an all-gather of
    the full [tokens, vocab] logits under GSPMD (perf log, EXPERIMENTS.md
    section Perf iteration 1).
    """
    hidden, moe_aux = forward_train(params, tokens[:, :-1], cfg, aux_embeds)
    targets = tokens[:, 1:]
    logits = (hidden @ params["head"]).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=logits.dtype)
    target_logit = jnp.einsum('bsv,bsv->bs', logits, onehot)
    nll = lse - target_logit
    return jnp.mean(nll) + 0.01 * moe_aux


# ===========================================================================
# serving: cache init, prefill, one-token decode
# ===========================================================================

def init_serve_cache(cfg: ArchConfig, batch: int, seq_len: int) -> Any:
    """Decode-state pytree for one-token serve steps.

    Exact attention: per-layer KV caches of length seq_len.
    VQ-Attention:    per-layer codebook + W-token ring (O(k+W) state --
                     the paper's inference memory win).
    SSM/hybrid:      constant-size recurrent states.
    """
    dt = _dtype(cfg)
    def kv_stack(n):
        return jax.vmap(lambda _: init_kv_cache(
            batch, seq_len, cfg.n_kv_heads, cfg.hd, dt))(jnp.arange(n))

    def vq_stack(n):
        return jax.vmap(lambda _: init_vq_cache(
            batch, cfg.n_kv_heads, cfg.hd, _vq_cfg(cfg), dt))(jnp.arange(n))

    if cfg.family in ("dense", "moe"):
        return {"kv": vq_stack(cfg.n_layers) if cfg.vq_attn
                else kv_stack(cfg.n_layers)}
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        return {"kv": vq_stack(cfg.n_layers) if cfg.vq_attn
                else kv_stack(cfg.n_layers),
                "cross_k": jnp.zeros((n_cross, batch, cfg.n_patches,
                                      cfg.n_kv_heads, cfg.hd), dt),
                "cross_v": jnp.zeros((n_cross, batch, cfg.n_patches,
                                      cfg.n_kv_heads, cfg.hd), dt)}
    if cfg.family == "audio":
        return {"kv": vq_stack(cfg.n_layers) if cfg.vq_attn
                else kv_stack(cfg.n_layers),
                "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                      cfg.n_kv_heads, cfg.hd), dt),
                "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                                      cfg.n_kv_heads, cfg.hd), dt)}
    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        return {"mlstm": jax.vmap(lambda _: init_mlstm_state(
                    batch, cfg.d_model, cfg.n_heads))(jnp.arange(n_pairs)),
                "slstm": jax.vmap(lambda _: init_slstm_state(
                    batch, cfg.d_model))(jnp.arange(n_pairs))}
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_period
        mstates = jax.vmap(jax.vmap(lambda _: init_mamba2_state(
            batch, cfg.d_model, cfg.ssm_state, _dtype(cfg))))(
                jnp.zeros((groups, cfg.attn_period)))
        attn_c = (jax.vmap(lambda _: init_vq_cache(
                      batch, cfg.n_kv_heads, cfg.hd, _vq_cfg(cfg), dt))
                  (jnp.arange(groups)) if cfg.vq_attn else
                  jax.vmap(lambda _: init_kv_cache(
                      batch, seq_len, cfg.n_kv_heads, cfg.hd, dt))
                  (jnp.arange(groups)))
        return {"mamba": mstates, "attn": attn_c}
    raise ValueError(cfg.family)


def serve_step(params: Params, token: jax.Array, cache: Any,
               cfg: ArchConfig) -> tuple[jax.Array, Any]:
    """One decode step.  token: [B, 1] int32 -> (logits [B, vocab], cache)."""
    b = token.shape[0]
    x = params["embed"][token]                           # [B, 1, d]

    if cfg.family in ("dense", "moe"):
        def body(xc, scan_in):
            bp, kvc = scan_in
            xc, kvc = _attn_decode(bp, xc, kvc, cfg)
            xc, _ = _ffn(bp, xc, cfg)
            return xc, kvc
        x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
        cache = {"kv": new_kv}

    elif cfg.family == "vlm":
        period = cfg.cross_attn_period
        groups = cfg.n_layers // period
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape(groups, period, *a.shape[1:]),
            params["blocks"])
        kv_g = jax.tree_util.tree_map(
            lambda a: a.reshape(groups, period, *a.shape[1:]), cache["kv"])

        def gbody(xc, scan_in):
            tb, cb, kvc, ck, cv = scan_in
            def tbody(x2, si):
                bp, kv1 = si
                x2, kv1 = _attn_decode(bp, x2, kv1, cfg)
                x2, _ = _ffn(bp, x2, cfg)
                return x2, kv1
            xc, kvc = jax.lax.scan(tbody, xc, (tb, kvc))
            xc = _cross_attn(cb, xc, ck, cv, cfg, gated=True)
            xc, _ = _ffn(cb, xc, cfg)
            return xc, kvc
        x, new_kv = jax.lax.scan(
            gbody, x, (stacked, params["cross_blocks"], kv_g,
                       cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, kv=jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_kv))

    elif cfg.family == "audio":
        def body(xc, scan_in):
            bp, kvc, ck, cv = scan_in
            xc, kvc = _attn_decode(bp, xc, kvc, cfg)
            xc = _cross_attn(bp, xc, ck, cv, cfg)
            xc, _ = _ffn(bp, xc, cfg)
            return xc, kvc
        x, new_kv = jax.lax.scan(
            body, x, (params["blocks"], cache["kv"],
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, kv=new_kv)

    elif cfg.family == "ssm":
        def body(xc, scan_in):
            bp, ms, ss = scan_in
            o, ms = apply_mlstm_step(
                bp["mlstm"], rmsnorm(xc, bp["ln1"], cfg.norm_eps),
                ms, cfg.n_heads)
            xc = xc + o
            o, ss = apply_slstm_step(
                bp["slstm"], rmsnorm(xc, bp["ln2"], cfg.norm_eps), ss)
            return xc + o, (ms, ss)
        x, (new_m, new_s) = jax.lax.scan(
            body, x, (params["pairs"], cache["mlstm"], cache["slstm"]))
        cache = {"mlstm": new_m, "slstm": new_s}

    elif cfg.family == "hybrid":
        shared = params["shared"]

        def gbody(xc, scan_in):
            mblocks, mstates, kvc = scan_in
            def mbody(x2, si):
                bp, st = si
                o, st = apply_mamba2_step(
                    bp["mamba"], rmsnorm(x2, bp["ln"], cfg.norm_eps), st,
                    cfg.d_model, cfg.ssm_state)
                return x2 + o, st
            xc, mstates = jax.lax.scan(mbody, xc, (mblocks, mstates))
            xc, kvc = _attn_decode(shared, xc, kvc, cfg)
            xc, _ = _ffn(shared, xc, cfg)
            return xc, (mstates, kvc)
        x, (new_m, new_kv) = jax.lax.scan(
            gbody, x, (params["mamba"], cache["mamba"], cache["attn"]))
        cache = {"mamba": new_m, "attn": new_kv}

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return (x[:, 0] @ params["head"]), cache


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig,
            aux_embeds: jax.Array | None = None) -> jax.Array:
    """Prefill forward: last-position logits [B, vocab].

    The head is applied to the last position ONLY -- materializing
    [B, S, vocab] logits during prefill cost 384 GiB/device on the 32k
    cells (perf log, EXPERIMENTS.md section Perf iteration 1).

    (Cache materialization for the subsequent decode uses the same
    forward's K/V -- the dry-run lowers this function for prefill shapes;
    decode shapes take pre-existing caches via serve_step.)
    """
    hidden, _ = forward_train(params, tokens, cfg, aux_embeds)
    return hidden[:, -1] @ params["head"]
