"""repro subpackage."""
