"""GQA attention for the LM backbones: training, prefill, cached decode.

Layout conventions (TPU-friendly: batch/seq leading, heads x head_dim last):
  activations  [B, S, d_model]
  q            [B, S, Hq, dh]
  k, v         [B, S, Hkv, dh]      (GQA: Hq = G * Hkv)
  KV cache     [B, S_max, Hkv, dh]  (ring-indexed by absolute position)

The exact-attention path is the published architectures' faithful baseline;
VQ-Attention (repro/nn/vq_attention.py) is the paper's technique swapped in
behind the same interface.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init, rmsnorm, rope


class AttnParams(NamedTuple):
    wq: jax.Array          # [d, Hq*dh]
    wk: jax.Array          # [d, Hkv*dh]
    wv: jax.Array          # [d, Hkv*dh]
    wo: jax.Array          # [Hq*dh, d]
    q_norm: jax.Array      # [dh] (qk_norm archs; ones otherwise)
    k_norm: jax.Array      # [dh]


def init_attn(key, d: int, n_heads: int, n_kv: int, head_dim: int,
              dtype=jnp.float32) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_init(kq, d, n_heads * head_dim, dtype),
        wk=dense_init(kk, d, n_kv * head_dim, dtype),
        wv=dense_init(kv, d, n_kv * head_dim, dtype),
        wo=dense_init(ko, n_heads * head_dim, d, dtype),
        q_norm=jnp.ones((head_dim,), dtype),
        k_norm=jnp.ones((head_dim,), dtype))


def qkv(p: AttnParams, x: jax.Array, n_heads: int, n_kv: int, head_dim: int,
        positions: jax.Array, *, qk_norm: bool = False,
        rope_theta: float = 500000.0, use_rope: bool = True):
    b, s, _ = x.shape
    q = (x @ p.wq).reshape(b, s, n_heads, head_dim)
    k = (x @ p.wk).reshape(b, s, n_kv, head_dim)
    v = (x @ p.wv).reshape(b, s, n_kv, head_dim)
    if qk_norm:
        q = rmsnorm(q, p.q_norm)
        k = rmsnorm(k, p.k_norm)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


_Q_CHUNK = 1024


def _gqa_attend_block(q, k, v, causal, kv_mask, q_offset, skv_full):
    """One query block of GQA attention.  q: [B, sq, Hq, dh]."""
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum('bqhgd,bkhd->bhgqk', qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(dh)
    if causal:
        qi = q_offset + jnp.arange(sq)[:, None]   # absolute query positions
        ki = jnp.arange(skv)[None, :]
        s = jnp.where((ki <= qi)[None, None, None], s, -jnp.inf)
    if kv_mask is not None:
        s = jnp.where(kv_mask[:, None, None, None, :] > 0, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum('bhgqk,bkhd->bqhgd', p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, dh).astype(q.dtype)


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
               causal: bool = True,
               kv_mask: Optional[jax.Array] = None) -> jax.Array:
    """Grouped-query attention.

    q: [B, Sq, Hq, dh], k/v: [B, Skv, Hkv, dh] -> [B, Sq, Hq, dh].
    kv_mask: [B, Skv] validity (decode with ragged cache).

    Long sequences are processed in query chunks (a lax.scan) so the
    [sq, skv] score block never exceeds [_Q_CHUNK, skv] -- the XLA-level
    equivalent of the Pallas flash kernel's VMEM tiling (the kernel is the
    TPU execution path; this is the lowerable stand-in with the same
    activation footprint scaling).
    """
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    if sq <= _Q_CHUNK or sq % _Q_CHUNK != 0:
        qoff = (skv - sq) if causal else 0
        return _gqa_attend_block(q, k, v, causal, kv_mask, qoff, skv)

    nchunk = sq // _Q_CHUNK
    qc = q.reshape(b, nchunk, _Q_CHUNK, hq, dh)

    # checkpoint each chunk: the [bq, skv] scores are recomputed in the
    # backward pass instead of being stacked across the scan as residuals
    # (8 GiB/layer of f32 scores otherwise -- Perf iteration 5c)
    @jax.checkpoint
    def body(_, xs):
        qi, off = xs
        o = _gqa_attend_block(qi, k, v, causal, kv_mask, off, skv)
        return None, o

    offs = (skv - sq) + jnp.arange(nchunk) * _Q_CHUNK
    _, oc = jax.lax.scan(body, None, (jnp.moveaxis(qc, 1, 0), offs))
    return jnp.moveaxis(oc, 0, 1).reshape(b, sq, hq, dh)


class KVCache(NamedTuple):
    k: jax.Array         # [B, S_max, Hkv, dh]
    v: jax.Array         # [B, S_max, Hkv, dh]
    pos: jax.Array       # [] int32 -- number of tokens already cached


def init_kv_cache(b: int, s_max: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(jnp.zeros((b, s_max, n_kv, head_dim), dtype),
                   jnp.zeros((b, s_max, n_kv, head_dim), dtype),
                   jnp.zeros((), jnp.int32))


def decode_attend(q: jax.Array, cache: KVCache, k_new: jax.Array,
                  v_new: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token cached decode.  q/k_new/v_new: [B, 1, H*, dh]."""
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), cache.pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), cache.pos, axis=1)
    valid = (jnp.arange(kc.shape[1]) <= cache.pos).astype(jnp.float32)
    mask = jnp.broadcast_to(valid[None, :], kc.shape[:2])
    out = gqa_attend(q, kc, vc, causal=False, kv_mask=mask)
    return out, KVCache(kc, vc, cache.pos + 1)
