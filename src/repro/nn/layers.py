"""Shared NN primitives for the LM stack (no external NN library)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, f_in: int, f_out: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (f_in, f_out), jnp.float32)
            / jnp.sqrt(f_in)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (0.02 * jax.random.normal(key, (vocab, d), jnp.float32)
            ).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 500000.0) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, dh], positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin],
        axis=-1).astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array,
           w2: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x w1) * (x w3)) w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2
