"""repro subpackage."""
