"""GNN backbones as generalized graph convolutions (paper Tables 1 & 5).

Every backbone implements two execution modes sharing one parameter set:

  * ``full_apply``  -- exact message passing on an explicit (sub)graph
    (the "full-graph" oracle, the sampling baselines' subgraphs, inference);
  * ``vq_apply``    -- the paper's approximated message passing on a
    mini-batch (Eq. 6 forward, Eq. 7 backward via the custom-VJP injection,
    probe-trick gradient taps for the codebook update).  ``probe=None``
    skips the tap (the probe only matters under ``jax.grad``): the
    gradient-free consumers -- inference executor, serving step, eval --
    pass None instead of shipping per-layer zero tensors through the graph.

Backbones: GCN, SAGE-Mean, GAT (learnable row-normalized convolution,
Lipschitz-clipped scores per App. E), GIN, and a global-attention
GraphTransformer (dense learnable convolution -- the case sampling methods
cannot handle at all, paper Sec. 1/3).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.codebook import CodebookConfig
from repro.core.conv import (LayerVQState, MinibatchPack, fixed_conv_operands,
                             layer_codewords, out_of_batch_cluster_mass)
from repro.core.message_passing import (approx_message_passing,
                                        inject_context_grad_materialized,
                                        inject_context_grad_table,
                                        reconstruct)
from repro.graph.batching import FullGraphOperands
from repro.kernels import ops as kops

Params = dict[str, Any]
SCORE_CLIP = 5.0   # App. E Lipschitz regularization of attention scores


def _dense(key, f_in, f_out, scale=None):
    scale = scale or (1.0 / jnp.sqrt(f_in))
    return scale * jax.random.normal(key, (f_in, f_out), jnp.float32)


def _gcn_edge_vals(ops_: FullGraphOperands) -> tuple[jax.Array, jax.Array]:
    dt = ops_.degrees + 1.0
    vals = ops_.nbr_mask / jnp.sqrt(dt[:, None] * (dt[ops_.nbr_ids]))
    return vals, 1.0 / dt


# ===========================================================================
# GCN  (fixed convolution  C = D~^-1/2 A~ D~^-1/2)
# ===========================================================================

class GCN:
    name = "gcn"

    @staticmethod
    def init(key, f_in: int, f_out: int, **_) -> Params:
        kw, = jax.random.split(key, 1)
        return {"w": _dense(kw, f_in, f_out), "b": jnp.zeros((f_out,))}

    @staticmethod
    def f_grad(f_in: int, f_out: int, **_) -> int:
        return f_out          # gradient codewords live at the Z level

    @staticmethod
    def probe_shape(b: int, f_in: int, f_out: int, **_) -> tuple[int, ...]:
        return (b, f_out)

    @staticmethod
    def full_apply(p: Params, x, ops_: FullGraphOperands, act) -> jax.Array:
        vals, self_vals = _gcn_edge_vals(ops_)
        m = kops.spmm_ell(ops_.nbr_ids, vals, x, ops_.stripe_index) \
            + self_vals[:, None] * x
        return act(m @ p["w"] + p["b"])

    @staticmethod
    def vq_apply(p: Params, x_b, probe, pack: MinibatchPack,
                 vq: LayerVQState, degrees, cfg: CodebookConfig, act,
                 f_in: int, f_out: int, inject: bool = True) -> jax.Array:
        ops_, self_vals = fixed_conv_operands('gcn', pack, degrees)
        # int8 QTensor operands when the layer state carries a snapshot
        fcw, gcw = layer_codewords(vq, f_in, cfg)
        m = approx_message_passing(ops_, x_b, fcw, gcw, vq.assignment,
                                   p["w"], inject)
        m = m + self_vals[:, None] * x_b
        z = m @ p["w"] + p["b"]
        return act(z if probe is None else z + probe)


# ===========================================================================
# SAGE-Mean  (two fixed convolutions:  C1 = I,  C2 = D^-1 A)
# ===========================================================================

class SAGE:
    name = "sage"

    @staticmethod
    def init(key, f_in: int, f_out: int, **_) -> Params:
        k1, k2 = jax.random.split(key)
        return {"w1": _dense(k1, f_in, f_out), "w2": _dense(k2, f_in, f_out),
                "b": jnp.zeros((f_out,))}

    @staticmethod
    def f_grad(f_in: int, f_out: int, **_) -> int:
        return f_out

    @staticmethod
    def probe_shape(b, f_in, f_out, **_):
        return (b, f_out)

    @staticmethod
    def full_apply(p: Params, x, ops_: FullGraphOperands, act) -> jax.Array:
        vals = ops_.nbr_mask / jnp.maximum(ops_.degrees, 1.0)[:, None]
        mean_nbr = kops.spmm_ell(ops_.nbr_ids, vals, x, ops_.stripe_index)
        return act(x @ p["w1"] + mean_nbr @ p["w2"] + p["b"])

    @staticmethod
    def vq_apply(p: Params, x_b, probe, pack, vq, degrees, cfg, act,
                 f_in: int, f_out: int, inject: bool = True) -> jax.Array:
        ops_, _ = fixed_conv_operands('mean', pack, degrees)
        fcw, gcw = layer_codewords(vq, f_in, cfg)
        m2 = approx_message_passing(ops_, x_b, fcw, gcw, vq.assignment,
                                    p["w2"], inject)
        # identity convolution is always intra-batch -> exact autodiff
        z = x_b @ p["w1"] + m2 @ p["w2"] + p["b"]
        return act(z if probe is None else z + probe)


# ===========================================================================
# GIN  (C1 = A fixed;  C2 = (1+eps) I learnable-diagonal;  MLP head)
# ===========================================================================

class GIN:
    name = "gin"

    @staticmethod
    def init(key, f_in: int, f_out: int, **_) -> Params:
        k1, k2 = jax.random.split(key)
        return {"w1": _dense(k1, f_in, f_out), "b1": jnp.zeros((f_out,)),
                "w2": _dense(k2, f_out, f_out), "b2": jnp.zeros((f_out,)),
                "eps": jnp.zeros(())}

    @staticmethod
    def f_grad(f_in: int, f_out: int, **_) -> int:
        return f_out

    @staticmethod
    def probe_shape(b, f_in, f_out, **_):
        return (b, f_out)

    @staticmethod
    def full_apply(p: Params, x, ops_: FullGraphOperands, act) -> jax.Array:
        s = kops.spmm_ell(ops_.nbr_ids, ops_.nbr_mask, x,
                          ops_.stripe_index)
        m = (1.0 + p["eps"]) * x + s
        h = jax.nn.relu(m @ p["w1"] + p["b1"])
        return act(h @ p["w2"] + p["b2"])

    @staticmethod
    def vq_apply(p: Params, x_b, probe, pack, vq, degrees, cfg, act,
                 f_in: int, f_out: int, inject: bool = True) -> jax.Array:
        ops_, _ = fixed_conv_operands('adj', pack, degrees)
        fcw, gcw = layer_codewords(vq, f_in, cfg)
        s = approx_message_passing(ops_, x_b, fcw, gcw, vq.assignment,
                                   p["w1"], inject)
        m = (1.0 + p["eps"]) * x_b + s
        z = m @ p["w1"] + p["b1"]
        h = jax.nn.relu(z if probe is None else z + probe)
        return act(h @ p["w2"] + p["b2"])


# ===========================================================================
# GAT  (learnable row-normalized convolution, paper Table 1 + App. E tricks)
# ===========================================================================

def _gat_scores(xw: jax.Array, a_dst: jax.Array, a_src: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """xw: [..., H, fh] -> per-head destination/source score halves."""
    return jnp.einsum('...hf,hf->...h', xw, a_dst), \
        jnp.einsum('...hf,hf->...h', xw, a_src)


def _gat_edge_weight(s_dst, s_src):
    """exp(clip(LeakyReLU(s_dst + s_src))) -- Lipschitz-clipped (App. E)."""
    e = jax.nn.leaky_relu(s_dst + s_src, 0.2)
    return jnp.exp(jnp.clip(e, -SCORE_CLIP, SCORE_CLIP))


class GAT:
    name = "gat"
    heads = 4

    @staticmethod
    def init(key, f_in: int, f_out: int, heads: int = 4, **_) -> Params:
        assert f_out % heads == 0
        fh = f_out // heads
        kw, ka, kb = jax.random.split(key, 3)
        return {"w": _dense(kw, f_in, f_out).reshape(f_in, heads, fh),
                "a_dst": 0.1 * jax.random.normal(ka, (heads, fh)),
                "a_src": 0.1 * jax.random.normal(kb, (heads, fh)),
                "b": jnp.zeros((f_out,))}

    @staticmethod
    def f_grad(f_in: int, f_out: int, heads: int = 4, **_) -> int:
        # probe sits at the per-head augmented message level: H * (fh + 1)
        return f_out + heads

    @staticmethod
    def probe_shape(b, f_in, f_out, heads: int = 4, **_):
        return (b, f_out + heads)

    @staticmethod
    def full_apply(p: Params, x, ops_: FullGraphOperands, act) -> jax.Array:
        n, dcap = ops_.nbr_ids.shape
        heads, fh = p["a_dst"].shape
        xw = jnp.einsum('nf,fhe->nhe', x, p["w"])            # [n, H, fh]
        s_dst, s_src = _gat_scores(xw, p["a_dst"], p["a_src"])
        w_edge = _gat_edge_weight(s_dst[:, None, :], s_src[ops_.nbr_ids]
                                  ) * ops_.nbr_mask[..., None]  # [n, D, H]
        w_self = _gat_edge_weight(s_dst, s_src)              # [n, H]
        num = jnp.einsum('ndh,ndhe->nhe', w_edge, xw[ops_.nbr_ids]) \
            + w_self[..., None] * xw
        den = w_edge.sum(axis=1) + w_self                    # [n, H]
        y = num / jnp.maximum(den, 1e-9)[..., None]
        return act(y.reshape(n, heads * fh) + p["b"])

    @staticmethod
    def vq_apply(p: Params, x_b, probe, pack: MinibatchPack,
                 vq: LayerVQState, degrees, cfg: CodebookConfig, act,
                 f_in: int, f_out: int, inject: bool = True) -> jax.Array:
        b = x_b.shape[0]
        heads, fh = p["a_dst"].shape
        # dense f32 reads: GAT mixes branches through the per-head value
        # map, so kernel-side dequant epilogues cannot express its math
        fcw, gcw = layer_codewords(vq, f_in, cfg, dense=True)

        # ---- Eq. 7 backward injection (before anything touches x_b) ----
        # reverse-edge weights  C^h_{j,i} = w(s_dst(j), s_src(i)), with the
        # out-of-batch endpoint j reconstructed from its codewords
        x_rev_hat = jax.lax.stop_gradient(
            reconstruct(fcw, vq.assignment, pack.rev_ids))   # [b, Dr, f_in]
        ghat = jax.lax.stop_gradient(
            reconstruct(gcw, vq.assignment, pack.rev_ids))   # [b, Dr, H*(fh+1)]
        ghat = ghat.reshape(b, -1, heads, fh + 1)[..., :fh]  # value-part only
        xw0 = jnp.einsum('bf,fhe->bhe', x_b, p["w"])
        s_dst0, s_src0 = _gat_scores(xw0, p["a_dst"], p["a_src"])
        xw_rev = jnp.einsum('bdf,fhe->bdhe', x_rev_hat, p["w"])
        s_dst_rev, _ = _gat_scores(xw_rev, p["a_dst"], p["a_src"])
        rev_vals = _gat_edge_weight(s_dst_rev, s_src0[:, None, :]) \
            * jnp.where(pack.rev_pos < 0, pack.rev_mask, 0.0)[..., None]
        rev_vals = jax.lax.stop_gradient(rev_vals)           # [b, Dr, H]
        dr = rev_vals.shape[1]
        # fold heads into the neighbor axis; backward weight has no W factor
        # (probe lives pre-normalization, value space is the xw space) -> the
        # injected grad must be mapped back through W^T per head.  The
        # per-head W map mixes the product-VQ branches, so the lazy
        # codeword-residual form cannot express this tensor: GAT keeps the
        # materialized injection (message_passing.py docstring).
        ghat_x = jnp.einsum('bdhe,fhe->bdhf', ghat, p["w"]
                            ).reshape(b, dr * heads, f_in)
        if inject:
            x_b = inject_context_grad_materialized(
                x_b, rev_vals.transpose(0, 2, 1).reshape(b, heads * dr),
                ghat_x.reshape(b, heads * dr, f_in), None)

        # ---- Eq. 6 forward: exact intra + codeword context, per head ----
        xw = jnp.einsum('bf,fhe->bhe', x_b, p["w"])          # [b, H, fh]
        s_dst, s_src = _gat_scores(xw, p["a_dst"], p["a_src"])
        # in-batch neighbors
        pos = jnp.maximum(pack.nbr_pos, 0)
        in_mask = (pack.nbr_pos >= 0) * pack.nbr_mask
        w_in = _gat_edge_weight(s_dst[:, None, :], s_src[pos]
                                ) * in_mask[..., None]       # [b, D, H]
        xw_in = xw[pos]                                      # [b, D, H, fh]
        # out-of-batch neighbors: reconstruct, transform, score
        x_out_hat = jax.lax.stop_gradient(
            reconstruct(fcw, vq.assignment, pack.nbr_ids))   # [b, D, f_in]
        xw_out = jnp.einsum('bdf,fhe->bdhe', x_out_hat, p["w"])
        _, s_src_out = _gat_scores(xw_out, p["a_dst"], p["a_src"])
        out_mask = (pack.nbr_pos < 0) * pack.nbr_mask
        w_out = _gat_edge_weight(s_dst[:, None, :], s_src_out
                                 ) * out_mask[..., None]
        w_self = _gat_edge_weight(s_dst, s_src)              # [b, H]

        num = jnp.einsum('bdh,bdhe->bhe', w_in, xw_in) \
            + jnp.einsum('bdh,bdhe->bhe', w_out, xw_out) \
            + w_self[..., None] * xw
        den = w_in.sum(1) + w_out.sum(1) + w_self            # [b, H]
        # probe at the augmented (pre-normalization) message level
        m_aug = jnp.concatenate([num, den[..., None]], axis=-1)
        if probe is not None:
            m_aug = m_aug + probe.reshape(b, heads, fh + 1)
        y = m_aug[..., :fh] / jnp.maximum(m_aug[..., fh:], 1e-9)
        return act(y.reshape(b, heads * fh) + p["b"])


# ===========================================================================
# GraphTransformer  (dense learnable convolution, paper Table 5 + App. G)
# ===========================================================================

class GraphTransformer:
    """Global self-attention over ALL nodes each layer.

    Sampling methods cannot scale this (O(n^2) messages, no sparsity to
    sample); VQ-GNN reduces it to attention over b in-batch nodes + k
    codewords (the same machinery the LM-side VQ-Attention uses).
    Requires a full-width codebook (f_prod = f_in); see DESIGN.md.
    """
    name = "transformer"
    heads = 4

    @staticmethod
    def init(key, f_in: int, f_out: int, heads: int = 4, **_) -> Params:
        assert f_out % heads == 0
        dh = f_out // heads
        kq, kk, kv, ko = jax.random.split(key, 4)
        return {"wq": _dense(kq, f_in, f_out).reshape(f_in, heads, dh),
                "wk": _dense(kk, f_in, f_out).reshape(f_in, heads, dh),
                "wv": _dense(kv, f_in, f_out).reshape(f_in, heads, dh),
                "wo": _dense(ko, f_out, f_out), "b": jnp.zeros((f_out,))}

    @staticmethod
    def f_grad(f_in: int, f_out: int, **_) -> int:
        return f_out          # grad codewords at the attention-output level

    @staticmethod
    def probe_shape(b, f_in, f_out, **_):
        return (b, f_out)

    @staticmethod
    def full_apply(p: Params, x, ops_: FullGraphOperands, act) -> jax.Array:
        n = x.shape[0]
        heads, dh = p["wq"].shape[1:]
        q = jnp.einsum('nf,fhe->hne', x, p["wq"]) / jnp.sqrt(dh)
        k = jnp.einsum('nf,fhe->hne', x, p["wk"])
        v = jnp.einsum('nf,fhe->hne', x, p["wv"])
        s = jnp.clip(jnp.einsum('hne,hme->hnm', q, k),
                     -SCORE_CLIP, SCORE_CLIP)
        att = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum('hnm,hme->nhe', att, v).reshape(n, heads * dh)
        return act(y @ p["wo"] + p["b"])

    @staticmethod
    def vq_apply(p: Params, x_b, probe, pack: MinibatchPack,
                 vq: LayerVQState, degrees, cfg: CodebookConfig, act,
                 f_in: int, f_out: int, inject: bool = True) -> jax.Array:
        b = x_b.shape[0]
        heads, dh = p["wq"].shape[1:]
        assert vq.codebook.n_branches == 1, \
            "GraphTransformer needs a full-width codebook (f_prod=f_in)"
        dfcw, dgcw = layer_codewords(vq, f_in, cfg, dense=True)
        fcw, gcw = dfcw[0], dgcw[0]   # [k, f_in], [k, f_out]
        fcw = jax.lax.stop_gradient(fcw)
        mass = out_of_batch_cluster_mass(vq, pack.batch_ids)[0]  # [k]

        # ---- Eq. 7 injection: cluster-level reverse attention weights ----
        kk = fcw.shape[0]
        q_cl = jnp.einsum('kf,fhe->hke', fcw, p["wq"]) / jnp.sqrt(dh)
        k_cl = jnp.einsum('kf,fhe->hke', fcw, p["wk"])
        k_b0 = jnp.einsum('bf,fhe->hbe', x_b, p["wk"])
        s_cc = jnp.clip(jnp.einsum('hke,hue->hku', q_cl, k_cl),
                        -SCORE_CLIP, SCORE_CLIP)
        s_cb = jnp.clip(jnp.einsum('hke,hbe->hkb', q_cl, k_b0),
                        -SCORE_CLIP, SCORE_CLIP)
        # cluster-level row normalizer Z~_v (mass-weighted over clusters +
        # exact over in-batch keys)
        z_cl = jnp.einsum('hku,u->hk', jnp.exp(s_cc),
                          jnp.maximum(mass, 0.0)) \
            + jnp.exp(s_cb).sum(-1)                            # [h, k]
        rev_vals = jnp.exp(s_cb) * (mass[None, :, None] /
                                    jnp.maximum(z_cl, 1e-9)[..., None])
        rev_vals = jax.lax.stop_gradient(
            rev_vals.transpose(2, 0, 1).reshape(b, heads * kk))  # [b, h*k]
        # gradient codewords live at the attention-output (y) level; the
        # value path maps them back to x space per head: W_v,h G~_h.  The
        # receiving "neighbors" are the k clusters -- identical for every
        # row -- so the injection residual is the [h*k, f_in] table itself,
        # not its [b, h*k, f_in] broadcast (table-form injection).
        gcw_h = gcw.reshape(kk, heads, dh)
        ghat_x = jnp.einsum('khe,fhe->hkf', gcw_h, p["wv"])     # [h, k, f_in]
        ghat_x = jax.lax.stop_gradient(ghat_x.reshape(heads * kk, f_in))
        if inject:
            x_b = inject_context_grad_table(x_b, rev_vals, ghat_x, None)

        # ---- Eq. 6 forward: softmax over (b in-batch + k clusters) ----
        q = jnp.einsum('bf,fhe->hbe', x_b, p["wq"]) / jnp.sqrt(dh)
        k_in = jnp.einsum('bf,fhe->hbe', x_b, p["wk"])
        v_in = jnp.einsum('bf,fhe->hbe', x_b, p["wv"])
        k_cw = jnp.einsum('kf,fhe->hke', fcw, p["wk"])
        v_cw = jnp.einsum('kf,fhe->hke', fcw, p["wv"])
        s_in = jnp.clip(jnp.einsum('hbe,hue->hbu', q, k_in),
                        -SCORE_CLIP, SCORE_CLIP)                # [h, b, b]
        s_cw = jnp.clip(jnp.einsum('hbe,hke->hbk', q, k_cw),
                        -SCORE_CLIP, SCORE_CLIP) \
            + jnp.log(jnp.maximum(mass, 1e-9))[None, None, :]   # [h, b, k]
        s_cw = jnp.where(mass[None, None, :] > 0, s_cw, -jnp.inf)
        s = jnp.concatenate([s_in, s_cw], axis=-1)
        att = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum('hbu,hue->bhe', att[..., :b], v_in) \
            + jnp.einsum('hbk,hke->bhe', att[..., b:], v_cw)
        y = y.reshape(b, heads * dh)
        y = y if probe is None else y + probe
        return act(y @ p["wo"] + p["b"])


BACKBONES = {c.name: c for c in [GCN, SAGE, GIN, GAT, GraphTransformer]}
