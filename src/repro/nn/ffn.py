"""Feed-forward layers: SwiGLU MLP and top-k routed Mixture-of-Experts.

MoE dispatch is the capacity-gather formulation (DESIGN.md section 5):
  1. router -> top-k experts per token (+ softmax combine weights);
  2. each expert gathers its top-C tokens (C = tokens*k/E * capacity_factor)
     -- a plain gather, shardable with experts over the `model` axis (EP);
  3. batched per-expert matmuls  [E, C, d] x [E, d, ff];
  4. scatter-add combine weighted by router probs (+ psum over `model`).
No all-to-alls are emitted on a single device; under EP the gather/scatter
lower to the expected collectives.  Aux load-balance loss included.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init, swiglu


class MLPParams(NamedTuple):
    w1: jax.Array   # [d, ff]
    w3: jax.Array   # [d, ff]   (gate)
    w2: jax.Array   # [ff, d]


def init_mlp(key, d: int, ff: int, dtype=jnp.float32) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(dense_init(k1, d, ff, dtype),
                     dense_init(k3, d, ff, dtype),
                     dense_init(k2, ff, d, dtype))


def apply_mlp(p: MLPParams, x: jax.Array) -> jax.Array:
    return swiglu(x, p.w1, p.w3, p.w2)


class MoEParams(NamedTuple):
    router: jax.Array   # [d, E]
    w1: jax.Array       # [E, d, eff]
    w3: jax.Array       # [E, d, eff]
    w2: jax.Array       # [E, eff, d]


def init_moe(key, d: int, n_experts: int, expert_ff: int,
             dtype=jnp.float32) -> MoEParams:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return MoEParams(
        router=dense_init(kr, d, n_experts, jnp.float32),
        w1=(scale * jax.random.normal(k1, (n_experts, d, expert_ff))
            ).astype(dtype),
        w3=(scale * jax.random.normal(k3, (n_experts, d, expert_ff))
            ).astype(dtype),
        w2=((1.0 / jnp.sqrt(expert_ff)) *
            jax.random.normal(k2, (n_experts, expert_ff, d))).astype(dtype))


def apply_moe(p: MoEParams, x: jax.Array, top_k: int,
              capacity_factor: float = 1.25
              ) -> tuple[jax.Array, jax.Array]:
    """x: [T, d] (flattened tokens) -> (y [T, d], aux_loss []).

    Capacity-gather dispatch: expert e processes the C highest-prob tokens
    that routed to it (overflow tokens lose that expert -- standard
    capacity-drop semantics, recorded in the aux metrics).
    """
    t, d = x.shape
    e = p.router.shape[1]
    cap = min(t, max(1, int(t * top_k * capacity_factor / e)))

    logits = x.astype(jnp.float32) @ p.router            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)           # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce) / top_k

    # per-expert top-C token selection: score[token, expert] = routed prob
    routed = jnp.zeros((t, e), jnp.float32)
    routed = jnp.take_along_axis(
        routed, top_e, axis=1)  # placeholder to keep shapes obvious
    score = jnp.zeros((t, e), jnp.float32)
    score = score.at[jnp.arange(t)[:, None], top_e].add(top_p)

    gval, gidx = jax.lax.top_k(score.T, cap)             # [E, C]
    # gather tokens per expert: [E, C, d]
    xe = x[gidx]
    h = jnp.einsum('ecd,edf->ecf', xe.astype(jnp.float32),
                   p.w1.astype(jnp.float32))
    gate = jnp.einsum('ecd,edf->ecf', xe.astype(jnp.float32),
                      p.w3.astype(jnp.float32))
    h = jax.nn.silu(h) * gate
    ye = jnp.einsum('ecf,efd->ecd', h, p.w2.astype(jnp.float32))
    ye = ye * (gval > 0)[..., None]                      # mask empty slots

    # scatter-add combine, weighted by the (renormalized) router probs
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[gidx.reshape(-1)].add(
        (ye * gval[..., None]).reshape(-1, d))
    return y.astype(x.dtype), aux
