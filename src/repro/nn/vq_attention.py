"""VQ-Attention: the paper's approximated message passing on the token graph.

A causal attention layer is a dense learnable graph convolution (paper
Table 5); VQ-GNN's Eq. 6 replaces messages from far-away context with
messages from k codewords.  Transposed to the sequence axis:

  * the "mini-batch" is the current block of W tokens (exact attention
    within the block and to the previous block -- the C_in term);
  * all older tokens are represented by k codewords of their (key, value)
    pairs with cluster masses (the C~_out X~ term); attention to a cluster
    of mass m scores  q.k~ + log m  (App. E row-normalization, exact);
  * the codebook is built *streamingly* as the sequence is consumed
    (online k-means on keys, value centroids ride along), the in-sequence
    analogue of the paper's EMA codebook.

Backward: unlike the GNN setting, the full sequence is resident during LM
training, so the centroid construction (linear sums) stays inside autodiff
(assignments stop-gradient, straight-through) -- gradients DO flow to past
tokens' k/v through the codewords.  This replaces the Eq. 7 injection with
an exact VJP of the same approximation; DESIGN.md section 4 records this
adaptation.

Cost: O(S * (2W + k) * d) instead of O(S^2 * d) -- sub-quadratic training
and O(k + W) per decode step, which is what unlocks the ``long_500k`` cells
for dense architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class VQAttnConfig(NamedTuple):
    k: int = 1024          # codewords per (batch, kv-head)
    window: int = 512      # exact-attention block/window width


class VQKVCache(NamedTuple):
    """Decode-time state: codebook summaries + exact ring window.

    Shapes (per layer):
      sum_k/sum_v: [B, Hkv, k, dh]   running cluster sums
      count:       [B, Hkv, k]       cluster masses
      win_k/win_v: [B, W, Hkv, dh]   ring buffer of the last W tokens
      pos:         []                absolute position
    """
    sum_k: jax.Array
    sum_v: jax.Array
    count: jax.Array
    win_k: jax.Array
    win_v: jax.Array
    pos: jax.Array


def init_vq_cache(b: int, n_kv: int, head_dim: int, cfg: VQAttnConfig,
                  dtype=jnp.bfloat16) -> VQKVCache:
    return VQKVCache(
        sum_k=jnp.zeros((b, n_kv, cfg.k, head_dim), jnp.float32),
        sum_v=jnp.zeros((b, n_kv, cfg.k, head_dim), jnp.float32),
        count=jnp.zeros((b, n_kv, cfg.k), jnp.float32),
        win_k=jnp.zeros((b, cfg.window, n_kv, head_dim), dtype),
        win_v=jnp.zeros((b, cfg.window, n_kv, head_dim), dtype),
        pos=jnp.zeros((), jnp.int32))


def _centroids(sum_k, sum_v, count):
    denom = jnp.maximum(count, 1e-6)[..., None]
    return sum_k / denom, sum_v / denom


def _assign(keys: jax.Array, cent_k: jax.Array, count: jax.Array
            ) -> jax.Array:
    """Nearest centroid (masked to live clusters).  keys: [..., m, dh],
    cent_k: [..., k, dh], count: [..., k] -> [..., m] int32."""
    d = -2.0 * jnp.einsum('...md,...kd->...mk', keys.astype(jnp.float32),
                          cent_k.astype(jnp.float32)) \
        + jnp.sum(cent_k.astype(jnp.float32) ** 2, -1)[..., None, :]
    d = jnp.where(count[..., None, :] > 0, d, 0.5 * jnp.finfo(jnp.float32).max)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# training: block-scan with a streaming codebook
# ---------------------------------------------------------------------------

def vq_attention_train(q: jax.Array, k: jax.Array, v: jax.Array,
                       cfg: VQAttnConfig) -> jax.Array:
    """Causal VQ-Attention over a full training sequence.

    q: [B, S, Hq, dh], k/v: [B, S, Hkv, dh] -> [B, S, Hq, dh].
    S must be a multiple of cfg.window.
    """
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    w = min(cfg.window, s)
    nblk = s // w
    assert s % w == 0, (s, w)
    kcb = cfg.k
    scale = 1.0 / jnp.sqrt(dh)

    # [nblk, B, Hkv, w, dh] block-major layout for the scan
    kb = k.transpose(0, 2, 1, 3).reshape(b, hkv, nblk, w, dh
                                         ).transpose(2, 0, 1, 3, 4)
    vb = v.transpose(0, 2, 1, 3).reshape(b, hkv, nblk, w, dh
                                         ).transpose(2, 0, 1, 3, 4)
    qb = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, nblk, w, dh
                                         ).transpose(3, 0, 1, 2, 4, 5)

    causal = jnp.tril(jnp.ones((w, w), jnp.float32))

    def step(carry, blk):
        sum_k, sum_v, count, prev_k, prev_v, has_prev = carry
        qi, ki, vi = blk                       # [B,Hkv,(g,)w,dh]
        cent_k, cent_v = _centroids(sum_k, sum_v, count)
        q32 = qi.astype(jnp.float32) * scale

        # codeword context (C~_out X~): mass-weighted softmax contribution
        s_cb = jnp.einsum('bhgqd,bhkd->bhgqk', q32, cent_k) \
            + jnp.log(jnp.maximum(count, 1e-9))[:, :, None, None, :]
        s_cb = jnp.where(count[:, :, None, None, :] > 0, s_cb, -jnp.inf)
        # previous block (exact sliding window)
        s_pr = jnp.einsum('bhgqd,bhkd->bhgqk', q32,
                          prev_k.astype(jnp.float32))
        s_pr = jnp.where(has_prev > 0, s_pr, -jnp.inf)
        # current block, causal (C_in)
        s_in = jnp.einsum('bhgqd,bhkd->bhgqk', q32, ki.astype(jnp.float32))
        s_in = jnp.where(causal[None, None, None] > 0, s_in, -jnp.inf)

        s_all = jnp.concatenate([s_cb, s_pr, s_in], axis=-1)
        att = jax.nn.softmax(s_all, axis=-1)
        o = jnp.einsum('bhgqk,bhkd->bhgqd', att[..., :kcb], cent_v) \
            + jnp.einsum('bhgqk,bhkd->bhgqd', att[..., kcb:kcb + w],
                         prev_v.astype(jnp.float32)) \
            + jnp.einsum('bhgqk,bhkd->bhgqd', att[..., kcb + w:],
                         vi.astype(jnp.float32))

        # ---- streaming codebook update: fold the OUTGOING block (the one
        # leaving the exact window) into the clusters.  Assignments are
        # stop-gradient; the sums stay differentiable (straight-through). --
        def fold(args):
            sk, sv, ct = args
            # seed empty clusters round-robin from the incoming keys
            seed_slot = (jnp.argmin(ct, axis=-1)[..., None]
                         + jnp.arange(w)[None, None]) % kcb
            any_live = (ct.max(-1, keepdims=True) > 0)
            assign = jnp.where(
                any_live,
                jax.lax.stop_gradient(
                    _assign(prev_k.astype(jnp.float32), *_centroids(
                        sk, sv, ct)[:1], ct)),
                seed_slot.astype(jnp.int32))
            onehot = jax.nn.one_hot(assign, kcb, dtype=jnp.float32)
            pm = jnp.where(has_prev > 0, 1.0, 0.0)
            sk = sk + pm * jnp.einsum('bhwk,bhwd->bhkd', onehot,
                                      prev_k.astype(jnp.float32))
            sv = sv + pm * jnp.einsum('bhwk,bhwd->bhkd', onehot,
                                      prev_v.astype(jnp.float32))
            ct = ct + pm * jnp.sum(onehot, axis=2)
            return sk, sv, ct

        sum_k, sum_v, count = fold((sum_k, sum_v, count))
        return (sum_k, sum_v, count, ki, vi, jnp.ones(())), o

    init = (jnp.zeros((b, hkv, kcb, dh), jnp.float32),
            jnp.zeros((b, hkv, kcb, dh), jnp.float32),
            jnp.zeros((b, hkv, kcb), jnp.float32),
            jnp.zeros((b, hkv, w, dh), q.dtype),
            jnp.zeros((b, hkv, w, dh), q.dtype),
            jnp.zeros(()))
    _, outs = jax.lax.scan(step, init, (qb, kb, vb))
    # outs: [nblk, B, Hkv, g, w, dh] -> [B, S, Hq, dh]
    o = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv * g, s, dh)
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: O(k + W) per step via the fused Pallas kernel
# ---------------------------------------------------------------------------

def vq_attention_decode(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                        cache: VQKVCache, cfg: VQAttnConfig
                        ) -> tuple[jax.Array, VQKVCache]:
    """One decode step.  q: [B, 1, Hq, dh], k/v_new: [B, 1, Hkv, dh]."""
    b, _, hq, dh = q.shape
    hkv = k_new.shape[2]
    g = hq // hkv
    w = cache.win_k.shape[1]

    # fold the token that falls out of the window into the codebook
    slot = cache.pos % w
    old_k = jax.lax.dynamic_slice_in_dim(cache.win_k, slot, 1, axis=1)
    old_v = jax.lax.dynamic_slice_in_dim(cache.win_v, slot, 1, axis=1)
    evict = (cache.pos >= w).astype(jnp.float32)
    cent_k, _ = _centroids(cache.sum_k, cache.sum_v, cache.count)
    okh = old_k.transpose(0, 2, 1, 3).astype(jnp.float32)   # [B,Hkv,1,dh]
    ovh = old_v.transpose(0, 2, 1, 3).astype(jnp.float32)
    assign = _assign(okh, cent_k, jnp.maximum(cache.count, 1e-9))
    # seed empty codebook: first k evictions each claim their own slot
    seeded = jnp.where(cache.count.max() > 0, assign,
                       (cache.pos % cfg.k)[None, None, None])
    onehot = jax.nn.one_hot(seeded[..., 0], cfg.k, dtype=jnp.float32)
    sum_k = cache.sum_k + evict * onehot[..., None] * okh
    sum_v = cache.sum_v + evict * onehot[..., None] * ovh
    count = cache.count + evict * onehot

    # write the new token into the ring window
    win_k = jax.lax.dynamic_update_slice_in_dim(
        cache.win_k, k_new.astype(cache.win_k.dtype), slot, axis=1)
    win_v = jax.lax.dynamic_update_slice_in_dim(
        cache.win_v, v_new.astype(cache.win_v.dtype), slot, axis=1)
    # a ring slot is valid iff it has ever been written
    win_mask = (jnp.arange(w) <= cache.pos).astype(jnp.float32)

    cent_k, cent_v = _centroids(sum_k, sum_v, count)
    qh = q[:, 0].reshape(b, hkv, g, dh)                # group-major queries
    n = b * hkv
    out = kops.vq_attention_decode(
        qh.reshape(n, g, dh),
        cent_k.reshape(n, cfg.k, dh).astype(q.dtype),
        cent_v.reshape(n, cfg.k, dh).astype(q.dtype),
        count.reshape(n, cfg.k),
        win_k.transpose(0, 2, 1, 3).reshape(n, w, dh),
        win_v.transpose(0, 2, 1, 3).reshape(n, w, dh),
        jnp.broadcast_to(win_mask[None], (b, w)).repeat(hkv, 0).reshape(n, w))
    out = out.reshape(b, hkv, g, dh).reshape(b, 1, hq, dh)
    return out.astype(q.dtype), VQKVCache(sum_k, sum_v, count, win_k, win_v,
                                          cache.pos + 1)
