"""Mamba2 (SSD) block -- the state-space substrate for zamba2.

Scalar-decay state space (Mamba2's SSD form): per head h with state size N,

    H_t = a_t * H_{t-1} + dt_t * B_t (x) x_t        H in R^{N x P}
    y_t = C_t . H_t + D * x_t

a_t = exp(-dt_t * A_h) with per-head A > 0, dt via softplus.  Training uses
``jax.lax.associative_scan`` over the time axis (the recurrence is linear
with scalar per-head decay -> a classic first-order scan), which is both
exact and O(log S) depth -- the TPU-idiomatic replacement for the CUDA
chunked kernel (DESIGN.md hardware adaptation).  Decode carries (H, conv
state) explicitly: O(1) per step, no KV growth -- why the ``long_500k``
cell is native for SSM archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init


class Mamba2Params(NamedTuple):
    in_proj: jax.Array    # [d, 2*di + 2*N + H]   (x, z, B, C, dt)
    conv_w: jax.Array     # [4, di + 2*N]         depthwise conv over time
    a_log: jax.Array      # [H]
    d_skip: jax.Array     # [H]
    dt_bias: jax.Array    # [H]
    norm_scale: jax.Array # [di]
    out_proj: jax.Array   # [di, d]


class Mamba2State(NamedTuple):
    h: jax.Array          # [B, H, N, P]    SSM state
    conv: jax.Array       # [B, 3, di+2N]   last taps of the causal conv


def dims(d_model: int, ssm_state: int, expand: int = 2,
         head_p: int = 64) -> tuple[int, int, int]:
    di = expand * d_model
    n_heads = di // head_p
    return di, n_heads, ssm_state


def init_mamba2(key, d_model: int, ssm_state: int,
                dtype=jnp.float32) -> Mamba2Params:
    di, h, n = dims(d_model, ssm_state)
    k1, k2, k3 = jax.random.split(key, 3)
    conv_ch = di + 2 * n
    return Mamba2Params(
        in_proj=dense_init(k1, d_model, 2 * di + 2 * n + h, dtype),
        conv_w=(0.5 * jax.random.normal(k2, (4, conv_ch), jnp.float32)
                ).astype(dtype),
        a_log=jnp.zeros((h,), jnp.float32),
        d_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.full((h,), -2.0, jnp.float32),
        norm_scale=jnp.ones((di,), dtype),
        out_proj=dense_init(k3, di, d_model, dtype))


def _split(p: Mamba2Params, proj: jax.Array, di: int, n: int, h: int):
    x = proj[..., :di]
    z = proj[..., di:2 * di]
    bmat = proj[..., 2 * di:2 * di + n]
    cmat = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return x, z, bmat, cmat, dt


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel 4.  x: [B, S, C], w: [4, C]."""
    pad = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1]] * w[i][None, None]
               for i in range(4))


def apply_mamba2_train(p: Mamba2Params, xin: jax.Array, d_model: int,
                       ssm_state: int) -> jax.Array:
    """xin: [B, S, d] -> [B, S, d] via associative scan over time."""
    di, h, n = dims(d_model, ssm_state)
    pdim = di // h
    b, s, _ = xin.shape
    proj = xin @ p.in_proj
    x, z, bmat, cmat, dt = _split(p, proj, di, n, h)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p.conv_w))
    x, bmat, cmat = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)     # [B,S,H]
    a = jnp.exp(-dt * jnp.exp(p.a_log))                          # [B,S,H]
    xh = x.reshape(b, s, h, pdim).astype(jnp.float32)
    # state increment  dB_t = dt * B_t (x) x_t : [B,S,H,N,P]
    inc = jnp.einsum('bsh,bsn,bshp->bshnp', dt,
                     bmat.astype(jnp.float32), xh)

    def combine(e1, e2):
        a1, u1 = e1
        a2, u2 = e2
        return a1 * a2, a2[..., None, None] * u1 + u2

    a_seq = jnp.moveaxis(a, 1, 0)                                # [S,B,H]
    u_seq = jnp.moveaxis(inc, 1, 0)                              # [S,B,H,N,P]
    _, hstates = jax.lax.associative_scan(combine, (a_seq, u_seq))
    hstates = jnp.moveaxis(hstates, 0, 1)                        # [B,S,H,N,P]

    y = jnp.einsum('bsn,bshnp->bshp', cmat.astype(jnp.float32), hstates)
    y = y + p.d_skip[None, None, :, None] * xh
    y = y.reshape(b, s, di).astype(xin.dtype)
    y = y * jax.nn.silu(z)
    # grouped RMSNorm (per Mamba2)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, -1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + 1e-5) *
         p.norm_scale.astype(jnp.float32)).astype(xin.dtype)
    return y @ p.out_proj


def init_mamba2_state(b: int, d_model: int, ssm_state: int,
                      dtype=jnp.float32) -> Mamba2State:
    di, h, n = dims(d_model, ssm_state)
    return Mamba2State(
        h=jnp.zeros((b, h, n, di // h), jnp.float32),
        conv=jnp.zeros((b, 3, di + 2 * n), dtype))


def apply_mamba2_step(p: Mamba2Params, xin: jax.Array, state: Mamba2State,
                      d_model: int, ssm_state: int
                      ) -> tuple[jax.Array, Mamba2State]:
    """One decode step.  xin: [B, 1, d]."""
    di, h, n = dims(d_model, ssm_state)
    pdim = di // h
    b = xin.shape[0]
    proj = xin[:, 0] @ p.in_proj
    x, z, bmat, cmat, dt = _split(p, proj, di, n, h)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)              # [B, C]
    taps = jnp.concatenate([state.conv, xbc[:, None]], axis=1)   # [B, 4, C]
    xbc = jax.nn.silu(jnp.einsum('btc,tc->bc', taps, p.conv_w))
    new_conv = taps[:, 1:]
    x, bmat, cmat = xbc[:, :di], xbc[:, di:di + n], xbc[:, di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)     # [B,H]
    a = jnp.exp(-dt * jnp.exp(p.a_log))
    xh = x.reshape(b, h, pdim).astype(jnp.float32)
    hnew = a[..., None, None] * state.h + jnp.einsum(
        'bh,bn,bhp->bhnp', dt, bmat.astype(jnp.float32), xh)
    y = jnp.einsum('bn,bhnp->bhp', cmat.astype(jnp.float32), hnew)
    y = y + p.d_skip[None, :, None] * xh
    y = y.reshape(b, di).astype(xin.dtype) * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, -1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + 1e-5) *
         p.norm_scale.astype(jnp.float32)).astype(xin.dtype)
    return (y @ p.out_proj)[:, None], Mamba2State(hnew, new_conv)
