"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

mLSTM: per head, a matrix memory C in R^{dk x dv} with exponential gating,

    C_t = f_t C_{t-1} + i_t k_t v_t^T        n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t^T q_t / max(|n_t^T q_t|, 1)

with log-space gate stabilization (m_t).  Training uses the *parallel*
(attention-like) form the xLSTM paper derives -- a decay-masked quadratic
attention; decode uses the O(1) recurrence.  Attention-free: the matrix
memory is itself a fixed-size context summary, which is why VQ-GNN's
codebook technique is inapplicable here (DESIGN.md Arch-applicability) --
the arch already has a constant-size context.

sLSTM: scalar-memory LSTM with exponential gating; the recurrence is
nonlinear in h_{t-1} so training runs a lax.scan over time (the paper's own
parallelization limit).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMParams(NamedTuple):
    wq: jax.Array        # [d, H*dk]
    wk: jax.Array        # [d, H*dk]
    wv: jax.Array        # [d, H*dv]
    w_if: jax.Array      # [d, 2*H]   input/forget gate pre-activations
    wo: jax.Array        # [H*dv, d]
    ogate: jax.Array     # [d, H*dv]


def init_mlstm(key, d: int, n_heads: int, dtype=jnp.float32) -> MLSTMParams:
    dk = dv = d // n_heads
    ks = jax.random.split(key, 6)
    return MLSTMParams(
        wq=dense_init(ks[0], d, n_heads * dk, dtype),
        wk=dense_init(ks[1], d, n_heads * dk, dtype),
        wv=dense_init(ks[2], d, n_heads * dv, dtype),
        w_if=dense_init(ks[3], d, 2 * n_heads, dtype),
        wo=dense_init(ks[4], d, d, dtype),
        ogate=dense_init(ks[5], d, d, dtype))


def apply_mlstm_train(p: MLSTMParams, x: jax.Array,
                      n_heads: int) -> jax.Array:
    """Parallel (decay-masked quadratic) form.  x: [B, S, d]."""
    b, s, d = x.shape
    dk = d // n_heads
    q = (x @ p.wq).reshape(b, s, n_heads, dk) / jnp.sqrt(dk)
    k = (x @ p.wk).reshape(b, s, n_heads, dk)
    v = (x @ p.wv).reshape(b, s, n_heads, dk)
    gates = (x @ p.w_if).reshape(b, s, n_heads, 2).astype(jnp.float32)
    logi = -jax.nn.softplus(-gates[..., 0])  # log i_t (sigmoid input gate)
    logf = -jax.nn.softplus(-gates[..., 1])  # log f_t

    # cumulative log-forget F_t = sum_{u<=t} log f_u ;
    # score(t, u) = F_t - F_u + log i_u  (u <= t), stabilized per row.
    # Processed in query chunks (lax.scan) so the [T, U] decay matrix never
    # exceeds [chunk, S] -- the 32k prefill cells materialized the full
    # [S, S, H] tensor otherwise (EXPERIMENTS.md Perf iteration 1).
    fcum = jnp.cumsum(logf, axis=1)                          # [B,S,H]
    chunk = min(1024, s)
    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)

    def do_chunk(_, xs):
        qc, fc, off = xs          # [B,c,H,dk], [B,c,H], []
        scores = fc[:, :, None, :] - fcum[:, None, :, :] \
            + logi[:, None, :, :]                            # [B,c,S,H]
        tidx = off + jnp.arange(qc.shape[1])
        causal = (tidx[None, :, None] >= jnp.arange(s)[None, None, :]
                  )[..., None]
        scores = jnp.where(causal, scores, -jnp.inf)
        m = jnp.max(scores, axis=2, keepdims=True)
        dmat = jnp.exp(scores - m)
        sim = jnp.einsum('bthd,buhd->btuh', qc, k32)
        w = sim * dmat
        norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)),
                           jnp.exp(-m[:, :, 0]))
        hc = jnp.einsum('btuh,buhd->bthd', w, v32) / norm[..., None]
        return None, hc

    if s % chunk == 0 and s > chunk:
        nc = s // chunk
        qcs = jnp.moveaxis(q32.reshape(b, nc, chunk, n_heads, dk), 1, 0)
        fcs = jnp.moveaxis(fcum.reshape(b, nc, chunk, n_heads), 1, 0)
        offs = jnp.arange(nc) * chunk
        _, hcs = jax.lax.scan(do_chunk, None, (qcs, fcs, offs))
        h = jnp.moveaxis(hcs, 0, 1).reshape(b, s, d)
    else:
        _, h = do_chunk(None, (q32, fcum, jnp.zeros((), jnp.int32)))
        h = h.reshape(b, s, d)
    h = h.astype(x.dtype)
    return (h * jax.nn.sigmoid(x @ p.ogate)) @ p.wo


class MLSTMState(NamedTuple):
    c: jax.Array        # [B, H, dk, dv]
    n: jax.Array        # [B, H, dk]
    m: jax.Array        # [B, H]     log-space stabilizer


def init_mlstm_state(b: int, d: int, n_heads: int) -> MLSTMState:
    dk = d // n_heads
    return MLSTMState(jnp.zeros((b, n_heads, dk, dk), jnp.float32),
                      jnp.zeros((b, n_heads, dk), jnp.float32),
                      jnp.full((b, n_heads), -1e30, jnp.float32))


def apply_mlstm_step(p: MLSTMParams, x: jax.Array, state: MLSTMState,
                     n_heads: int) -> tuple[jax.Array, MLSTMState]:
    """x: [B, 1, d] -> ([B, 1, d], new state).  O(1) per step."""
    b, _, d = x.shape
    dk = d // n_heads
    xt = x[:, 0]
    q = (xt @ p.wq).reshape(b, n_heads, dk).astype(jnp.float32) / jnp.sqrt(dk)
    k = (xt @ p.wk).reshape(b, n_heads, dk).astype(jnp.float32)
    v = (xt @ p.wv).reshape(b, n_heads, dk).astype(jnp.float32)
    gates = (xt @ p.w_if).reshape(b, n_heads, 2).astype(jnp.float32)
    logi = -jax.nn.softplus(-gates[..., 0])
    logf = -jax.nn.softplus(-gates[..., 1])

    m_new = jnp.maximum(state.m + logf, logi)
    fs = jnp.exp(state.m + logf - m_new)
    is_ = jnp.exp(logi - m_new)
    c = fs[..., None, None] * state.c + is_[..., None, None] * \
        jnp.einsum('bhk,bhv->bhkv', k, v)
    n = fs[..., None] * state.n + is_[..., None] * k
    num = jnp.einsum('bhk,bhkv->bhv', q, c)
    # stabilized-space normalizer floor is exp(-m), NOT 1 (the unstabilized
    # floor 1 maps to exp(-m) after the m_t rescaling -- matches the
    # parallel form exactly; xLSTM stabilization appendix)
    den = jnp.maximum(jnp.abs(jnp.einsum('bhk,bhk->bh', q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, d).astype(x.dtype)
    out = (h * jax.nn.sigmoid(xt @ p.ogate)) @ p.wo
    return out[:, None], MLSTMState(c, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMParams(NamedTuple):
    w_x: jax.Array      # [d, 4*d]   (i, f, z, o) input projections
    w_h: jax.Array      # [d, 4*d]   recurrent projections
    b: jax.Array        # [4*d]
    wo: jax.Array       # [d, d]


def init_slstm(key, d: int, dtype=jnp.float32) -> SLSTMParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return SLSTMParams(
        w_x=dense_init(k1, d, 4 * d, dtype),
        w_h=(0.3 * jax.random.normal(k2, (d, 4 * d), jnp.float32) /
             jnp.sqrt(d)).astype(dtype),
        b=jnp.zeros((4 * d,), dtype),
        wo=dense_init(k3, d, d, dtype))


class SLSTMState(NamedTuple):
    h: jax.Array        # [B, d]
    c: jax.Array        # [B, d]
    n: jax.Array        # [B, d]
    m: jax.Array        # [B, d]


def init_slstm_state(b: int, d: int) -> SLSTMState:
    return SLSTMState(jnp.zeros((b, d), jnp.float32),
                      jnp.zeros((b, d), jnp.float32),
                      jnp.ones((b, d), jnp.float32),
                      jnp.full((b, d), -1e30, jnp.float32))


def _slstm_cell(p: SLSTMParams, xt: jax.Array,
                st: SLSTMState) -> tuple[jax.Array, SLSTMState]:
    pre = (xt @ p.w_x + st.h.astype(xt.dtype) @ p.w_h + p.b
           ).astype(jnp.float32)
    d = xt.shape[-1]
    zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
    logi = zi                      # exponential input gate (log space)
    logf = -jax.nn.softplus(-zf)   # sigmoid forget gate (log space)
    m_new = jnp.maximum(st.m + logf, logi)
    i = jnp.exp(logi - m_new)
    f = jnp.exp(st.m + logf - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c = f * st.c + i * z
    n = jnp.maximum(f * st.n + i, 1e-6)
    h = o * (c / n)
    return h.astype(xt.dtype), SLSTMState(h, c, n, m_new)


def apply_slstm_train(p: SLSTMParams, x: jax.Array) -> jax.Array:
    """x: [B, S, d] -- sequential lax.scan (nonlinear recurrence)."""
    b, s, d = x.shape
    st0 = init_slstm_state(b, d)

    def step(st, xt):
        h, st2 = _slstm_cell(p, xt, st)
        return st2, h

    _, hs = jax.lax.scan(step, st0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1) @ p.wo


def apply_slstm_step(p: SLSTMParams, x: jax.Array, st: SLSTMState
                     ) -> tuple[jax.Array, SLSTMState]:
    h, st2 = _slstm_cell(p, x[:, 0], st)
    return (h @ p.wo)[:, None], st2
