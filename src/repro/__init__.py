"""repro: VQ-GNN (NeurIPS 2021) as a production JAX/TPU framework."""

__version__ = "1.0.0"
