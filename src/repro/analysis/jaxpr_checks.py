"""Pass 1: jaxpr-level contract checks (REPRO10x) over the entry registry.

Every registered entry point is traced abstractly (tiny
ShapeDtypeStruct specs, no device work) and its ClosedJaxpr inspected:

  REPRO101  exact ``pallas_call`` dispatch count under forced kernels --
            in particular ONE fused context dispatch per layer regardless
            of the product-VQ branch count (the registry traces a second
            branch width to prove invariance).
  REPRO102  no host callbacks (``pure_callback`` / ``debug_callback`` /
            ``io_callback``) anywhere in a jitted hot body -- a callback
            inside the epoch scan would fence the device per batch.
  REPRO103  quantized dtype flow: every storage dtype present in the
            entry's operands (int8 / float8_e4m3fn) must reach some
            ``pallas_call`` input, and no ``convert_element_type`` OUTSIDE
            a kernel body upcasts a storage dtype to float -- i.e. no
            host-level dequantization before the kernel (the in-kernel
            f32 epilogue is the only sanctioned upcast).
  REPRO104  donation realized: the AOT-lowered module of each donating
            entry must carry input/output aliasing (``tf.aliasing_output``
            in the StableHLO text) -- a dropped ``donate_argnames`` still
            traces fine but silently doubles peak state memory.
  REPRO105  scan-carry bytes bounded: each ``lax.scan`` carry must fit
            the entry's budget (the donated model/VQ/opt state for the
            epoch executors, one activation table for the inference
            sweep) -- a stowaway [n, D] table in the carry is how O(n)
            leaks into the per-step working set.
  REPRO106  gradient-injection residuals: the saved vjp residuals of
            ``inject_context_grad`` must stay O(b*Dr + k*f) -- no leaf as
            large as the dense [b, Dr, f_grad] reconstruction the lazy
            Eq. 7 form exists to avoid.
  REPRO107  trace-counter contract: entries that promise compile-count
            telemetry must bump their counter exactly once per trace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis import Finding
from repro.analysis import registry
from repro.analysis.trace_count import INFER_TRACE_COUNT
from repro.distributed.quantization import dtype_nbits

_STORAGE = (jnp.dtype(jnp.int8), jnp.dtype(jnp.float8_e4m3fn))


def _sub_jaxprs(eqn):
    """(closed)jaxprs nested in an equation's params."""
    subs = []
    for v in eqn.params.values():
        leaves = jax.tree_util.tree_leaves(
            v, is_leaf=lambda x: isinstance(
                x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)))
        for leaf in leaves:
            if isinstance(leaf, jax.core.ClosedJaxpr):
                subs.append(leaf.jaxpr)
            elif isinstance(leaf, jax.core.Jaxpr):
                subs.append(leaf)
    return subs


def iter_eqns(jaxpr, in_kernel: bool = False):
    """Yield ``(eqn, in_kernel)`` over a jaxpr and all nested jaxprs;
    ``in_kernel`` marks equations inside a ``pallas_call`` body."""
    for eqn in jaxpr.eqns:
        yield eqn, in_kernel
        inner = in_kernel or eqn.primitive.name == "pallas_call"
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, inner)


def pallas_calls(closed_jaxpr):
    return [eqn for eqn, ink in iter_eqns(closed_jaxpr.jaxpr)
            if eqn.primitive.name == "pallas_call" and not ink]


def _aval_bytes(aval) -> int:
    size = 1
    for d in aval.shape:
        size *= int(d)
    return (size * dtype_nbits(aval.dtype) + 7) // 8


def check_entry(entry) -> list[Finding]:
    findings: list[Finding] = []
    loc = f"<entry:{entry.name}>"

    # REPRO107 -- counter bump, observable only on a fresh (uncached)
    # trace, so snapshot around the first .jaxpr() call
    check_counter = entry.counter is not None and entry._jaxpr is None
    before = INFER_TRACE_COUNT.snapshot() if check_counter else None
    try:
        cj = entry.jaxpr()
    except Exception as exc:  # a broken entry is itself a finding
        return [Finding("REPRO101", loc, 0,
                        f"entry failed to trace: {type(exc).__name__}: "
                        f"{exc}")]
    if check_counter:
        delta = INFER_TRACE_COUNT.delta(before)
        if delta.get(entry.counter, 0) != 1:
            findings.append(Finding(
                "REPRO107", loc, 0,
                f"expected exactly one '{entry.counter}' trace-counter "
                f"bump per trace, saw {delta.get(entry.counter, 0)} "
                f"(delta {delta})"))

    # REPRO101 -- exact dispatch count
    calls = pallas_calls(cj)
    if entry.pallas_count is not None and len(calls) != entry.pallas_count:
        names = [e.params["name_and_src_info"].name for e in calls]
        findings.append(Finding(
            "REPRO101", loc, 0,
            f"expected exactly {entry.pallas_count} pallas_call "
            f"dispatches, traced {len(calls)}: {names}"))

    # REPRO102 -- no host callbacks anywhere in the body
    for eqn, _ in iter_eqns(cj.jaxpr):
        if "callback" in eqn.primitive.name:
            findings.append(Finding(
                "REPRO102", loc, 0,
                f"host callback '{eqn.primitive.name}' inside the jitted "
                f"body (fences the device every step)"))

    # REPRO103 -- quantized dtype flow
    for dt in entry.quantized_dtypes:
        reaches = any(
            jnp.dtype(v.aval.dtype) == dt
            for eqn in calls for v in eqn.invars
            if hasattr(v, "aval") and hasattr(v.aval, "dtype"))
        if calls and not reaches:
            findings.append(Finding(
                "REPRO103", loc, 0,
                f"quantized operand dtype {dt} never reaches a "
                f"pallas_call input (dequantized upstream?)"))
    if entry.quantized_dtypes:
        for eqn, ink in iter_eqns(cj.jaxpr):
            if ink or eqn.primitive.name != "convert_element_type":
                continue
            src = jnp.dtype(eqn.invars[0].aval.dtype)
            dst = jnp.dtype(eqn.params["new_dtype"])
            if src in _STORAGE and jnp.issubdtype(dst, jnp.floating):
                findings.append(Finding(
                    "REPRO103", loc, 0,
                    f"host-level dequantization {src} -> {dst} outside "
                    f"a kernel body: quantized operands must stay in "
                    f"storage dtype until the in-kernel epilogue"))

    # REPRO104 -- donation aliasing in the lowered module
    if entry.donated_min and entry.lower is not None:
        text = entry.lower().as_text()
        aliased = text.count("tf.aliasing_output")
        if aliased < entry.donated_min:
            findings.append(Finding(
                "REPRO104", loc, 0,
                f"donation not realized: {aliased} aliased outputs in "
                f"the lowered module (expected >= {entry.donated_min}); "
                f"donate_argnames dropped or shapes/dtypes mismatched?"))

    # REPRO105 -- scan carry byte budget
    if entry.carry_budget is not None:
        for eqn, ink in iter_eqns(cj.jaxpr):
            if ink or eqn.primitive.name != "scan":
                continue
            inner = eqn.params["jaxpr"].jaxpr
            nc, ncarry = eqn.params["num_consts"], eqn.params["num_carry"]
            carry = [v.aval for v in inner.invars[nc:nc + ncarry]]
            total = sum(_aval_bytes(a) for a in carry)
            if total > entry.carry_budget:
                findings.append(Finding(
                    "REPRO105", loc, 0,
                    f"scan carry is {total} bytes, over the entry's "
                    f"{entry.carry_budget}-byte budget (a node-indexed "
                    f"table riding the carry?)"))

    return findings


def residual_findings() -> list[Finding]:
    """REPRO106: concrete tiny vjp of the lazy Eq. 7 injection."""
    from repro.core.message_passing import inject_context_grad
    b, dr, nb, k, f_blk, f, n = 16, 8, 4, 8, 4, 8, 40
    f_grad = nb * f_blk
    key = jax.random.PRNGKey(0)
    x_b = jnp.zeros((b, f), jnp.float32)
    rv = jnp.ones((b, dr), jnp.float32)
    ri = jax.random.randint(key, (b, dr), 0, n, jnp.int32)
    gcw = jnp.ones((nb, k, f_blk), jnp.float32)
    asg = jnp.zeros((nb, n), jnp.int32)
    w = jnp.ones((f_grad, f), jnp.float32)

    _, vjp_fn = jax.vjp(
        lambda xb: inject_context_grad(xb, rv, ri, gcw, asg, w), x_b)
    dense = b * dr * f_grad * 4  # the [b, Dr, f_grad] reconstruction
    return residual_leaf_findings(vjp_fn, dense,
                                  "<vjp:inject_context_grad>")


def residual_leaf_findings(vjp_fn, dense_bytes: int,
                           where: str) -> list[Finding]:
    """Flag vjp residuals that reach ``dense_bytes`` (singly or summed)."""
    leaves = jax.tree_util.tree_leaves(vjp_fn)
    sizes = [int(a.size) * dtype_nbits(a.dtype) // 8 for a in leaves
             if hasattr(a, "size")]
    findings = []
    if any(sz >= dense_bytes for sz in sizes):
        findings.append(Finding(
            "REPRO106", where, 0,
            f"a saved vjp residual is as large as the dense [b, Dr, "
            f"f_grad] reconstruction ({max(sizes)} >= {dense_bytes} "
            f"bytes): the lazy Eq. 7 form must save only the "
            f"O(b*Dr + k*f) operands"))
    if sum(sizes) >= dense_bytes:
        findings.append(Finding(
            "REPRO106", where, 0,
            f"total saved vjp residuals ({sum(sizes)} bytes) reach the "
            f"dense reconstruction size ({dense_bytes} bytes)"))
    return findings


def run(root: str | None = None) -> list[Finding]:
    del root  # jaxpr contracts are registry-driven, not path-driven
    findings: list[Finding] = []
    for entry in registry.entries():
        findings.extend(check_entry(entry))
    findings.extend(residual_findings())
    return findings
