"""CLI: ``python -m repro.analysis [--format text|github] [--baseline F]``.

Runs the three passes (AST lint first -- it needs no jax -- then the
jaxpr contract pass, then the Pallas VMEM pass, which reuses the jaxpr
pass's cached traces), prints every unsuppressed finding in the chosen
format, and exits 1 if any remain.  ``--baseline`` names a suppression
file of ``Finding.key()`` lines; the repo policy is an EMPTY baseline
(fix the tree, not the checker), but the flag exists so a downstream
fork can adopt the gate incrementally.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import Finding, load_baseline, suppress

_PASSES = ("ast", "jaxpr", "vmem")


def _run_pass(name: str, root: str) -> list[Finding]:
    if name == "ast":
        from repro.analysis import ast_checks
        return ast_checks.run(root)
    if name == "jaxpr":
        from repro.analysis import jaxpr_checks
        return jaxpr_checks.run(root)
    from repro.analysis import pallas_vmem
    return pallas_vmem.run(root)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checks: jaxpr contracts, Pallas "
                    "VMEM footprints, repo lint rules")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text")
    ap.add_argument("--baseline", metavar="FILE",
                    help="suppression file (one finding key per line)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=_PASSES, metavar="|".join(_PASSES),
                    help="run only the named pass(es); default: all")
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    args = ap.parse_args(argv)

    findings: list[Finding] = []
    for name in args.passes or _PASSES:
        findings.extend(_run_pass(name, args.root))
    if args.baseline:
        findings = suppress(findings, load_baseline(args.baseline))

    for f in findings:
        print(f.format(args.format))
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("repro.analysis: clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
