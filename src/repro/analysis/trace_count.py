"""Trace-time compile counters (the compile-count telemetry).

A :class:`TraceCounter` is a plain dict of named counters bumped at TRACE
time inside jitted bodies: retracing is the expensive event the executors
promise to bound (one inference pass costs n_layers layer traces, a serve
step one trace -- independent of the batch count S and of n % b), so the
counter deltas ARE the compile-count contract.  dict subclassing keeps the
historical ``INFER_TRACE_COUNT["layer"]`` indexing working everywhere.

Shared by the inference-executor entry points (``models/gnn.py``), their
tests, and the ``repro.analysis`` jaxpr pass (which asserts the deltas
while tracing the registered entry points on tiny specs).
"""
from __future__ import annotations


class TraceCounter(dict):
    """Named monotonic counters with snapshot/delta helpers."""

    def bump(self, key: str) -> None:
        """Increment ``key`` (call at trace time inside the jitted body)."""
        self[key] = self.get(key, 0) + 1

    def snapshot(self) -> dict:
        return dict(self)

    def delta(self, before: dict) -> dict:
        """Per-key increments since ``before`` (a :meth:`snapshot`)."""
        keys = set(self) | set(before)
        return {k: self.get(k, 0) - before.get(k, 0) for k in keys}


# The inference executors' counters: "layer" bumps once per trace of the
# per-layer scan body (replicated + row-sharded), "serve" once per trace
# of the one-compile serving step.
INFER_TRACE_COUNT = TraceCounter(layer=0, serve=0)
