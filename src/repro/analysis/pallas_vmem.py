"""Pass 2: Pallas VMEM static analysis (REPRO20x).

Walks every ``pallas_call`` equation of every registered entry's traced
jaxpr and computes its per-dispatch VMEM working set from the grid
mapping itself -- the sum of BlockSpec block bytes over the operands the
kernel actually holds in VMEM (operands in the ``any`` memory space are
HBM-resident and DMA'd manually; they charge their scratch buffers, not
their array bytes).

  REPRO201  a dispatch's computed VMEM working set exceeds the per-core
            envelope (2x the dispatch-heuristic budget: the heuristic
            reserves half of the ~16 MiB core VMEM, so any BLOCK footprint
            beyond the full envelope cannot be double-buffered at all).
  REPRO202  a BlockSpec that does not tile its operand evenly (array dim
            not divisible by block dim): the kernels pad their operands
            before dispatch, so a ragged block in a traced jaxpr means a
            padding path was dropped.
  REPRO203  dispatch-crossover cross-check: probe ``kernels/ops.py`` just
            below and just above its size heuristics and verify the
            heuristic agrees with the computed footprints -- below the
            SpMM crossover the resident kernel's working set must fit the
            envelope, above it the whole-matrix-in-VMEM kernel must NOT
            be chosen (ditto fused-vs-loop for the context kernel, where
            "one fused dispatch" is the below-crossover signature).

The crossover probes re-derive their shapes from the LIVE budgets
(``_vmem_budget_mb``), so a deployment that overrides
``REPRO_*_VMEM_BUDGET_MB`` is checked against its own configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis import Finding
from repro.analysis import registry
from repro.analysis.jaxpr_checks import pallas_calls
from repro.distributed.quantization import dtype_nbits


def _block_bytes(bm) -> int:
    total = 1
    for d in bm.block_shape:
        total *= int(d) if isinstance(d, int) else 1
    try:
        nbits = dtype_nbits(bm.array_shape_dtype.dtype)
    except (KeyError, TypeError):
        return 0
    return (total * nbits + 7) // 8


def _is_vmem(bm) -> bool:
    """Default-space blocks live in VMEM; 'any' means HBM-resident."""
    space = getattr(bm.block_aval, "memory_space", None)
    return space is None or "any" not in str(space).lower()


def _scratch_bytes(eqn) -> int:
    gm = eqn.params["grid_mapping"]
    ns = gm.num_scratch_operands
    if not ns:
        return 0
    body = eqn.params["jaxpr"]
    total = 0
    for var in body.invars[len(body.invars) - ns:]:
        aval = getattr(var.aval, "inner_aval", var.aval)
        shape = getattr(aval, "shape", ())
        try:
            nbits = dtype_nbits(getattr(aval, "dtype", None))
        except (KeyError, TypeError):
            continue  # semaphores and other unsized scratch
        size = 1
        for d in shape:
            size *= int(d)
        total += (size * nbits + 7) // 8
    return total


def dispatch_footprint(eqn) -> int:
    """Computed VMEM bytes of one pallas_call dispatch."""
    gm = eqn.params["grid_mapping"]
    blocks = sum(_block_bytes(bm) for bm in gm.block_mappings
                 if _is_vmem(bm))
    return blocks + _scratch_bytes(eqn)


def _kernel_name(eqn) -> str:
    return eqn.params["name_and_src_info"].name


def _envelope_bytes(kops) -> int:
    budget = max(
        kops._vmem_budget_mb(kops._dispatch_overrides,
                             "REPRO_SPMM_VMEM_BUDGET_MB"),
        kops._vmem_budget_mb(kops._context_overrides,
                             "REPRO_CONTEXT_VMEM_BUDGET_MB"))
    return int(budget * 2 * 2 ** 20)


def check_dispatches(closed_jaxpr, where: str,
                     envelope: int) -> list[Finding]:
    """REPRO201/202 over every pallas_call of one traced jaxpr."""
    findings = []
    for eqn in pallas_calls(closed_jaxpr):
        name = _kernel_name(eqn)
        fp = dispatch_footprint(eqn)
        if fp > envelope:
            findings.append(Finding(
                "REPRO201", where, 0,
                f"pallas dispatch '{name}' holds {fp} bytes in VMEM, "
                f"over the {envelope}-byte per-dispatch envelope"))
        for bm in eqn.params["grid_mapping"].block_mappings:
            if not _is_vmem(bm):
                continue
            arr = bm.array_shape_dtype.shape
            blk = bm.block_shape
            for a, b in zip(arr, blk):
                if isinstance(b, int) and b > 0 and int(a) % b != 0:
                    findings.append(Finding(
                        "REPRO202", where, 0,
                        f"'{name}' BlockSpec {tuple(blk)} does not tile "
                        f"operand {tuple(arr)} evenly (pad before "
                        f"dispatch)"))
                    break
    return findings


def _crossover_findings() -> list[Finding]:
    """REPRO203: ops.py heuristics vs computed footprints."""
    from repro.kernels import ops as kops
    findings: list[Finding] = []
    sds = jax.ShapeDtypeStruct
    envelope = _envelope_bytes(kops)
    b, deg = 32, 8

    def spmm_probe(n_src, f):
        args = (sds((b, deg), jnp.int32), sds((b, deg), jnp.float32),
                sds((n_src, f), jnp.float32))
        with registry.forced_pallas():
            # fresh lambda per probe: make_jaxpr caches traces on the
            # (function object, avals) pair, and the dispatch decision
            # must be re-evaluated under the CURRENT overrides
            return jax.make_jaxpr(lambda *a: kops.spmm_ell(*a))(*args)

    budget = int(kops._vmem_budget_mb(
        kops._dispatch_overrides, "REPRO_SPMM_VMEM_BUDGET_MB") * 2 ** 20)
    f = 16
    n_below = int(budget * 0.9) // (f * 4)
    n_above = int(budget * 1.2) // (f * 4)
    below = pallas_calls(spmm_probe(n_below, f))
    if len(below) != 1 or dispatch_footprint(below[0]) > envelope:
        findings.append(Finding(
            "REPRO203", "<crossover:spmm_ell>", 0,
            f"below the SpMM crossover ([{n_below}, {f}] f32) the "
            f"resident dispatch's computed footprint "
            f"{[dispatch_footprint(e) for e in below]} exceeds the "
            f"{envelope}-byte envelope (heuristic admits over-budget "
            f"dispatches)"))
    above = pallas_calls(spmm_probe(n_above, f))
    resident_x = [
        e for e in above
        if any(_is_vmem(bm) and tuple(bm.block_shape) == (  # whole x in VMEM
            bm.array_shape_dtype.shape) and
            bm.array_shape_dtype.shape[0] >= n_above
            for bm in e.params["grid_mapping"].block_mappings)]
    if resident_x:
        findings.append(Finding(
            "REPRO203", "<crossover:spmm_ell>", 0,
            f"above the SpMM crossover ([{n_above}, {f}] f32) the "
            f"dispatcher still VMEM-blocks the whole source matrix "
            f"({[_kernel_name(e) for e in resident_x]})"))

    def ctx_probe(n, nb):
        k, fb = 8, 4
        args = (sds((b, deg), jnp.int32), sds((b, deg), jnp.float32),
                sds((nb, n), jnp.int32), sds((nb, k, fb), jnp.float32))
        with registry.forced_pallas():
            return jax.make_jaxpr(lambda *a: kops.context_ell(*a))(*args)

    cbudget = int(kops._vmem_budget_mb(
        kops._context_overrides,
        "REPRO_CONTEXT_VMEM_BUDGET_MB") * 2 ** 20)
    nb = 4
    n_below = int(cbudget * 0.9) // (nb * 4)
    n_above = int(cbudget * 1.2) // (nb * 4)
    below = pallas_calls(ctx_probe(n_below, nb))
    if (len(below) != 1 or "context" not in _kernel_name(below[0])
            or dispatch_footprint(below[0]) > envelope):
        findings.append(Finding(
            "REPRO203", "<crossover:context_ell>", 0,
            f"below the context crossover ([{nb}, {n_below}] int32) "
            f"expected ONE fused dispatch within the envelope, traced "
            f"{[(_kernel_name(e), dispatch_footprint(e)) for e in below]}"
        ))
    above = pallas_calls(ctx_probe(n_above, nb))
    if any("context" in _kernel_name(e) for e in above):
        findings.append(Finding(
            "REPRO203", "<crossover:context_ell>", 0,
            f"above the context crossover ([{nb}, {n_above}] int32) the "
            f"fused kernel (whole assignment table VMEM-resident) is "
            f"still dispatched"))
    return findings


def run(root: str | None = None) -> list[Finding]:
    del root
    from repro.kernels import ops as kops
    envelope = _envelope_bytes(kops)
    findings: list[Finding] = []
    for entry in registry.entries():
        try:
            cj = entry.jaxpr()
        except Exception:
            continue  # the jaxpr pass reports trace failures
        findings.extend(
            check_dispatches(cj, f"<entry:{entry.name}>", envelope))
    findings.extend(_crossover_findings())
    return findings
