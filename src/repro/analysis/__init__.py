"""Static contract checking for the VQ-GNN serving stack (DESIGN.md sec. 16).

Three passes, each emitting :class:`Finding` rows:

  * ``jaxpr_checks``  (REPRO1xx) -- abstractly trace the registered hot
    entry points on tiny specs and prove the dispatch-count, callback,
    quantized-dtype-flow, donation, scan-carry and residual contracts
    from the jaxprs themselves.
  * ``pallas_vmem``   (REPRO2xx) -- walk every ``pallas_call`` equation's
    grid + BlockSpecs, compute per-dispatch VMEM footprints and
    grid/block divisibility, and cross-check the ``kernels/ops.py``
    dispatch crossovers against the computed footprints.
  * ``ast_checks``    (REPRO0xx) -- repo lint rules on the source tree
    (env reads reachable from jit, banned one-hot/einsum shapes in hot
    modules, Python loops in kernel bodies, unregistered pytree
    containers, import-time side effects).

CLI: ``python -m repro.analysis [--format text|github] [--baseline FILE]
[--pass ast|vmem|jaxpr ...]`` -- exits non-zero on any unsuppressed
finding.  This module stays import-light (no jax, no pass imports): the
shared ``trace_count`` telemetry lives here and is imported by
``models/gnn.py``, so pulling the passes in eagerly would be a cycle.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: a rule id, a location, and a message."""
    rule: str          # "REPRO001" ... "REPRO2xx"
    path: str          # repo-relative source path, or "<entry:NAME>" for
    #                    jaxpr-level findings with no single source line
    line: int          # 1-based; 0 when not tied to a line
    message: str

    def key(self) -> str:
        """Stable identity for baseline suppression (message-insensitive,
        so rewording a diagnostic never invalidates a baseline)."""
        return f"{self.rule}|{self.path}|{self.line}"

    def format(self, fmt: str = "text") -> str:
        if fmt == "github":
            # GitHub Actions workflow-command annotation syntax
            loc = f"file={self.path},line={max(self.line, 1)}"
            return f"::error {loc},title={self.rule}::{self.message}"
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def load_baseline(path: str) -> set[str]:
    """Suppression keys, one ``Finding.key()`` per line; '#' comments."""
    keys: set[str] = set()
    with open(path) as fh:
        for raw in fh:
            line = raw.split("#", 1)[0].strip()
            if line:
                keys.add(line)
    return keys


def suppress(findings: list[Finding], baseline: set[str]) -> list[Finding]:
    return [f for f in findings if f.key() not in baseline]
