"""Pass 3: repo lint rules (REPRO00x) -- pure-AST, no jax import needed.

  REPRO001  ``os.environ`` / ``os.getenv`` read inside a function
            reachable from a jit-traced body.  A live env read under
            trace desynchronizes from jit's executable cache (keyed on
            shapes + statics only, never on the environment), which is
            how the same process silently runs two different configs.
            ``repro/hostenv.py`` is the single sanctioned chokepoint
            (trace-frozen snapshot semantics) and is exempt.
            Reachability is an over-approximation: any function whose
            NAME is referenced inside a reachable function body counts
            as called (decorator jits, ``jax.jit(f)`` assignments, and
            functions handed to scan/cond/shard_map/grad/... seed the
            root set).  The tree is expected to be exactly clean, so
            over-approximating costs nothing and misses nothing.
  REPRO002  dense VQ materializations in the hot modules: ``one_hot``
            under ``core/``, ``kernels/`` and ``models/gnn.py`` (the
            [n, k] indicator is the O(n*k) form the paper's Sec. 4
            sparse-assignment design exists to avoid), and ``einsum``
            in ``core/codebook.py`` / ``core/conv.py`` (the [n, b, k]
            contraction path; the sketch-form einsums of
            ``message_passing.py`` and the oracle einsums of
            ``kernels/ref.py`` are the sanctioned exceptions).
  REPRO003  Python ``for``/``while`` inside a Pallas kernel body (a
            function taking ``*_ref`` parameters): trace-time loops
            unroll into the kernel and break the static block schedule.
            Host-side per-branch dispatch loops (``_context_ell_loop``)
            are outside kernel bodies and untouched.
  REPRO004  a class defining ``tree_flatten`` without
            ``register_pytree_node_class`` (decorator or module-level
            registration call): it traces as a leaf or errors only at
            the first jit boundary that receives it.
  REPRO005  import-time process mutation: assigning/updating
            ``os.environ`` (or ``os.putenv``) at module top level.
            Mutations under ``if __name__ == "__main__":`` are the CLI
            pattern and exempt (``launch/dryrun.py``).
"""
from __future__ import annotations

import ast
import os
from typing import Iterator

from repro.analysis import Finding

# modules where the [n, k] one-hot indicator is banned
_HOT_PREFIXES = ("core/", "kernels/", "models/gnn.py")
# modules where einsum itself is banned (dense-assignment contraction)
_NO_EINSUM = ("core/codebook.py", "core/conv.py")
_ENV_EXEMPT = ("hostenv.py",)

_ROOT_TAKERS = {
    "scan", "fori_loop", "while_loop", "cond", "switch", "shard_map",
    "grad", "value_and_grad", "vjp", "jvp", "custom_vjp", "custom_jvp",
    "defvjp", "defjvp", "checkpoint", "remat", "pallas_call", "vmap",
    "pmap",
}


def _py_files(root: str) -> Iterator[tuple[str, str]]:
    src = os.path.join(root, "src", "repro")
    for dirpath, _, names in os.walk(src):
        for name in sorted(names):
            if name.endswith(".py"):
                full = os.path.join(dirpath, name)
                yield full, os.path.relpath(full, root)


def _callee_name(func) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _is_jit_deco(deco) -> bool:
    """jax.jit / functools.partial(jax.jit, ...) decorators."""
    if _callee_name(deco) == "jit" or (
            isinstance(deco, ast.Name) and deco.id == "jit"):
        return True
    if isinstance(deco, ast.Call):
        if _callee_name(deco.func) == "jit":
            return True
        if _callee_name(deco.func) == "partial" and deco.args and \
                _callee_name(deco.args[0]) == "jit":
            return True
    return False


class _FnInfo:
    def __init__(self, rel: str, node: ast.AST):
        self.rel = rel
        self.node = node
        self.refs: set[str] = set()      # every identifier referenced
        self.env_reads: list[int] = []   # lines touching os.environ

    def scan(self):
        for sub in ast.walk(self.node):
            if isinstance(sub, ast.Name):
                self.refs.add(sub.id)
            elif isinstance(sub, ast.Attribute):
                self.refs.add(sub.attr)
                if sub.attr == "environ" and \
                        isinstance(sub.value, ast.Name) and \
                        sub.value.id == "os":
                    self.env_reads.append(sub.lineno)
            elif isinstance(sub, ast.Call) and \
                    _callee_name(sub.func) == "getenv":
                self.env_reads.append(sub.lineno)


def _collect(tree: ast.Module, rel: str, fns: dict, roots: set):
    """Index every function; seed jit roots from decorators, jax.jit(f)
    assignments, and names passed to trace-entering combinators."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = _FnInfo(rel, node)
            info.scan()
            fns.setdefault(node.name, []).append(info)
            if any(_is_jit_deco(d) for d in node.decorator_list):
                roots.add(node.name)
        elif isinstance(node, ast.Call):
            callee = _callee_name(node.func)
            if callee == "jit":
                for arg in node.args[:1]:
                    if (n := _callee_name(arg)):
                        roots.add(n)
            elif callee in _ROOT_TAKERS:
                for arg in list(node.args) + [k.value for k in
                                              node.keywords]:
                    if (n := _callee_name(arg)):
                        roots.add(n)


def _reachable(fns: dict, roots: set) -> set:
    seen: set[str] = set()
    frontier = [r for r in roots if r in fns]
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for info in fns[name]:
            for ref in info.refs:
                if ref in fns and ref not in seen:
                    frontier.append(ref)
    return seen


def _env_findings(parsed: list) -> list[Finding]:
    fns: dict[str, list[_FnInfo]] = {}
    roots: set[str] = set()
    for rel, tree in parsed:
        _collect(tree, rel, fns, roots)
    findings = []
    for name in sorted(_reachable(fns, roots)):
        for info in fns[name]:
            if info.rel.endswith(_ENV_EXEMPT) or not info.env_reads:
                continue
            for line in sorted(set(info.env_reads)):
                findings.append(Finding(
                    "REPRO001", info.rel, line,
                    f"os.environ read in '{name}', reachable from a "
                    f"jit-traced body -- route it through "
                    f"repro.hostenv.env_knob (trace-frozen snapshot)"))
    return findings


def _banned_call_findings(rel: str, tree: ast.Module) -> list[Finding]:
    sub = rel.split("src/repro/", 1)[-1]
    findings = []
    hot = sub.startswith(_HOT_PREFIXES)
    no_einsum = sub in _NO_EINSUM
    if not (hot or no_einsum):
        return findings
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _callee_name(node.func)
        if hot and callee == "one_hot":
            findings.append(Finding(
                "REPRO002", rel, node.lineno,
                "one_hot in a hot module materializes the dense [n, k] "
                "assignment indicator; use gather/segment ops on the "
                "sparse assignment instead"))
        if no_einsum and callee == "einsum":
            findings.append(Finding(
                "REPRO002", rel, node.lineno,
                "einsum in the codebook/conv hot path (dense [n, b, k] "
                "contraction form); use the kernel dispatchers"))
    return findings


def _kernel_loop_findings(rel: str, tree: ast.Module) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        args = node.args
        names = [a.arg for a in (args.posonlyargs + args.args +
                                 args.kwonlyargs)]
        if not any(n.endswith("_ref") for n in names):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, (ast.For, ast.While)):
                findings.append(Finding(
                    "REPRO003", rel, sub.lineno,
                    f"Python loop inside Pallas kernel body "
                    f"'{node.name}' unrolls at trace time; use "
                    f"lax.fori_loop or grid steps"))
    return findings


def _pytree_findings(rel: str, tree: ast.Module) -> list[Finding]:
    registered: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and (
                _callee_name(node.func) or "").startswith(
                    "register_pytree"):
            for arg in node.args[:1]:
                if (n := _callee_name(arg)):
                    registered.add(n)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        has_flatten = any(
            isinstance(m, ast.FunctionDef) and m.name == "tree_flatten"
            for m in node.body)
        if not has_flatten:
            continue
        decorated = any(
            (_callee_name(d) or getattr(d, "id", "")) ==
            "register_pytree_node_class" for d in node.decorator_list)
        if not decorated and node.name not in registered:
            findings.append(Finding(
                "REPRO004", rel, node.lineno,
                f"class '{node.name}' defines tree_flatten but is never "
                f"registered as a pytree node; it crosses jit "
                f"boundaries as an opaque leaf"))
    return findings


def _import_side_effect_findings(rel: str,
                                 tree: ast.Module) -> list[Finding]:
    findings = []

    def _is_main_guard(node) -> bool:
        return (isinstance(node, ast.If) and
                isinstance(node.test, ast.Compare) and
                isinstance(node.test.left, ast.Name) and
                node.test.left.id == "__name__")

    def _visit(body):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if _is_main_guard(node):
                continue
            if isinstance(node, (ast.If, ast.Try, ast.With)):
                for attr in ("body", "orelse", "finalbody"):
                    _visit(getattr(node, attr, []) or [])
                for h in getattr(node, "handlers", []):
                    _visit(h.body)
                continue
            for sub in ast.walk(node):
                target = None
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    tgts = (sub.targets if isinstance(sub, ast.Assign)
                            else [sub.target])
                    for t in tgts:
                        if isinstance(t, ast.Subscript) and \
                                isinstance(t.value, ast.Attribute) and \
                                t.value.attr == "environ":
                            target = sub
                elif isinstance(sub, ast.Call):
                    cn = _callee_name(sub.func)
                    if cn == "putenv" or (
                            cn in ("update", "setdefault", "pop") and
                            isinstance(sub.func, ast.Attribute) and
                            isinstance(sub.func.value, ast.Attribute) and
                            sub.func.value.attr == "environ"):
                        target = sub
                if target is not None:
                    findings.append(Finding(
                        "REPRO005", rel, target.lineno,
                        "process environment mutated at import time; "
                        "move it under `if __name__ == '__main__':` "
                        "(importing a module must be side-effect free)"))

    _visit(tree.body)
    return findings


def run(root: str | None = None) -> list[Finding]:
    root = root or os.getcwd()
    parsed = []
    findings: list[Finding] = []
    for full, rel in _py_files(root):
        with open(full) as fh:
            try:
                tree = ast.parse(fh.read(), filename=rel)
            except SyntaxError as exc:
                findings.append(Finding(
                    "REPRO005", rel, exc.lineno or 0,
                    f"unparseable module: {exc.msg}"))
                continue
        parsed.append((rel, tree))
        findings.extend(_banned_call_findings(rel, tree))
        findings.extend(_kernel_loop_findings(rel, tree))
        findings.extend(_pytree_findings(rel, tree))
        findings.extend(_import_side_effect_findings(rel, tree))
    findings.extend(_env_findings(parsed))
    return findings
