"""Entry-point registry for the jaxpr / VMEM analysis passes.

Builds ONE tiny setup (a 40-node SBM graph, a 2-layer GCN, an epoch plan)
and registers every hot jitted entry point of the serving stack against
it: the single-device / DP / row-sharded epoch executors, the sampler
baseline executor, the layer-locked inference sweep and the one-compile
serve step (the latter two across all five precision tiers).  Each
:class:`Entry` bundles

  * a thunk that traces the entry on ``ShapeDtypeStruct`` specs
    (``jax.make_jaxpr`` -- abstract, no FLOPs, no device buffers), and a
    thunk that AOT-lowers it (for the donation/aliasing check);
  * its contracts: exact ``pallas_call`` dispatch count under forced
    kernels, donation aliasing, scan-carry byte budget, the quantized
    storage dtypes that must reach the kernels, and the trace-counter key
    the entry must bump exactly once per trace.

Kernel-forcing note: on CPU the dispatchers route to the jnp oracles, so
the inference-side entries trace under ``REPRO_FORCE_PALLAS=1`` (set
host-side around the trace; ``repro.hostenv`` snapshots it).  The
TRAINING entries trace on the oracle path instead -- reverse-mode AD
through the interpret-mode SpMM kernel has no transpose rule (the same
reason the gradient tests skip under forced kernels), and their contracts
(donation, scan carry, callback freedom) are dispatch-independent.

Traced jaxprs are cached per entry so the jaxpr pass and the VMEM pass
share one trace.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import hostenv
from repro.distributed.quantization import tree_bytes

# Tiny-but-ragged: S = ceil(40/16) = 3 batches with a wrap-padded tail, so
# every trace exercises the slot-mask path.
_N, _B = 40, 16
_F, _CLASSES = 16, 4


def _sds(tree):
    """Pytree of concrete arrays -> same tree of ShapeDtypeStructs."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)),
        tree)


@contextlib.contextmanager
def forced_pallas():
    """Host-side REPRO_FORCE_PALLAS=1 around a trace.

    Mutating ``os.environ`` here is legitimate: this runs host-side (no
    trace active when the snapshot refreshes), exactly the configuration
    path the env-read-once contract sanctions."""
    prev = os.environ.get("REPRO_FORCE_PALLAS")
    os.environ["REPRO_FORCE_PALLAS"] = "1"
    hostenv.reset_env_snapshot()
    try:
        yield
    finally:
        if prev is None:
            del os.environ["REPRO_FORCE_PALLAS"]
        else:
            os.environ["REPRO_FORCE_PALLAS"] = prev
        hostenv.reset_env_snapshot()


@dataclasses.dataclass
class Entry:
    """One registered entry point plus its static contracts."""
    name: str
    trace: Callable[[], Any]            # () -> ClosedJaxpr
    lower: Optional[Callable[[], Any]]  # () -> jax.stages.Lowered
    force_pallas: bool = False
    # exact pallas_call dispatch count (None = count not pinned)
    pallas_count: Optional[int] = None
    # minimum "tf.aliasing_output" occurrences in the lowered text
    donated_min: int = 0
    # max bytes of any scan carry in the jaxpr (None = entry has no scan)
    carry_budget: Optional[int] = None
    # trace_count key this entry bumps exactly once per trace
    counter: Optional[str] = None
    # storage dtypes of quantized input leaves that must reach a
    # pallas_call without an intervening host-level float upcast
    quantized_dtypes: tuple = ()

    _jaxpr: Any = None

    def jaxpr(self):
        """The entry's ClosedJaxpr, traced once and cached."""
        if self._jaxpr is None:
            if self.force_pallas:
                with forced_pallas():
                    self._jaxpr = self.trace()
            else:
                self._jaxpr = self.trace()
        return self._jaxpr


def fresh_jaxpr(jit_fn, call, *args):
    """``jax.make_jaxpr(call)(*args)`` with ``jit_fn``'s trace cache
    dropped first.  The pjit cache is keyed on avals + statics ONLY --
    never on dispatch overrides or env knobs -- so an analysis trace that
    hit a stale cache entry would (a) skip the Python body (no trace-
    counter bump) and (b) reflect whatever dispatch config was active at
    the original trace.  The checker wants the CURRENT tree's behavior,
    so it always retraces."""
    if hasattr(jit_fn, "clear_cache"):
        jit_fn.clear_cache()
    return jax.make_jaxpr(call)(*args)


@functools.lru_cache(maxsize=None)
def tiny_setup(f_prod: int = 4):
    """The shared tiny problem instance, built once per branch width."""
    from repro.core.codebook import CodebookConfig
    from repro.graph.batching import (build_epoch_plan, epoch_slices,
                                      full_operands)
    from repro.graph.datasets import _node_classification
    from repro.models.gnn import GNNConfig, init_gnn, init_vq_states
    from repro.train.optimizer import rmsprop

    g = _node_classification("analysis-tiny", _N, _F, _CLASSES, 3.0,
                             0.6, 0.5, 0.5, 8, 0)
    cfg = GNNConfig(backbone="gcn", f_in=g.f, hidden=8,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=8, f_prod=f_prod))
    tm = np.zeros(g.n, np.float32)
    tm[g.train_idx] = 1.0
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    vq = init_vq_states(jax.random.PRNGKey(1), cfg, g.n)
    opt = rmsprop(3e-3)
    bids, smask = epoch_slices(np.arange(g.n), _B)
    return dict(
        g=g, cfg=cfg, opt=opt, params=params, vq=vq,
        ost=opt.init(params), plan=build_epoch_plan(g),
        degrees=full_operands(g).degrees,
        x=jnp.asarray(g.features), labels=jnp.asarray(g.labels),
        tm=jnp.asarray(tm),
        perm=jnp.asarray(bids.astype(np.int32)),
        smask=jnp.asarray(smask))


def _quantized_leaf_dtypes(tree) -> tuple:
    """Storage dtypes of the sub-f32 leaves of a quantized state tree."""
    storage = {jnp.dtype(jnp.int8), jnp.dtype(jnp.float8_e4m3fn)}
    found = {jnp.result_type(a) for a in jax.tree_util.tree_leaves(tree)
             if jnp.result_type(a) in storage}
    return tuple(sorted(found, key=str))


def _epoch_args(s):
    return (_sds(s["params"]), _sds(s["vq"]), _sds(s["ost"]),
            _sds(s["plan"]), _sds(s["perm"]), _sds(s["smask"]),
            _sds(s["x"]), _sds(s["labels"]), _sds(s["tm"]),
            _sds(s["degrees"]))


def _epoch_carry_budget(s) -> int:
    # the scan carries exactly the donated (params, vq, opt) state; pad
    # with a small absolute slack for scalar step counters and the like
    return tree_bytes((s["params"], s["vq"], s["ost"])) + 4096


# Dispatch counts under forced kernels, pinned per entry (and per tier
# where the operand dtypes change the kernel choice).  The registry pins
# them exactly: a new dispatch in the hot path must update this table in
# the same PR, which is precisely the review surface the checker exists
# to create.  Branch-count (nb) invariance is checked separately by
# tracing two branch widths -- the counts here must hold for BOTH.
PALLAS_COUNTS = {
    # per layer: ONE fused context dispatch (regardless of nb) + ONE
    # intra-batch SpMM; the non-inductive inference path runs no
    # assignment-refresh kernel.  Serve = the same two per layer x 2.
    "vq_infer_layer": 2,
    "vq_serve_batch": 4,
}


def _infer_entry(tier: Optional[str], f_prod: int = 4) -> Entry:
    from repro.models.gnn import quantize_vq_states, vq_infer_layer
    s = tiny_setup(f_prod)
    vq = (s["vq"] if tier in (None, "fp32")
          else quantize_vq_states(s["vq"], s["cfg"], precision=tier))
    st = vq[0]
    acts = jnp.zeros((s["g"].n, s["cfg"].f_in), jnp.float32)
    args = (_sds(s["params"][0]), _sds(st), _sds(s["plan"]),
            _sds(s["perm"]), _sds(s["smask"]), _sds(acts),
            _sds(s["degrees"]))
    cfg = s["cfg"]
    fn = functools.partial(vq_infer_layer, cfg=cfg, layer=0,
                           inductive=False)
    label = "fp32" if tier in (None, "fp32") else tier
    return Entry(
        name=f"vq_infer_layer[{label}]" + (
            f"@f_prod={f_prod}" if f_prod != 4 else ""),
        trace=lambda: fresh_jaxpr(vq_infer_layer, fn, *args),
        lower=None,
        force_pallas=True,
        pallas_count=PALLAS_COUNTS["vq_infer_layer"],
        carry_budget=(s["g"].n + 1) * cfg.f_in * 4 + 4096,
        counter="layer",
        quantized_dtypes=_quantized_leaf_dtypes(vq[0]))


def _serve_entry(tier: Optional[str], f_prod: int = 4) -> Entry:
    from repro.models.gnn import quantize_vq_states, vq_serve_batch
    s = tiny_setup(f_prod)
    vq = (s["vq"] if tier in (None, "fp32")
          else quantize_vq_states(s["vq"], s["cfg"], precision=tier))
    bids = jnp.zeros((_B,), jnp.int32)
    args = (_sds(s["params"]), _sds(vq), _sds(s["plan"]), _sds(bids),
            _sds(s["x"]), _sds(s["degrees"]))
    cfg = s["cfg"]
    fn = functools.partial(vq_serve_batch, cfg=cfg)
    label = "fp32" if tier in (None, "fp32") else tier
    return Entry(
        name=f"vq_serve_batch[{label}]" + (
            f"@f_prod={f_prod}" if f_prod != 4 else ""),
        trace=lambda: fresh_jaxpr(vq_serve_batch, fn, *args),
        lower=None,
        force_pallas=True,
        pallas_count=PALLAS_COUNTS["vq_serve_batch"],
        counter="serve",
        quantized_dtypes=_quantized_leaf_dtypes(vq))


def _train_entries() -> list[Entry]:
    from repro.distributed.data_parallel import _dp_epoch_jit, \
        _sharded_epoch_jit
    from repro.distributed.sharding import graph_dp_mesh
    from repro.graph.batching import SamplerEpochPlan
    from repro.models.gnn import sampler_train_epoch, vq_train_epoch

    s = tiny_setup()
    cfg, opt = s["cfg"], s["opt"]
    eargs = _epoch_args(s)
    budget = _epoch_carry_budget(s)

    entries = [Entry(
        name="vq_train_epoch",
        trace=lambda: fresh_jaxpr(
            vq_train_epoch,
            lambda *a: vq_train_epoch(*a, cfg, opt), *eargs),
        lower=lambda: vq_train_epoch.lower(*eargs, cfg, opt),
        donated_min=1, carry_budget=budget)]

    # sampler baseline: S batches of P=16 padded subgraph rows, deg cap 8
    sp = SamplerEpochPlan(
        node_ids=jnp.zeros((3, _B), jnp.int32),
        nbr_ids=jnp.zeros((3, _B, 8), jnp.int32),
        nbr_mask=jnp.zeros((3, _B, 8), jnp.float32),
        degrees=jnp.zeros((3, _B), jnp.float32),
        loss_mask=jnp.zeros((3, _B), jnp.float32))
    sargs = (_sds(s["params"]), _sds(s["ost"]), _sds(sp), _sds(s["x"]),
             _sds(s["labels"]))
    entries.append(Entry(
        name="sampler_train_epoch",
        trace=lambda: fresh_jaxpr(
            sampler_train_epoch,
            lambda *a: sampler_train_epoch(*a, cfg, opt), *sargs),
        lower=lambda: sampler_train_epoch.lower(*sargs, cfg, opt),
        donated_min=1,
        carry_budget=tree_bytes((s["params"], s["ost"])) + 4096))

    mesh = graph_dp_mesh()
    if int(mesh.shape["data"]) == 1:
        # the DP / row-sharded executors divide the batch axis over the
        # mesh; at ndev=1 the shard is the whole table, so the replicated
        # tiny operands trace both bodies unchanged
        entries.append(Entry(
            name="dp_epoch",
            trace=lambda: fresh_jaxpr(
                _dp_epoch_jit,
                lambda *a: _dp_epoch_jit(*a, mesh=mesh, cfg=cfg,
                                         opt=opt), *eargs),
            lower=lambda: _dp_epoch_jit.lower(*eargs, mesh=mesh, cfg=cfg,
                                              opt=opt),
            donated_min=1, carry_budget=budget))
        entries.append(Entry(
            name="sharded_epoch",
            trace=lambda: fresh_jaxpr(
                _sharded_epoch_jit,
                lambda *a: _sharded_epoch_jit(*a, mesh=mesh, cfg=cfg,
                                              opt=opt,
                                              compress=False), *eargs),
            lower=lambda: _sharded_epoch_jit.lower(
                *eargs, mesh=mesh, cfg=cfg, opt=opt, compress=False),
            donated_min=1, carry_budget=budget))
    return entries


@functools.lru_cache(maxsize=None)
def entries() -> tuple:
    """All registered entries (tuple: cached, iteration-stable)."""
    from repro.kernels import ops as kops
    out = _train_entries()
    for tier in kops.PRECISIONS:
        out.append(_infer_entry(tier))
        out.append(_serve_entry(tier))
    # branch-count invariance probes: same dispatch-count contract must
    # hold at a different product-VQ width (f_prod=2 -> more branches)
    out.append(_infer_entry("fp32", f_prod=2))
    out.append(_serve_entry("int8+a4", f_prod=2))
    return tuple(out)
