"""Batched LM serving with VQ-compressed KV cache vs exact cache.

The inference-side payoff of the paper: the KV state per sequence is
O(k + W) instead of O(t) -- constant memory, constant per-token latency
regardless of context length.

    PYTHONPATH=src python examples/serve_lm.py --tokens 64 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.lm import init_lm, init_serve_cache, serve_step


def cache_bytes(cache) -> int:
    return sum(np.asarray(x).nbytes
               for x in jax.tree_util.tree_leaves(cache))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=4096,
                    help="pre-allocated context length for the exact cache")
    args = ap.parse_args()

    base = ArchConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab=2048, remat=False, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), base)

    step = jax.jit(lambda p, t, c: serve_step(p, t, c, base))
    vq_cfg = base.with_vq(k=128, window=64)
    step_vq = jax.jit(lambda p, t, c: serve_step(p, t, c, vq_cfg))

    for name, cfg, fn in [("exact-kv", base, step),
                          ("vq-kv", vq_cfg, step_vq)]:
        cache = init_serve_cache(cfg, args.batch, args.context)
        tok = jnp.zeros((args.batch, 1), jnp.int32)
        logits, cache = fn(params, tok, cache)  # compile
        t0 = time.time()
        outs = []
        for _ in range(args.tokens):
            logits, cache = fn(params, tok, cache)
            tok = jnp.argmax(logits, -1)[:, None]
            outs.append(np.asarray(tok[:, 0]))
        dt = time.time() - t0
        tps = args.tokens * args.batch / dt
        print(f"{name:9s}: {tps:8.1f} tok/s   cache "
              f"{cache_bytes(cache)/2**20:7.2f} MB   "
              f"sample: {[int(o[0]) for o in outs[:8]]}")
    print("\nvq-kv cache size is independent of --context; exact-kv grows "
          "linearly with it.")


if __name__ == "__main__":
    main()
