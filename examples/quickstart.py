"""Quickstart: VQ-GNN (paper Alg. 1) vs full-graph training on a synthetic
ogbn-arxiv look-alike -- the paper's core accuracy-parity claim in ~2 min.

    PYTHONPATH=src python examples/quickstart.py [--n 2000] [--epochs 60]
"""
import argparse

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_vq, vq_inference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--backbone", default="gcn",
                    choices=["gcn", "sage", "gat", "gin", "transformer"])
    args = ap.parse_args()

    g = synthetic_arxiv(n=args.n)
    print(f"graph: {g.n} nodes, {g.m} edges, {g.num_classes} classes")
    cfg = GNNConfig(backbone=args.backbone, f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=2,
                    codebook=CodebookConfig(k=256, f_prod=4))

    print("\n-- full-graph oracle --")
    rf = train_full(g, cfg, epochs=args.epochs, eval_every=20)
    for h in rf["history"]:
        print(f"  epoch {h['epoch']:4d}  val {h['val']:.4f}  "
              f"({h['time']:.1f}s)")

    print("\n-- VQ-GNN (mini-batched, streaming codebooks) --")
    rv = train_vq(g, cfg, epochs=args.epochs, batch_size=400, eval_every=20)
    for h in rv["history"]:
        print(f"  epoch {h['epoch']:4d}  val {h['val']:.4f}  "
              f"({h['time']:.1f}s)")

    print(f"\nfull-graph test acc: {rf['final']['test']:.4f}")
    print(f"VQ-GNN     test acc: {rv['final']['test']:.4f}")
    print(f"VQ-GNN per-batch memory model: "
          f"{rv['mem_bytes']/2**20:.1f} MB "
          f"(all {rv['messages']:.0f} messages preserved)")

    import numpy as np
    emb = vq_inference(rv["params"], rv["vq_states"], g, cfg, 400)
    acc = (np.argmax(emb[g.test_idx], -1) == g.labels[g.test_idx]).mean()
    print(f"VQ mini-batched inference test acc: {acc:.4f}")


if __name__ == "__main__":
    main()
