"""Link prediction (paper Table 4, ogbl-collab setting): VQ-GNN vs
full-graph on the synthetic collab look-alike, Hits@50 metric.

    PYTHONPATH=src python examples/link_prediction.py
"""
import argparse

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_collab
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_vq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--epochs", type=int, default=40)
    args = ap.parse_args()

    g = synthetic_collab(n=args.n)
    print(f"graph: {g.n} nodes, {g.m} message edges, "
          f"{len(g.val_edges)} val / {len(g.test_edges)} test positives")
    cfg = GNNConfig(backbone="sage", f_in=g.f, hidden=64, n_out=64,
                    n_layers=2, task="link",
                    codebook=CodebookConfig(k=256, f_prod=4))
    rf = train_full(g, cfg, epochs=args.epochs, eval_every=args.epochs)
    rv = train_vq(g, cfg, epochs=args.epochs, batch_size=500,
                  eval_every=args.epochs)
    print(f"full-graph Hits@50: val {rf['final']['val']:.4f} "
          f"test {rf['final']['test']:.4f}")
    print(f"VQ-GNN     Hits@50: val {rv['final']['val']:.4f} "
          f"test {rv['final']['test']:.4f}")


if __name__ == "__main__":
    main()
