"""Graph-Transformer with GLOBAL attention scaled by VQ (paper App. G).

The case no sampling method can handle: every node attends to every node
(a dense learnable convolution, O(n^2) messages).  VQ-GNN reduces each
mini-batch row to b in-batch keys + k codeword keys -- this example trains
it mini-batched, which is impossible for subgraph samplers.

    PYTHONPATH=src python examples/graph_transformer.py
"""
import argparse

from repro.core.codebook import CodebookConfig
from repro.graph.datasets import synthetic_arxiv
from repro.models.gnn import GNNConfig
from repro.train.gnn_trainer import train_full, train_vq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1200)
    ap.add_argument("--epochs", type=int, default=30)
    args = ap.parse_args()

    g = synthetic_arxiv(n=args.n)
    cfg = GNNConfig(backbone="transformer", f_in=g.f, hidden=64,
                    n_out=g.num_classes, n_layers=2, heads=4,
                    codebook=CodebookConfig(k=128))
    print(f"global attention: {g.n}^2 = {g.n**2:,} messages per layer "
          f"full-graph; VQ mini-batch: b*(b+k) per batch")
    rf = train_full(g, cfg, epochs=args.epochs, eval_every=args.epochs)
    rv = train_vq(g, cfg, epochs=args.epochs, batch_size=300,
                  eval_every=args.epochs)
    print(f"full-graph  val acc: {rf['final']['val']:.4f}")
    print(f"VQ-GNN      val acc: {rv['final']['val']:.4f}")


if __name__ == "__main__":
    main()
