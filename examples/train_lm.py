"""End-to-end LM training driver: dense decoder with VQ-Attention (the
paper's technique on the token graph) vs exact attention, on the synthetic
token stream, with checkpoints and restart.

Default is CPU-sized; pass --preset 100m for the ~100M-parameter run
(use a TPU host or be patient):

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse

from repro.configs.base import ArchConfig
from repro.train.loop import train

PRESETS = {
    "tiny": ArchConfig(name="tiny-lm", family="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                       vocab=2048, remat=False, dtype="float32"),
    "100m": ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=32768, remat=True, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vq", action="store_true",
                    help="enable VQ-Attention (codebook context)")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    if args.vq:
        cfg = cfg.with_vq(k=64, window=64)
    n_params = cfg.param_count()
    print(f"arch {cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"vq_attn={cfg.vq_attn}")

    out = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
                lr=3e-4, ckpt_dir=args.ckpt, ckpt_every=50, log_every=10)
    for h in out["history"]:
        print(f"  step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"({h['time']:.0f}s)")
    first, last = out["history"][0], out["history"][-1]
    print(f"\nloss: {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({args.steps} steps, ckpts in {args.ckpt})")


if __name__ == "__main__":
    main()
